"""Assigned-architecture config registry: ``get_config(arch_id)``.

Each module defines ``CONFIG`` with the exact published architecture
hyperparameters ([source; verified-tier] noted per file).  Shapes come from
``repro.models.config.SHAPES``; (arch × shape) applicability (e.g. long_500k
only for sub-quadratic archs) is encoded in ``cell_supported``.
"""
from importlib import import_module
from typing import Dict, List, Tuple

from ..models.config import ModelConfig, SHAPES, ShapeConfig

ARCH_IDS: List[str] = [
    "qwen3_14b",
    "llama3_405b",
    "starcoder2_3b",
    "deepseek_7b",
    "whisper_large_v3",
    "kimi_k2_1t_a32b",
    "moonshot_v1_16b_a3b",
    "mamba2_2p7b",
    "jamba_v0p1_52b",
    "qwen2_vl_2b",
]

_ALIASES = {i.replace("_", "-"): i for i in ARCH_IDS}
_ALIASES.update(
    {
        "qwen3-14b": "qwen3_14b",
        "llama3-405b": "llama3_405b",
        "starcoder2-3b": "starcoder2_3b",
        "deepseek-7b": "deepseek_7b",
        "whisper-large-v3": "whisper_large_v3",
        "kimi-k2-1t-a32b": "kimi_k2_1t_a32b",
        "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
        "mamba2-2.7b": "mamba2_2p7b",
        "jamba-v0.1-52b": "jamba_v0p1_52b",
        "qwen2-vl-2b": "qwen2_vl_2b",
    }
)


def get_config(arch: str) -> ModelConfig:
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    if mod_name not in ARCH_IDS:
        raise KeyError(f"unknown arch '{arch}'; known: {sorted(_ALIASES)}")
    return import_module(f"repro.configs.{mod_name}").CONFIG


def cell_supported(cfg: ModelConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Is (arch × shape) runnable? Returns (supported, reason-if-not)."""
    if shape.name == "long_500k" and cfg.family not in ("ssm", "hybrid"):
        return False, "long_500k needs sub-quadratic context (SSM/hybrid only)"
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    out = []
    for a in ARCH_IDS:
        for s in SHAPES:
            out.append((a, s))
    return out
