"""llama3-405b [dense] — 126L d_model=16384 128H (GQA kv=8) d_ff=53248
vocab=128256 — GQA, 128k vocab. [arXiv:2407.21783; unverified]

Training state uses bf16 params + f32 master moments sharded FSDP×TP; see
dist/sharding_rules.py. long_500k is skipped (pure full attention)."""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=53248,
    vocab_size=128256,
    rope_theta=500_000.0,
    mlp_act="swiglu",
    param_dtype="bfloat16",  # 405B f32 params would not fit 256 chips
    fsdp_over_pod=True,
    opt_state_dtype="bfloat16",
)
