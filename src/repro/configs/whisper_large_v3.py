"""whisper-large-v3 [audio] — enc-dec, 32L decoder, d_model=1280 20H (kv=20)
d_ff=5120 vocab=51866 — conv/mel frontend is a STUB (input_specs provides
precomputed 1500-frame embeddings). [arXiv:2212.04356; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    num_layers=32,          # decoder layers
    encoder_layers=32,
    encoder_seq=1500,       # 30 s of mel frames after conv stride 2
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    vocab_size=51866,
    mlp_act="gelu",
    tie_embeddings=True,
    frontend="audio_stub",
)
