"""mamba2-2.7b [ssm] — 64L d_model=2560 attention-free, vocab=50280,
ssm_state=128 — SSD (state-space duality). Runs long_500k (O(1)/token
state). [arXiv:2405.21060; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=80,  # placeholder (no attention)
    d_ff=0,       # mamba blocks have no separate FFN
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
    tie_embeddings=True,
)
