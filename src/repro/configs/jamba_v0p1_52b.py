"""jamba-v0.1-52b [hybrid] — 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, MoE 16e top-2, mamba:attn 7:1 interleave (attention at layer
offset 7 of each period-8 block), MoE every 2 layers. Runs long_500k: the 4
attention layers use a 262k sliding window at 500k context.
[arXiv:2403.19887; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=14336,
    vocab_size=65536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    attn_period=8,
    attn_offset=7,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_groups=1,
    ssm_chunk=128,
    mlp_act="swiglu",
)
