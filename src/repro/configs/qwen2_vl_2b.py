"""qwen2-vl-2b [vlm] — 28L d_model=1536 12H (GQA kv=2) d_ff=8960
vocab=151936 — M-RoPE, dynamic resolution. Vision frontend is a STUB:
input_specs provides precomputed patch embeddings mixed into the token
stream plus (t,h,w) position ids for M-RoPE. [arXiv:2409.12191; hf]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-2b",
    family="vlm",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    head_dim=128,
    d_ff=8960,
    vocab_size=151936,
    mrope=True,
    rope_theta=1_000_000.0,
    mlp_act="swiglu",
    tie_embeddings=True,
    frontend="vision_stub",
)
