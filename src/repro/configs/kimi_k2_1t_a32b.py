"""kimi-k2-1t-a32b [moe] — 61L d_model=7168 64H (GQA kv=8) per-expert
d_ff=2048 vocab=163840, MoE 384 experts top-8, first layer dense —
trillion-param MoE. [arXiv:2501.kimi2; unverified]"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    head_dim=112,  # 7168/64
    d_ff=2048,     # per-expert FFN width
    vocab_size=163840,
    num_experts=384,
    experts_per_token=8,
    first_dense_layers=1,
    rope_theta=50_000.0,
    mlp_act="swiglu",
    param_dtype="bfloat16",  # 1T params: bf16 + sharded state
    fsdp_over_pod=True,
    opt_state_dtype="bfloat16",
)
