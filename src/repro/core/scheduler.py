"""Multi-tenant fleet scheduling (paper §3: right-size resources *per job*).

The production tf.data service multiplexes many concurrent jobs over one
shared worker fleet.  Giving every job a task on every worker (the seed
behavior) couples the tenants: one starving job inflates the fleet for
everyone, and a comfortable job can never release workers to a starving
one.  This module is the arbitration layer between them:

* Each job reports a **demand** — how many workers it currently wants —
  derived from its own consumer-observed stall aggregate
  (``client_stall``, the Cachew-style signal the feeders already export):
  a starving job bids for the workers its throughput deficit implies
  (``allocated / (1 - stall_frac)``, growth-capped per round); a sated
  job releases one worker per round; a job with no fresh signal holds;
  a brand-new job bids for the whole fleet and lets fairness trim it.

* ``FleetScheduler.plan`` arbitrates the bids with **weighted max-min
  fairness** (progressive water-filling): demands that fit inside their
  weighted fair share are granted in full, and the leftover capacity is
  re-divided among the still-hungry jobs by weight.  The result is the
  per-job worker *share* the dispatcher then realizes by granting and
  retiring tasks.

* The plan also reports the fleet-level imbalance — ``unmet`` (capacity
  a *starving* job wanted but could not get) and ``surplus`` (capacity
  nobody wants) — which is exactly what the two-level ``Autoscaler``
  consumes: per-job share adjustment first, global pool resize only when
  aggregate demand and fleet capacity disagree.

Pure policy, no I/O: the dispatcher owns the state, this module owns the
arithmetic, so allocation behavior is unit-testable without a deployment.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple


@dataclass
class SchedulerConfig:
    # consumer-observed stall fraction above which a job is starving and
    # bids for more workers (mirrors AutoscalerConfig.stall_out_threshold)
    stall_out_threshold: float = 0.05
    # below this the job is comfortably fed and releases one worker/round
    stall_in_threshold: float = 0.01
    # a starving job's bid may grow by at most this many workers per round
    # (damping: the stall signal lags the allocation by a heartbeat or two)
    max_grow_step: int = 2
    # grow fast, shrink patiently: a job must be CONTINUOUSLY sated this
    # long before releasing a worker.  The stall signal lags allocation
    # changes by the buffer-drain time (client queue + worker buffers), so
    # an eager shrinker collapses a job's share faster than the stall
    # feedback can push back; the patience window must outlast that lag.
    shrink_patience_s: float = 3.0
    # no schedulable job is squeezed below this many workers
    min_share: int = 1


@dataclass
class JobDemand:
    """One job's scheduling inputs, snapshotted by the dispatcher."""

    job_id: str
    weight: float = 1.0
    allocated: int = 0  # active tasks (live workers only)
    max_workers: int = 0  # 0 = unbounded
    stall_frac: Optional[float] = None  # fresh client_stall aggregate, or None


@dataclass
class FleetPlan:
    """Output of one scheduling round."""

    capacity: int
    shares: Dict[str, int]  # job_id -> granted worker share
    wants: Dict[str, int]  # job_id -> demanded workers (pre-arbitration)
    total_demand: int = 0
    unmet: int = 0  # starving demand the fleet could not satisfy
    surplus: int = 0  # fleet capacity no job wants
    starving: List[str] = field(default_factory=list)


def weighted_max_min(
    capacity: int, entries: List[Tuple[str, int, float]]
) -> Dict[str, int]:
    """Weighted max-min fair integer allocation (water-filling).

    ``entries`` is ``[(job_id, want, weight)]``.  Jobs whose demand fits
    inside their weighted fair share are granted in full; their leftover
    is re-divided among the rest by weight until nothing fits, then the
    remaining capacity is split by weight (largest-remainder rounding).
    Every job with a positive demand is guaranteed at least one worker
    whenever the fleet is large enough to allow it.
    """
    shares: Dict[str, int] = {jid: 0 for jid, _, _ in entries}
    if capacity <= 0:
        return shares
    demanding = [e for e in entries if e[1] > 0]
    if capacity < len(demanding):
        # degenerate fleet: fewer workers than tenants.  Proportional
        # splitting would hand some jobs share 0 by rounding, and WHICH
        # jobs would vary round to round (displaced jobs re-bid for the
        # whole fleet), tearing down and re-granting task sets forever.
        # Instead give one worker each to the `capacity` highest-weight
        # jobs (ties by id) — deterministic, so the same jobs win every
        # round and the rest wait for capacity.
        for jid, _, _ in sorted(demanding, key=lambda e: (-e[2], e[0]))[:capacity]:
            shares[jid] = 1
        return shares
    pending: Dict[str, Tuple[int, float]] = {
        jid: (want, max(1e-9, float(weight)))
        for jid, want, weight in entries
        if want > 0
    }
    left = capacity
    while left > 0 and pending:
        total_w = sum(w for _, w in pending.values())
        fitted = [
            jid for jid, (want, w) in pending.items() if want <= left * w / total_w
        ]
        if fitted:
            for jid in fitted:
                want, _ = pending.pop(jid)
                shares[jid] = want
                left -= want
            continue
        # every remaining demand exceeds its fair share: split by weight
        quota = {jid: left * w / total_w for jid, (_, w) in pending.items()}
        base = {jid: int(q) for jid, q in quota.items()}
        rem = left - sum(base.values())
        for jid in sorted(pending, key=lambda j: (-(quota[j] - base[j]), j)):
            if rem <= 0:
                break
            base[jid] += 1
            rem -= 1
        for jid in pending:
            shares[jid] = base[jid]
        pending.clear()
    # min-share guarantee: steal from the largest holder for any job the
    # rounding starved, while the fleet has a worker per demanding job
    demanding = [jid for jid, want, _ in entries if want > 0]
    if capacity >= len(demanding):
        for jid in sorted(j for j in demanding if shares[j] == 0):
            donor = max(shares, key=lambda j: (shares[j], j))
            if shares[donor] <= 1:
                break
            shares[donor] -= 1
            shares[jid] = 1
    return shares


class FleetScheduler:
    """Demand-driven weighted max-min fair worker allocation."""

    def __init__(self, config: Optional[SchedulerConfig] = None):
        self.config = config or SchedulerConfig()
        # job_id -> monotonic time the job's current sated streak began
        # (shrink-patience bookkeeping; pruned for jobs that disappear)
        self._sated_since: Dict[str, float] = {}

    def is_starving(self, d: JobDemand) -> bool:
        return (
            d.stall_frac is not None
            and d.stall_frac > self.config.stall_out_threshold
        )

    def desired_share(
        self, d: JobDemand, capacity: int, now: Optional[float] = None
    ) -> int:
        """How many workers one job bids for this round."""
        cfg = self.config
        now = time.monotonic() if now is None else now
        cap = capacity if d.max_workers <= 0 else min(d.max_workers, capacity)
        if d.allocated <= 0:
            # brand-new (or fully displaced) job: bid for everything and
            # let max-min fairness trim the bid to the job's fair share
            want = capacity
        elif d.stall_frac is None:
            want = d.allocated  # no fresh signal: hold
        elif d.stall_frac > cfg.stall_out_threshold:
            # throughput deficit: the consumer is fed (1 - stall) of the
            # time, so ~allocated / (1 - stall) workers would feed it
            self._sated_since.pop(d.job_id, None)
            deficit = math.ceil(d.allocated / max(0.05, 1.0 - d.stall_frac))
            want = min(d.allocated + cfg.max_grow_step, max(d.allocated + 1, deficit))
        elif d.stall_frac < cfg.stall_in_threshold:
            # comfortably fed: release one worker per full patience window
            since = self._sated_since.setdefault(d.job_id, now)
            if now - since >= cfg.shrink_patience_s:
                want = d.allocated - 1
                self._sated_since[d.job_id] = now  # restart the clock
            else:
                want = d.allocated
        else:
            self._sated_since.pop(d.job_id, None)
            want = d.allocated  # hysteresis band: hold
        return max(cfg.min_share, min(want, cap))

    def plan(
        self,
        capacity: int,
        demands: List[JobDemand],
        now: Optional[float] = None,
    ) -> FleetPlan:
        now = time.monotonic() if now is None else now
        live = {d.job_id for d in demands}
        for jid in [j for j in self._sated_since if j not in live]:
            del self._sated_since[jid]
        wants = {d.job_id: self.desired_share(d, capacity, now) for d in demands}
        shares = weighted_max_min(
            capacity, [(d.job_id, wants[d.job_id], d.weight) for d in demands]
        )
        starving = [d.job_id for d in demands if self.is_starving(d)]
        # unmet counts only STARVING jobs' trimmed bids: a comfortable job
        # holding fewer workers than it historically had is not a reason
        # to grow the fleet.  Exception: a job displaced to share 0 (a
        # degenerate fleet smaller than the tenant count) is starving by
        # construction whether or not its clients report stall — without
        # this, a share-0 job whose consumers never call report_feed_stall
        # blocks forever and the pool never grows to place it.
        unmet = sum(max(0, wants[j] - shares.get(j, 0)) for j in starving)
        unmet += sum(
            1
            for d in demands
            if wants[d.job_id] > 0
            and shares.get(d.job_id, 0) == 0
            and d.job_id not in starving
        )
        total = sum(wants.values())
        return FleetPlan(
            capacity=capacity,
            shares=shares,
            wants=wants,
            total_demand=total,
            unmet=unmet,
            surplus=max(0, capacity - total),
            starving=starving,
        )
