"""Deployment orchestration (the paper's Borg/Kubernetes role, §3.1).

``LocalOrchestrator`` spins up a dispatcher and a pool of workers (in-proc or
TCP transport), runs the failure-detection GC loop, supports scale-out /
scale-in (Autopilot's role), worker kill/restart (fault-injection for tests
and benchmarks), and dispatcher restart-from-journal.

Multi-tenant deployments (``scheduling=True``) add two surfaces the
two-level ``Autoscaler`` consumes: ``rebalance()`` (one fleet-scheduling
round — per-job worker shares, see ``core.scheduler``) and
``pick_removable()`` (drain-aware scale-in victim selection: never remove
a worker holding an unfinished snapshot stream or unconsumed coordinated
rounds while an idle worker exists).
"""
from __future__ import annotations

import logging
import os
import tempfile
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Set, Tuple, Type

logger = logging.getLogger(__name__)

from ..obs.registry import get_registry
from .dispatcher import CrashPoints, Dispatcher, StandbyDispatcher
from .protocol import new_id
from .transport import INPROC, Stub, TCPServer
from .worker import Worker


@dataclass
class ServiceHandle:
    dispatcher_address: str
    orchestrator: "LocalOrchestrator"


class LocalOrchestrator:
    def __init__(
        self,
        num_workers: int = 2,
        transport: str = "inproc",
        journal_path: Optional[str] = None,
        journal: bool = False,
        heartbeat_timeout: float = 2.0,
        worker_heartbeat_interval: float = 0.2,
        gc_interval: float = 0.5,
        worker_buffer_size: int = 8,
        cache_capacity: int = 16,
        overpartition: int = 4,
        snapshot_root: Optional[str] = None,
        autocache_config: Optional[Any] = None,
        scheduling: bool = False,
        scheduler_config: Optional[Any] = None,
        crash_points: Optional[CrashPoints] = None,
        lease_timeout: float = 1.0,
        replication_interval: float = 0.05,
        worker_processes: int = 0,
    ):
        self._transport = transport
        # worker_processes=N runs each worker's pipelines in an N-child
        # process pool (data.executors); 0 keeps the in-thread engine
        self._worker_processes = worker_processes
        if journal and journal_path is None:
            journal_path = os.path.join(
                tempfile.mkdtemp(prefix="repro-dispatcher-"), "journal.bin"
            )
        self._journal_path = journal_path
        self._snapshot_root = snapshot_root
        self._autocache_config = autocache_config
        self._scheduling = scheduling
        self._scheduler_config = scheduler_config
        self._hb_timeout = heartbeat_timeout
        self._worker_hb = worker_heartbeat_interval
        self._gc_interval = gc_interval
        self._worker_buffer = worker_buffer_size
        self._cache_capacity = cache_capacity
        self._overpartition = overpartition
        self._num_workers = num_workers

        self.dispatcher: Optional[Dispatcher] = None
        self.workers: List[Worker] = []
        self.dispatcher_address = ""
        self._dispatcher_name = new_id("dispatcher")
        self._tcp_dispatcher: Optional[TCPServer] = None
        self._stop_gc = threading.Event()
        self._gc_thread: Optional[threading.Thread] = None
        # HA: chaos crash injection + hot-standby failover
        self._crash_points = crash_points
        self._lease_timeout = lease_timeout
        self._replication_interval = replication_interval
        self.standby: Optional[StandbyDispatcher] = None
        self._standby_idx = 0
        # Log-first-instance: background/cleanup paths swallow expected
        # failures (worker mid-shutdown, dispatcher already gone) but each
        # distinct (context, exception type) is logged once so a systemic
        # fault is visible instead of silently eaten in a loop.
        self._logged_errors: Set[Tuple[str, Type[BaseException]]] = set()

    def _note_error(self, context: str, exc: BaseException) -> None:
        get_registry().counter(
            "orchestrator_errors_total",
            "swallowed background errors in the orchestrator, by context",
        ).labels(context=context, kind=type(exc).__name__).inc()
        key = (context, type(exc))
        if key in self._logged_errors:
            return
        self._logged_errors.add(key)
        logger.warning(
            "orchestrator: %s failed with %r (suppressing repeats)", context, exc
        )

    # ------------------------------------------------------------------
    def start(self) -> ServiceHandle:
        self._start_dispatcher()
        for _ in range(self._num_workers):
            self.add_worker()
        self._gc_thread = threading.Thread(target=self._gc_loop, daemon=True)
        self._gc_thread.start()
        return ServiceHandle(self.dispatcher_address, self)

    def _start_dispatcher(self) -> None:
        self.dispatcher = Dispatcher(
            journal_path=self._journal_path,
            heartbeat_timeout=self._hb_timeout,
            overpartition=self._overpartition,
            snapshot_root=self._snapshot_root,
            autocache_config=self._autocache_config,
            scheduling=self._scheduling,
            scheduler_config=self._scheduler_config,
            crash_points=self._crash_points,
        )
        if self._crash_points is not None:
            self._crash_points.on_fire = self._on_dispatcher_crash
        if self._transport == "tcp":
            self._tcp_dispatcher = TCPServer(self.dispatcher).start()
            self.dispatcher_address = self._tcp_dispatcher.address
        elif self._transport == "grpc":
            from .transport import GrpcServer

            self._tcp_dispatcher = GrpcServer(self.dispatcher).start()
            self.dispatcher_address = self._tcp_dispatcher.address
        else:
            self.dispatcher_address = INPROC.bind(self._dispatcher_name, self.dispatcher)

    def _gc_loop(self) -> None:
        while not self._stop_gc.wait(self._gc_interval):
            if self.dispatcher is not None:
                self.dispatcher.check_workers()

    # ------------------------------------------------------------------
    # Worker pool management (Autopilot-style horizontal scaling)
    # ------------------------------------------------------------------
    def add_worker(
        self,
        tags: Optional[Dict[str, Any]] = None,
        worker_processes: Optional[int] = None,
        host_key: Optional[str] = None,
    ) -> Worker:
        # host_key overrides the advertised co-location identity — lets a
        # deployment (or test) model a worker on another host, which clients
        # must reach over tcp:// even when it actually runs in this process.
        w = Worker(
            dispatcher_address=self.dispatcher_address,
            transport=self._transport,
            buffer_size=self._worker_buffer,
            heartbeat_interval=self._worker_hb,
            cache_capacity=self._cache_capacity,
            tags=tags,
            worker_processes=(
                self._worker_processes
                if worker_processes is None
                else worker_processes
            ),
            host_key=host_key,
        ).start()
        try:
            # Readiness probe: a worker that answers ping has bound its
            # transport, so bring-up failures surface here instead of as
            # timeouts in the first data fetch.
            Stub(w.address).call("ping")
        except Exception as e:
            self._note_error(f"worker {w.worker_id} bring-up ping", e)
        self.workers.append(w)
        return w

    def scale_to(self, n: int) -> None:
        while len([w for w in self.workers if not w._stopping.is_set()]) < n:
            self.add_worker()
        live = [w for w in self.workers if not w._stopping.is_set()]
        for w in live[n:]:
            self.remove_worker(w)

    def remove_worker(self, worker: Worker) -> None:
        worker.stop()
        if self.dispatcher is not None:
            try:
                Stub(self.dispatcher_address).call(
                    "remove_worker", worker_id=worker.worker_id
                )
            except Exception as e:
                # Expected when the dispatcher is mid-restart; its GC sweep
                # reclaims the worker's tasks anyway.
                self._note_error("remove_worker deregistration", e)

    def kill_worker(self, index: int = 0) -> Worker:
        """Fault injection: crash a worker without notifying the dispatcher."""
        live = [w for w in self.workers if not w._stopping.is_set()]
        w = live[index]
        w.fail()
        return w

    @property
    def live_workers(self) -> List[Worker]:
        return [w for w in self.workers if not w._stopping.is_set()]

    def rebalance(self) -> Optional[Dict[str, Any]]:
        """One fleet-scheduling round (no-op None unless the deployment was
        created with ``scheduling=True``).  The two-level Autoscaler calls
        this every step; tests and benchmarks may drive it directly."""
        if self.dispatcher is None:
            return None
        return self.dispatcher.rebalance()

    def pick_removable(self) -> Optional[Worker]:
        """Drain-aware scale-in victim selection.

        Returns the live worker that is cheapest to remove: no unfinished
        snapshot streams, no pending (materialized-but-unconsumed)
        coordinated rounds, lowest buffer occupancy.  Returns None when no
        live worker is currently drainable — the caller should skip
        scale-in this round rather than kill a busy worker.
        """
        candidates = []
        for w in self.live_workers:
            try:
                ds = w.drain_stats()
            except Exception as e:
                # Worker mid-shutdown: not a candidate this round.
                self._note_error("drain_stats during pick_removable", e)
                continue
            if ds["active_snapshot_streams"] or ds["pending_coordinated_rounds"]:
                continue
            candidates.append((ds["buffer_occupancy"], w.worker_id, w))
        if not candidates:
            return None
        return min(candidates)[2]

    # ------------------------------------------------------------------
    # Dispatcher fault injection / recovery (paper §3.4)
    # ------------------------------------------------------------------
    def kill_dispatcher(self) -> None:
        if self._transport in ("tcp", "grpc") and self._tcp_dispatcher is not None:
            self._tcp_dispatcher.stop()
            self._tcp_dispatcher = None
        else:
            INPROC.unbind(self._dispatcher_name)
        if self.dispatcher is not None:
            self.dispatcher.close()
            self.dispatcher = None

    def crash_dispatcher(self) -> None:
        """HA-path crash: the dispatcher stops answering but its journal
        file handle stays open (a real dead process just stops writing) —
        ``kill_dispatcher`` by contrast closes the journal for a clean
        restart.  Used directly by tests; injected crash points route here
        via ``_on_dispatcher_crash``."""
        if self.dispatcher is not None:
            self.dispatcher.fail()
        self._unbind_dispatcher()

    def _on_dispatcher_crash(self, point: str) -> None:
        """CrashPoints.on_fire callback: runs ON an RPC handler thread, so
        the transport teardown happens in a side thread (a TCP server
        cannot shut itself down from inside one of its own handlers)."""
        if self.dispatcher is not None:
            self.dispatcher.fail()
        threading.Thread(target=self._unbind_dispatcher, daemon=True).start()

    def _unbind_dispatcher(self) -> None:
        if self._transport in ("tcp", "grpc") and self._tcp_dispatcher is not None:
            self._tcp_dispatcher.stop()
            self._tcp_dispatcher = None
        else:
            INPROC.unbind(self._dispatcher_name)

    # ------------------------------------------------------------------
    # Hot-standby failover (dispatcher HA)
    # ------------------------------------------------------------------
    def arm_standby(self) -> StandbyDispatcher:
        """Start a hot standby tailing the primary's journal.

        The standby replays the replication stream into its own state (and
        its own journal file); when the primary stops answering for longer
        than ``lease_timeout`` it promotes itself and the orchestrator
        rebinds the service address to it — clients and workers reconnect
        through their existing backoff paths.
        """
        assert self._journal_path, "standby failover requires a journal"
        self._standby_idx += 1
        standby_path = f"{self._journal_path}.standby{self._standby_idx}"
        self.standby = StandbyDispatcher(
            journal_path=standby_path,
            primary_address=self.dispatcher_address,
            primary_journal_path=self._journal_path,
            lease_timeout=self._lease_timeout,
            poll_interval=self._replication_interval,
            on_promote=self._adopt_standby,
            heartbeat_timeout=self._hb_timeout,
            overpartition=self._overpartition,
            snapshot_root=self._snapshot_root,
            autocache_config=self._autocache_config,
            scheduling=self._scheduling,
            scheduler_config=self._scheduler_config,
        ).start()
        return self.standby

    def _adopt_standby(self, standby: StandbyDispatcher) -> None:
        """on_promote callback: rebind the service address to the promoted
        standby.  From here on ITS journal is the WAL of record (future
        restarts and standbys chain off it)."""
        self.dispatcher = standby.dispatcher
        self._journal_path = standby.journal_path
        if self._transport == "tcp":
            host_port = self.dispatcher_address[len("tcp://") :]
            host, port = host_port.rsplit(":", 1)
            deadline = time.monotonic() + 5.0
            while True:
                try:
                    self._tcp_dispatcher = TCPServer(
                        self.dispatcher, host=host, port=int(port)
                    ).start()
                    break
                except OSError:
                    # the crashed primary's socket may still be closing
                    if time.monotonic() > deadline:
                        raise
                    time.sleep(0.05)
        elif self._transport == "grpc":
            from .transport import GrpcServer

            host_port = self.dispatcher_address[len("grpc://") :]
            host, port = host_port.rsplit(":", 1)
            self._tcp_dispatcher = GrpcServer(
                self.dispatcher, host=host, port=int(port)
            ).start()
        else:
            INPROC.bind(self._dispatcher_name, self.dispatcher)

    def wait_for_failover(self, timeout: float = 10.0) -> bool:
        """Block until the armed standby has promoted itself."""
        assert self.standby is not None, "arm_standby first"
        return self.standby.promoted.wait(timeout)

    def restart_dispatcher(self) -> None:
        """Restart from the write-ahead journal at the SAME address (workers
        and clients reconnect transparently)."""
        assert self.dispatcher is None, "kill_dispatcher first"
        self.dispatcher = Dispatcher(
            journal_path=self._journal_path,
            heartbeat_timeout=self._hb_timeout,
            overpartition=self._overpartition,
            snapshot_root=self._snapshot_root,
            autocache_config=self._autocache_config,
            scheduling=self._scheduling,
            scheduler_config=self._scheduler_config,
        )
        if self._transport == "tcp":
            # rebind on a fresh port is not transparent; for TCP tests use
            # inproc-equivalent restart semantics by reusing the port.
            host_port = self.dispatcher_address[len("tcp://") :]
            host, port = host_port.rsplit(":", 1)
            self._tcp_dispatcher = TCPServer(
                self.dispatcher, host=host, port=int(port)
            ).start()
        else:
            INPROC.bind(self._dispatcher_name, self.dispatcher)

    # ------------------------------------------------------------------
    # Admin / observability surface (thin wrappers over dispatcher RPCs)
    # ------------------------------------------------------------------
    def stats(self) -> Dict[str, Any]:
        return Stub(self.dispatcher_address).call("stats")

    def list_workers(self) -> Dict[str, Any]:
        """Dispatcher-side view of registered workers (id, address, tags,
        liveness) — the admin counterpart of ``self.workers``, which only
        knows about workers THIS orchestrator started."""
        return Stub(self.dispatcher_address).call("list_workers")

    def metrics_dump(self) -> Dict[str, Any]:
        """Dispatcher-side metrics snapshot (registry families, per-job
        stats, worker addresses, trace-buffer depth) — what the fleet
        dashboard (``python -m repro.obs.top``) scrapes each interval."""
        return Stub(self.dispatcher_address).call("metrics_dump")

    def retire_task(self, task_id: str) -> Dict[str, Any]:
        """Administratively retire one task through the journaled path; the
        owning worker prunes its runner on its next heartbeat."""
        return Stub(self.dispatcher_address).call("retire_task", task_id=task_id)

    def stop(self) -> None:
        self._stop_gc.set()
        if self.standby is not None:
            self.standby.stop()
        for w in self.workers:
            w.stop()
        self.kill_dispatcher()


def start_service(num_workers: int = 2, **kw: Any) -> ServiceHandle:
    """One-call deployment for examples/tests."""
    return LocalOrchestrator(num_workers=num_workers, **kw).start()
