"""tf.data-service client (paper §3.1): fetches preprocessed batches.

Two read modes:

* **parallel fetch** (default): a *window* of ``fetch_window`` fetcher
  threads per worker task, each with its own connection, keeps that many
  ``get_elements`` requests outstanding against the worker — transfer
  overlaps with worker-side production and client-side decode, and each RPC
  drains up to ``max_batch`` elements, amortizing per-RPC overhead.  Order
  across (and now within) workers is unspecified — the paper's
  relaxed-visitation stance makes this fine.  Workers that predate the
  batched protocol are detected via the unknown-method error and served by
  the single-element ``get_element`` fallback.
* **coordinated reads** (``num_consumers > 0``): strict round-robin — for
  training step r every consumer fetches its ``consumer_index`` slot of round
  r from worker ``sorted_workers[r % n]``, guaranteeing same-bucket batches
  across all clients in the step (§3.6).  Round identity is per-element, so
  this path always uses single-element fetch.

Compression is negotiated per job: the client requests a codec by name (or
``"auto"``); the dispatcher resolves it against the deployment's codec
registry (``core.codecs``) and the agreed name is applied worker-side.
Frames are tag-prefixed, so decode never needs out-of-band codec state.

The client records stall time (time blocked waiting for data): the paper's
"input-bound" diagnosis is ``stall_time / wall_time``.
"""
from __future__ import annotations

import queue
import socket
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

from ..data.elements import (
    Element,
    copy_element,
    decode_element,
    decode_elements,
)
from ..data.graph import Graph
from ..obs.registry import MetricsRegistry
from ..obs.tracing import TraceContext, Tracer
from .protocol import (
    DEFAULT_FETCH_WINDOW,
    DEFAULT_MAX_BATCH,
    DEFAULT_POLL_TIMEOUT,
    FetchStatus,
    new_id,
)
from .codecs import available_codecs
from .shm_ring import ShmRing
from .transport import Backoff, Stub, TransportError, decompress


class ClientMetrics:
    """Session counters, now backed by a :class:`MetricsRegistry`.

    The old dataclass was mutated with bare ``+=`` from every fetcher
    thread in the window — read-modify-writes that lose updates under
    thread switches.  Mutation now goes through :meth:`add` (per-series
    locked, exact); reads stay attribute-style (``metrics.batches``) via
    ``__getattr__`` so callers and tests are unchanged, and the same
    series surface in the registry scraped by ``metrics_dump`` dashboards.
    """

    _FIELDS = (
        "batches",
        "bytes_received",
        "stall_time",
        "fetch_time",
        "rpcs",
        "retries",
        "fallback_tasks",  # tasks demoted to the single-element v1 path
        "shm_tasks",  # tasks that negotiated a shm:// ring data plane
        "shm_batches",  # OK responses served via a ring descriptor
    )

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._series = {
            name: self.registry.counter(f"client_{name}", "client session counter")
            for name in self._FIELDS
        }

    def add(self, **deltas: float) -> None:
        for name, delta in deltas.items():
            self._series[name].add(delta)

    def __getattr__(self, name: str):
        series = self.__dict__.get("_series") or {}
        if name in series:
            return series[name].value
        raise AttributeError(name)

    def snapshot(self) -> Dict[str, float]:
        return {name: s.value for name, s in self._series.items()}


@dataclass
class _FetchError:
    """Queued in place of an element to surface a fatal decode error."""

    task_id: str
    error: Exception


@dataclass
class _ShmRelease:
    """Queued AFTER a zero-copy batch: the consumer loop releases the ring
    slot once it has advanced past every element borrowed from it."""

    ring: ShmRing
    slot: int


@dataclass
class _TaskHandle:
    task_id: str
    job_id: str
    worker_id: str
    worker_address: str
    stub: Stub
    done: bool = False
    failed: bool = False
    batched: bool = True  # flips False when the worker lacks get_elements
    poisoned: bool = False  # undecodable responses: never resurrect
    # shm:// data-plane negotiation state (per task handle; the fetch
    # window's threads share the ring — slot leases are per-descriptor)
    shm_state: str = "unknown"  # unknown | active | off
    shm_channel: str = ""
    shm_ring: Optional[ShmRing] = None
    shm_lock: threading.Lock = field(default_factory=threading.Lock)


class DataServiceClient:
    """One iteration session over a service-backed dataset.

    Data-plane knobs (parallel-fetch mode):

    * ``buffer_size``  — capacity of the client-side element queue the
      training loop consumes from.
    * ``fetch_window`` — outstanding ``get_elements`` requests kept in
      flight per worker task; each slot is a thread with its own
      connection, so transfer pipelines with decode and production.
    * ``max_batch``    — maximum elements a worker may return per RPC.
    * ``compression``  — requested codec name (``None``/``"none"``,
      ``"zlib"``, ``"lz4"``, or ``"auto"``); the dispatcher negotiates the
      codec actually applied (``negotiated_compression`` after iteration
      starts) against what the deployment has available.

    Tasks on workers that predate the batched protocol automatically fall
    back to one-element-per-RPC ``get_element`` (``metrics.fallback_tasks``
    counts them); coordinated reads always use the single-element path
    because rounds are element-indexed.
    """

    _END = object()

    def __init__(
        self,
        dispatcher_address: str,
        graph: Graph,
        processing_mode: str = "off",
        job_name: Optional[str] = None,
        num_consumers: int = 0,
        consumer_index: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        target_workers: str = "any",
        max_workers: int = 0,
        weight: float = 1.0,
        resume_offsets: bool = False,
        autocache: bool = False,
        buffer_size: int = 8,
        fetch_window: int = DEFAULT_FETCH_WINDOW,
        max_batch: int = DEFAULT_MAX_BATCH,
        prefer_batched: bool = True,
        heartbeat_interval: float = 0.3,
        optimize: bool = True,
        trace_sample: float = 0.0,
        shm: bool = True,
        zero_copy: bool = False,
        host_key: Optional[str] = None,
    ):
        self.client_id = new_id("client")
        self.metrics = ClientMetrics()
        # trace_sample > 0 mints a session-level root trace at registration
        # (journaled dispatcher-side with the job) and samples that fraction
        # of element-batch fetches into cross-process spans
        self.tracer = Tracer(
            process=f"client:{self.client_id}", sample_rate=trace_sample
        )
        self.trace_root: Optional[TraceContext] = None
        self._dispatcher = Stub(dispatcher_address)
        # the RAW graph is registered; the dispatcher optimizes it once so
        # identical pipelines from different jobs share a dataset_id (§3.5)
        self._graph = graph
        self._mode = processing_mode
        self._job_name = job_name
        self._m = num_consumers
        self._consumer_index = consumer_index
        self._sharing = sharing
        self._compression = compression
        self._target_workers = target_workers
        self._max_workers = max_workers
        self._weight = weight
        self._resume_offsets = resume_offsets
        self._autocache = autocache
        self._buffer_size = buffer_size
        self._fetch_window = max(1, fetch_window)
        self._max_batch = max(1, max_batch)
        # False forces the v1 one-element-per-RPC path from the start:
        # benchmark baseline and mixed-version deployment drills.
        self._prefer_batched = prefer_batched
        self._hb_interval = heartbeat_interval
        # shm:// negotiation: enabled by default; rings are only attached to
        # workers whose ping() host matches ours AND whose control channel is
        # a real socket (inproc workers are already zero-copy).
        self._shm_enabled = shm
        # zero_copy=True hands out decoded views that BORROW the ring slot
        # ("valid until the next element") instead of copying out — the
        # DeviceFeeder path, where every element is device_put immediately.
        self._zero_copy = zero_copy
        self._host_key = host_key or socket.gethostname()
        self.negotiated_compression: Optional[str] = None
        # the dispatcher's autocache verdict for this job, once registered:
        # "compute" | "write_through" | "read" | None (autocache off)
        self.autocache_decision: Optional[str] = None

        # latest feed-side stall window (set by repro.feed.DeviceFeeder via
        # report_feed_stall); forwarded on every dispatcher heartbeat as the
        # autoscaler's client-latency signal
        self._feed_stats: Optional[Dict[str, float]] = None

        self._tasks: Dict[str, _TaskHandle] = {}
        self._tasks_lock = threading.Lock()
        self._active_fetchers = 0  # window threads still running (all tasks)
        self._queue: "queue.Queue[Any]" = queue.Queue(maxsize=max(2, buffer_size))
        self._job_finished = threading.Event()
        self._closed = threading.Event()
        self._fetchers: Dict[str, List[threading.Thread]] = {}
        self._job_id = ""

    # ------------------------------------------------------------------
    # Session setup
    # ------------------------------------------------------------------
    def _register(self) -> None:
        resp = self._dispatcher.call(
            "get_or_register_dataset", graph_bytes=self._graph.to_bytes()
        )
        self.trace_root = self.tracer.start_trace()
        kw: Dict[str, Any] = dict(
            dataset_id=resp["dataset_id"],
            job_name=self._job_name,
            policy=self._mode,
            num_consumers=self._m,
            sharing=self._sharing,
            compression=self._compression,
            max_workers=self._max_workers,
            weight=self._weight,
            resume_offsets=self._resume_offsets,
            client_id=self.client_id,
            client_codecs=available_codecs(),  # negotiation: what WE decode
            autocache=self._autocache,
        )
        if self.trace_root is not None:
            # the job-level root context: journaled with job_created, so a
            # promoted standby keeps stamping spans with the same trace_id
            kw["trace"] = self.trace_root.to_wire()
            # zero-duration root marker, recorded BEFORE anything downstream
            # can parent to it, so every span's parent chain resolves even
            # if the dispatcher crashes mid-registration
            self.tracer.record(
                "client.session",
                self.trace_root,
                time.time(),
                0.0,
                client_id=self.client_id,
            )
        view = self._dispatcher.call("get_or_create_job", **kw)
        self._job_id = view["job_id"]
        self.negotiated_compression = view.get("compression")
        self.autocache_decision = view.get("autocache")
        self._sync_tasks(view)

    def _sync_tasks(self, view: Dict[str, Any]) -> None:
        with self._tasks_lock:
            seen = set()
            for t in view["tasks"]:
                seen.add(t["task_id"])
                h = self._tasks.get(t["task_id"])
                if h is None:
                    h = self._tasks[t["task_id"]] = _TaskHandle(
                        task_id=t["task_id"],
                        job_id=t["job_id"],
                        worker_id=t["worker_id"],
                        worker_address=t["worker_address"],
                        stub=Stub(t["worker_address"]),
                        batched=self._prefer_batched,
                    )
                    if self._m == 0 and not self._closed.is_set():
                        self._spawn_fetcher(h)
                elif h.failed and not h.done and not h.poisoned:
                    # the dispatcher re-listed a task we gave up on (e.g. the
                    # transient window right after a dispatcher restart when
                    # workers had not yet re-registered): resurrect it.
                    # Poisoned tasks (undecodable responses from a healthy
                    # worker) stay dead — resurrecting would drain-and-drop
                    # the worker's elements in an endless loop.
                    h.failed = False
                    if self._m == 0 and not self._closed.is_set():
                        self._spawn_fetcher(h)
            # tasks whose worker died are dropped by the dispatcher view
            for tid, h in self._tasks.items():
                if tid not in seen and not h.done:
                    h.failed = True
            if view.get("finished"):
                self._job_finished.set()

    def report_feed_stall(self, stats: Dict[str, float]) -> None:
        """Feed-side stall hook (``repro.feed``): record the consumer's
        latest stall window; the heartbeat loop forwards it so the
        dispatcher (and through it the autoscaler) sees what the
        *accelerator* observes, not just worker buffer occupancy."""
        self._feed_stats = dict(stats)

    def _heartbeat_loop(self) -> None:
        backoff = Backoff(
            base=self._hb_interval, cap=max(1.0, 4 * self._hb_interval)
        )
        delay = self._hb_interval
        while not self._closed.wait(delay):
            try:
                kw: Dict[str, Any] = dict(
                    job_id=self._job_id, client_id=self.client_id
                )
                # report-once: each stall window is forwarded on ONE
                # heartbeat, so a consumer that stops stepping stops
                # reporting and the dispatcher's TTL ages the job's
                # aggregate out — re-sending the last window forever would
                # pin a stale "starving" signal on the autoscaler
                stall_stats, self._feed_stats = self._feed_stats, None
                if stall_stats is not None:
                    kw["stall_stats"] = stall_stats
                hbctx = (
                    self.trace_root.child()
                    if self.trace_root is not None
                    else None
                )
                if hbctx is not None:
                    kw["trace"] = hbctx.to_wire()
                wall, t0 = time.time(), time.perf_counter()
                try:
                    view = self._dispatcher.call("client_heartbeat", **kw)
                finally:
                    # record even when the call dies mid-flight: the
                    # dispatcher may have recorded its child span before
                    # crashing, and that child's parent must exist
                    if hbctx is not None:
                        self.tracer.record(
                            "client.heartbeat",
                            hbctx,
                            wall,
                            time.perf_counter() - t0,
                            parent_id=self.trace_root.span_id,
                            job_id=self._job_id,
                        )
                self._sync_tasks(view)
            except TransportError:
                # dispatcher down: keep consuming from workers (§3.4);
                # jittered backoff avoids stampeding a promoted standby
                delay = backoff.next_delay()
                continue
            backoff.reset()
            delay = self._hb_interval
            if self._job_finished.is_set():
                return

    # ------------------------------------------------------------------
    # Parallel-fetch mode (pipelined, batched)
    # ------------------------------------------------------------------
    def _spawn_fetcher(self, handle: _TaskHandle) -> None:
        """Start ``fetch_window`` fetcher threads for one task.

        Each thread owns a private ``Stub`` (its own connection over
        ``tcp://``/``grpc://``), so the window's requests genuinely overlap
        on the wire instead of serializing on one socket.
        """
        threads = []
        for _ in range(self._fetch_window):
            stub = Stub(handle.worker_address)
            th = threading.Thread(
                target=self._fetch_run, args=(handle, stub), daemon=True
            )
            threads.append(th)
            self._active_fetchers += 1  # caller holds _tasks_lock
            th.start()
        self._fetchers[handle.task_id] = threads

    def _fetch_run(self, handle: _TaskHandle, stub: Stub) -> None:
        """Thread body: fetch loop + completion accounting.

        The END sentinel may only be enqueued once NO fetcher thread is
        still running: with ``fetch_window > 1`` a sibling thread can reach
        END_OF_TASK while this thread still holds decoded elements it has
        not enqueued yet — finishing on task state alone would drop them.
        """
        try:
            self._fetch_loop(handle, stub)
        finally:
            with self._tasks_lock:
                self._active_fetchers -= 1
            self._maybe_finish()

    def _negotiate_shm(self, handle: _TaskHandle, stub: Stub) -> None:
        """Decide the task's data plane ONCE per handle (first fetcher wins).

        shm:// is used only when (a) this session enables it, (b) the
        worker's control channel is a real socket (inproc is already
        zero-copy), and (c) the worker's advertised host matches ours.
        Anything going wrong — old worker without the RPC, attach refusal,
        segment unreachable — leaves the handle on the inline data plane;
        negotiation never fails a fetch.
        """
        with handle.shm_lock:
            if handle.shm_state != "unknown":
                return
            handle.shm_state = "off"
            if not self._shm_enabled or self._m > 0:
                return
            if handle.worker_address.startswith("inproc://"):
                return
            try:
                pong = stub.call("ping")
                if not pong.get("shm") or pong.get("host") != self._host_key:
                    return
                resp = stub.call("shm_attach")
                if not resp.get("ok"):
                    return
                ring = ShmRing.attach(resp["segment"])
            except Exception:
                return  # any failure: stay on the inline plane
            handle.shm_ring = ring
            handle.shm_channel = resp["channel"]
            handle.shm_state = "active"
            self.metrics.add(shm_tasks=1)

    def _fetch_loop(self, handle: _TaskHandle, stub: Stub) -> None:
        """One slot of the task's prefetch window.

        Prefers the batched ``get_elements`` RPC; demotes the whole task to
        the single-element v1 path when the worker reports an unknown
        method.  A transport failure marks the task failed — the dispatcher
        notices the dead worker and re-lists tasks via heartbeat (worker
        churn also tears the shm ring down with the handle: the replacement
        task renegotiates from scratch, so shm:// degrades to tcp://
        mid-job without consumer-visible effect).
        """
        self._negotiate_shm(handle, stub)
        backoff = 0.005
        while not self._closed.is_set() and not handle.done and not handle.failed:
            # per-element-batch sampling decision: unsampled fetches carry
            # no trace key at all, keeping the hot-path payload unchanged
            ctx = (
                self.trace_root.child()
                if self.trace_root is not None and self.tracer.should_sample()
                else None
            )
            try:
                wall = time.time() if ctx is not None else 0.0
                t0 = time.perf_counter()
                try:
                    kw: Dict[str, Any] = dict(
                        task_id=handle.task_id, job_id=self._job_id
                    )
                    if ctx is not None:
                        kw["trace"] = ctx.to_wire()
                    if handle.batched:
                        if handle.shm_state == "active":
                            kw["shm_channel"] = handle.shm_channel
                        resp = stub.call(
                            "get_elements",
                            max_batch=self._max_batch,
                            timeout=DEFAULT_POLL_TIMEOUT,  # worker long-polls
                            **kw,
                        )
                    else:
                        resp = stub.call("get_element", **kw)
                finally:
                    # span recorded even on failure: the worker may have
                    # recorded children before the response was lost
                    if ctx is not None:
                        self.tracer.record(
                            "client.fetch",
                            ctx,
                            wall,
                            time.perf_counter() - t0,
                            parent_id=self.trace_root.span_id,
                            task_id=handle.task_id,
                        )
                self.metrics.add(
                    fetch_time=time.perf_counter() - t0, rpcs=1
                )
            except (TransportError, ValueError) as e:
                # ValueError surfaces directly over inproc://; TransportError
                # wraps the remote repr over tcp:// and grpc://.
                if handle.batched and "unknown method get_elements" in str(e):
                    with self._tasks_lock:  # dedup across window threads
                        if handle.batched:
                            handle.batched = False
                            self.metrics.add(fallback_tasks=1)
                    continue
                handle.failed = True  # worker died; dispatcher will notice
                break
            status = resp["status"]
            if status == FetchStatus.OK.value:
                backoff = 0.005
                try:
                    with self.tracer.span(
                        "client.decode", ctx, task_id=handle.task_id
                    ):
                        elems = self._decode_batch(resp, handle)
                except Exception as e:
                    # corrupt/undecodable frame (e.g. codec tag this process
                    # cannot handle): poison the task — permanently failed,
                    # never resurrected — and surface the error to the
                    # consumer instead of dying silently.
                    handle.poisoned = True
                    handle.failed = True
                    self._enqueue(_FetchError(handle.task_id, e))
                    break
                for elem in elems:
                    self._enqueue(elem)
            elif status == FetchStatus.PENDING.value:
                self.metrics.add(retries=1)
                time.sleep(backoff)
                # batched calls already long-polled worker-side, so PENDING
                # means "genuinely dry" — keep the client-side pause short.
                backoff = min(backoff * 2, 0.02 if handle.batched else 0.1)
            else:  # END_OF_TASK
                handle.done = True

    def _decode(self, resp: Dict[str, Any]) -> Element:
        """Decode a single-element (v1) response."""
        if "element_compressed" in resp:
            elem = decode_element(decompress(resp["element_compressed"]))
        else:
            elem = resp["element"]
        self.metrics.add(bytes_received=resp.get("nbytes", 0))
        return elem

    def _decode_batch(
        self, resp: Dict[str, Any], handle: Optional[_TaskHandle] = None
    ) -> List[Any]:
        """Decode a batched (v2) OR single-element (v1) OK response."""
        if (
            "shm_slot" in resp
            and handle is not None
            and handle.shm_ring is not None
        ):
            return self._decode_shm(resp, handle)
        if "batch_compressed" in resp:
            elems = decode_elements(decompress(resp["batch_compressed"]))
        elif "elements" in resp:
            elems = resp["elements"]
        else:
            return [self._decode(resp)]
        self.metrics.add(bytes_received=resp.get("nbytes", 0))
        return elems

    def _decode_shm(
        self, resp: Dict[str, Any], handle: _TaskHandle
    ) -> List[Any]:
        """Resolve a ring descriptor into elements.

        Default: decode views out of the slot, deep-copy every element, and
        release the lease immediately — callers can hold elements as long as
        they like.  ``zero_copy=True``: the decoded arrays BORROW the slot
        (read-only, no copy) and a ``_ShmRelease`` marker queued after the
        batch frees the lease once the consumer has moved past it.
        Compressed frames always copy (decompression materializes anyway).
        """
        ring = handle.shm_ring
        slot = resp["shm_slot"]
        view = ring.payload(slot, resp["shm_len"], resp.get("shm_seq"))
        self.metrics.add(bytes_received=resp.get("nbytes", 0), shm_batches=1)
        if resp.get("shm_codec"):
            data = bytes(view)
            ring.release(slot)
            return decode_elements(decompress(data))
        if self._zero_copy:
            elems: List[Any] = list(decode_elements(view))
            elems.append(_ShmRelease(ring, slot))
            return elems
        try:
            return [copy_element(e) for e in decode_elements(view)]
        finally:
            ring.release(slot)

    def _enqueue(self, elem: Element) -> None:
        while not self._closed.is_set():
            try:
                self._queue.put(elem, timeout=0.1)
                return
            except queue.Full:
                continue

    def _maybe_finish(self) -> None:
        with self._tasks_lock:
            all_done = (
                self._tasks
                and self._active_fetchers == 0
                and all(h.done or h.failed for h in self._tasks.values())
            )
        if all_done and self._job_finished.is_set():
            try:
                self._queue.put_nowait(self._END)
            except queue.Full:
                # consumer will re-check completion on queue timeout
                pass

    # ------------------------------------------------------------------
    # Iteration
    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Element]:
        self._register()
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        hb.start()
        try:
            if self._m > 0:
                yield from self._iter_coordinated()
            else:
                yield from self._iter_parallel()
        finally:
            self.close()

    def _iter_parallel(self) -> Iterator[Element]:
        while True:
            t0 = time.perf_counter()
            try:
                item = self._queue.get(timeout=0.2)
            except queue.Empty:
                self.metrics.add(stall_time=time.perf_counter() - t0)
                with self._tasks_lock:
                    # fetcher threads may still hold decoded elements after
                    # their task flips done — wait for them to exit too
                    done = (
                        self._tasks
                        and self._active_fetchers == 0
                        and all(h.done or h.failed for h in self._tasks.values())
                    )
                if done and self._job_finished.is_set() and self._queue.empty():
                    return
                continue
            self.metrics.add(stall_time=time.perf_counter() - t0)
            if item is self._END:
                return
            if isinstance(item, _ShmRelease):
                # consumer has advanced past every element of the zero-copy
                # batch that borrowed this slot: lease goes back to the worker
                item.ring.release(item.slot)
                continue
            if isinstance(item, _FetchError):
                raise RuntimeError(
                    f"task {item.task_id}: undecodable response "
                    f"({item.error!r}) — client/worker codec registries "
                    f"likely disagree"
                ) from item.error
            self.metrics.add(batches=1)
            yield item

    def _iter_coordinated(self) -> Iterator[Element]:
        """Round-robin over workers; all consumers see same-bucket rounds."""
        round_index = 0
        backoff = 0.005
        while not self._closed.is_set():
            with self._tasks_lock:
                live = sorted(
                    (h for h in self._tasks.values() if not h.failed and not h.done),
                    key=lambda h: h.worker_id,
                )
            if not live:
                if self._job_finished.is_set():
                    return
                time.sleep(0.02)
                continue
            handle = live[round_index % len(live)]
            ctx = (
                self.trace_root.child()
                if self.trace_root is not None and self.tracer.should_sample()
                else None
            )
            kw: Dict[str, Any] = dict(
                task_id=handle.task_id,
                job_id=self._job_id,
                round_index=round_index,
                consumer_index=self._consumer_index,
            )
            if ctx is not None:
                kw["trace"] = ctx.to_wire()
            wall = time.time() if ctx is not None else 0.0
            t0 = time.perf_counter()
            try:
                resp = handle.stub.call("get_element", **kw)
                self.metrics.add(rpcs=1)
            except TransportError:
                handle.failed = True
                continue
            finally:
                self.metrics.add(stall_time=time.perf_counter() - t0)
                if ctx is not None:
                    self.tracer.record(
                        "client.fetch",
                        ctx,
                        wall,
                        time.perf_counter() - t0,
                        parent_id=self.trace_root.span_id,
                        task_id=handle.task_id,
                        round_index=round_index,
                    )
            status = resp["status"]
            if status == FetchStatus.OK.value:
                self.metrics.add(batches=1)
                backoff = 0.005
                yield self._decode(resp)
                round_index += 1
            elif status == FetchStatus.PENDING.value:
                self.metrics.add(retries=1)
                time.sleep(backoff)
                backoff = min(backoff * 2, 0.05)
            else:  # END_OF_TASK: coordinated jobs end at first exhausted worker
                return

    def close(self) -> None:
        first = not self._closed.is_set()
        self._closed.set()
        if not first:
            return
        with self._tasks_lock:
            handles = list(self._tasks.values())
        for h in handles:
            with h.shm_lock:
                ring, channel = h.shm_ring, h.shm_channel
                h.shm_ring, h.shm_channel, h.shm_state = None, "", "off"
            if ring is None:
                continue
            try:
                # best-effort: the worker unlinks the segment; if it is
                # already gone it reclaims the ring at stop() instead
                h.stub.call("shm_detach", channel=channel)
            except Exception:
                pass
            # NOTE: no ring.close() here — fetcher threads may be mid-decode
            # on a borrowed view; dropping the reference lets GC unmap once
            # the last view dies (the worker owns the segment NAME).


class DistributedDataset:
    """Iterable returned by ``Dataset.distribute(...)`` (paper Fig. 4)."""

    def __init__(
        self,
        graph: Graph,
        service: Any,
        processing_mode: str = "off",
        job_name: Optional[str] = None,
        num_consumers: int = 0,
        consumer_index: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        target_workers: str = "any",
        max_workers: int = 0,
        weight: float = 1.0,
        resume_offsets: bool = False,
        autocache: bool = False,
        buffer_size: int = 8,
        fetch_window: int = DEFAULT_FETCH_WINDOW,
        max_batch: int = DEFAULT_MAX_BATCH,
        prefer_batched: bool = True,
        trace_sample: float = 0.0,
        shm: bool = True,
        zero_copy: bool = False,
        host_key: Optional[str] = None,
    ):
        self._graph = graph
        address = getattr(service, "dispatcher_address", service)
        if not isinstance(address, str):
            raise TypeError("service must be a ServiceHandle or dispatcher address")
        self._address = address
        self._kw = dict(
            processing_mode=processing_mode,
            job_name=job_name,
            num_consumers=num_consumers,
            consumer_index=consumer_index,
            sharing=sharing,
            compression=compression,
            target_workers=target_workers,
            max_workers=max_workers,
            weight=weight,
            resume_offsets=resume_offsets,
            autocache=autocache,
            buffer_size=buffer_size,
            fetch_window=fetch_window,
            max_batch=max_batch,
            prefer_batched=prefer_batched,
            trace_sample=trace_sample,
            shm=shm,
            zero_copy=zero_copy,
            host_key=host_key,
        )
        self.last_client: Optional[DataServiceClient] = None

    def session(self, **overrides: Any) -> DataServiceClient:
        """Open one iteration session; ``overrides`` patch the distribute-
        time client kwargs (e.g. ``repro.feed.DeviceFeeder`` sets
        ``num_consumers``/``consumer_index`` for per-host registration)."""
        kw = {**self._kw, **overrides}
        self.last_client = DataServiceClient(self._address, self._graph, **kw)
        return self.last_client

    def __iter__(self) -> Iterator[Element]:
        return iter(self.session())


def materialize(
    service: Any,
    dataset: Any,
    path: str,
    num_streams: int = 0,
    compression: Optional[str] = None,
    chunk_bytes: int = 0,
    wait: bool = True,
    timeout: float = 300.0,
    poll_interval: float = 0.05,
) -> Dict[str, Any]:
    """Materialize a pipeline into a snapshot through the service.

    Registers the dataset with the dispatcher and starts (or joins — the
    call is idempotent per path) a distributed snapshot write: the
    dispatcher partitions the source into streams, workers execute the
    pipeline and append committed chunks under ``path``.  With ``wait``
    the call polls until the snapshot is finalized (riding through
    dispatcher downtime like any client, §3.4) and returns the final
    status; otherwise it returns the initial status view immediately.

    Consume the result with ``Dataset.from_snapshot(path)`` — including
    mid-write via ``tail=True``.
    """
    address = getattr(service, "dispatcher_address", service)
    if not isinstance(address, str):
        raise TypeError("service must be a ServiceHandle or dispatcher address")
    graph: Graph = dataset.graph if hasattr(dataset, "graph") else dataset
    stub = Stub(address)
    resp = stub.call(
        "start_snapshot",
        path=path,
        graph_bytes=graph.to_bytes(),
        num_streams=num_streams,
        compression=compression,
        client_codecs=available_codecs(),
        chunk_bytes=chunk_bytes,
    )
    if not wait or resp.get("finished"):
        return resp
    deadline = time.monotonic() + timeout
    while True:
        try:
            st = stub.call("snapshot_status", snapshot_id=resp["snapshot_id"])
        except TransportError:
            st = {}  # dispatcher down: keep polling (it restarts in place)
        if st.get("finished"):
            return st
        if time.monotonic() > deadline:
            raise TimeoutError(
                f"snapshot {resp['snapshot_id']} at {path} not finished "
                f"after {timeout:.0f}s: {st}"
            )
        time.sleep(poll_interval)
