"""tf.data-service client (paper §3.1): fetches preprocessed batches.

Two read modes:

* **parallel fetch** (default): one fetcher thread per worker task feeding a
  bounded client-side buffer — maximizes ingestion, order across workers is
  unspecified (the paper's relaxed-visitation stance makes this fine).
* **coordinated reads** (``num_consumers > 0``): strict round-robin — for
  training step r every consumer fetches its ``consumer_index`` slot of round
  r from worker ``sorted_workers[r % n]``, guaranteeing same-bucket batches
  across all clients in the step (§3.6).

The client records stall time (time blocked waiting for data): the paper's
"input-bound" diagnosis is ``stall_time / wall_time``.
"""
from __future__ import annotations

import queue
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, Iterator, List, Optional

from ..data.elements import Element, decode_element, element_nbytes
from ..data.graph import Graph
from .protocol import FetchStatus, new_id
from .transport import Stub, TransportError, decompress


@dataclass
class ClientMetrics:
    batches: int = 0
    bytes_received: int = 0
    stall_time: float = 0.0
    fetch_time: float = 0.0
    rpcs: int = 0
    retries: int = 0


@dataclass
class _TaskHandle:
    task_id: str
    job_id: str
    worker_id: str
    worker_address: str
    stub: Stub
    done: bool = False
    failed: bool = False


class DataServiceClient:
    """One iteration session over a service-backed dataset."""

    _END = object()

    def __init__(
        self,
        dispatcher_address: str,
        graph: Graph,
        processing_mode: str = "off",
        job_name: Optional[str] = None,
        num_consumers: int = 0,
        consumer_index: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        target_workers: str = "any",
        max_workers: int = 0,
        resume_offsets: bool = False,
        buffer_size: int = 8,
        heartbeat_interval: float = 0.3,
        optimize: bool = True,
    ):
        self.client_id = new_id("client")
        self.metrics = ClientMetrics()
        self._dispatcher = Stub(dispatcher_address)
        # the RAW graph is registered; the dispatcher optimizes it once so
        # identical pipelines from different jobs share a dataset_id (§3.5)
        self._graph = graph
        self._mode = processing_mode
        self._job_name = job_name
        self._m = num_consumers
        self._consumer_index = consumer_index
        self._sharing = sharing
        self._compression = compression
        self._target_workers = target_workers
        self._max_workers = max_workers
        self._resume_offsets = resume_offsets
        self._buffer_size = buffer_size
        self._hb_interval = heartbeat_interval

        self._tasks: Dict[str, _TaskHandle] = {}
        self._tasks_lock = threading.Lock()
        self._queue: "queue.Queue[Any]" = queue.Queue(maxsize=max(2, buffer_size))
        self._job_finished = threading.Event()
        self._closed = threading.Event()
        self._fetchers: Dict[str, threading.Thread] = {}
        self._job_id = ""

    # ------------------------------------------------------------------
    # Session setup
    # ------------------------------------------------------------------
    def _register(self) -> None:
        resp = self._dispatcher.call(
            "get_or_register_dataset", graph_bytes=self._graph.to_bytes()
        )
        view = self._dispatcher.call(
            "get_or_create_job",
            dataset_id=resp["dataset_id"],
            job_name=self._job_name,
            policy=self._mode,
            num_consumers=self._m,
            sharing=self._sharing,
            compression=self._compression,
            max_workers=self._max_workers,
            resume_offsets=self._resume_offsets,
            client_id=self.client_id,
        )
        self._job_id = view["job_id"]
        self._sync_tasks(view)

    def _sync_tasks(self, view: Dict[str, Any]) -> None:
        with self._tasks_lock:
            seen = set()
            for t in view["tasks"]:
                seen.add(t["task_id"])
                h = self._tasks.get(t["task_id"])
                if h is None:
                    h = self._tasks[t["task_id"]] = _TaskHandle(
                        task_id=t["task_id"],
                        job_id=t["job_id"],
                        worker_id=t["worker_id"],
                        worker_address=t["worker_address"],
                        stub=Stub(t["worker_address"]),
                    )
                    if self._m == 0 and not self._closed.is_set():
                        self._spawn_fetcher(h)
                elif h.failed and not h.done:
                    # the dispatcher re-listed a task we gave up on (e.g. the
                    # transient window right after a dispatcher restart when
                    # workers had not yet re-registered): resurrect it.
                    h.failed = False
                    if self._m == 0 and not self._closed.is_set():
                        self._spawn_fetcher(h)
            # tasks whose worker died are dropped by the dispatcher view
            for tid, h in self._tasks.items():
                if tid not in seen and not h.done:
                    h.failed = True
            if view.get("finished"):
                self._job_finished.set()

    def _heartbeat_loop(self) -> None:
        while not self._closed.wait(self._hb_interval):
            try:
                view = self._dispatcher.call(
                    "client_heartbeat", job_id=self._job_id, client_id=self.client_id
                )
                self._sync_tasks(view)
            except TransportError:
                continue  # dispatcher down: keep consuming from workers (§3.4)
            if self._job_finished.is_set():
                return

    # ------------------------------------------------------------------
    # Parallel-fetch mode
    # ------------------------------------------------------------------
    def _spawn_fetcher(self, handle: _TaskHandle) -> None:
        th = threading.Thread(target=self._fetch_loop, args=(handle,), daemon=True)
        self._fetchers[handle.task_id] = th
        th.start()

    def _fetch_loop(self, handle: _TaskHandle) -> None:
        backoff = 0.005
        while not self._closed.is_set() and not handle.done and not handle.failed:
            try:
                t0 = time.perf_counter()
                resp = handle.stub.call(
                    "get_element", task_id=handle.task_id, job_id=self._job_id
                )
                self.metrics.fetch_time += time.perf_counter() - t0
                self.metrics.rpcs += 1
            except TransportError:
                handle.failed = True  # worker died; dispatcher will notice
                break
            status = resp["status"]
            if status == FetchStatus.OK.value:
                backoff = 0.005
                self._enqueue(self._decode(resp))
            elif status == FetchStatus.PENDING.value:
                self.metrics.retries += 1
                time.sleep(backoff)
                backoff = min(backoff * 2, 0.1)
            else:  # END_OF_TASK
                handle.done = True
        self._maybe_finish()

    def _decode(self, resp: Dict[str, Any]) -> Element:
        if "element_compressed" in resp:
            elem = decode_element(decompress(resp["element_compressed"]))
        else:
            elem = resp["element"]
        self.metrics.bytes_received += resp.get("nbytes", 0)
        return elem

    def _enqueue(self, elem: Element) -> None:
        while not self._closed.is_set():
            try:
                self._queue.put(elem, timeout=0.1)
                return
            except queue.Full:
                continue

    def _maybe_finish(self) -> None:
        with self._tasks_lock:
            all_done = self._tasks and all(
                h.done or h.failed for h in self._tasks.values()
            )
        if all_done and self._job_finished.is_set():
            try:
                self._queue.put_nowait(self._END)
            except queue.Full:
                # consumer will re-check completion on queue timeout
                pass

    # ------------------------------------------------------------------
    # Iteration
    # ------------------------------------------------------------------
    def __iter__(self) -> Iterator[Element]:
        self._register()
        hb = threading.Thread(target=self._heartbeat_loop, daemon=True)
        hb.start()
        try:
            if self._m > 0:
                yield from self._iter_coordinated()
            else:
                yield from self._iter_parallel()
        finally:
            self.close()

    def _iter_parallel(self) -> Iterator[Element]:
        while True:
            t0 = time.perf_counter()
            try:
                item = self._queue.get(timeout=0.2)
            except queue.Empty:
                self.metrics.stall_time += time.perf_counter() - t0
                with self._tasks_lock:
                    done = self._tasks and all(
                        h.done or h.failed for h in self._tasks.values()
                    )
                if done and self._job_finished.is_set() and self._queue.empty():
                    return
                continue
            self.metrics.stall_time += time.perf_counter() - t0
            if item is self._END:
                return
            self.metrics.batches += 1
            yield item

    def _iter_coordinated(self) -> Iterator[Element]:
        """Round-robin over workers; all consumers see same-bucket rounds."""
        round_index = 0
        backoff = 0.005
        while not self._closed.is_set():
            with self._tasks_lock:
                live = sorted(
                    (h for h in self._tasks.values() if not h.failed and not h.done),
                    key=lambda h: h.worker_id,
                )
            if not live:
                if self._job_finished.is_set():
                    return
                time.sleep(0.02)
                continue
            handle = live[round_index % len(live)]
            t0 = time.perf_counter()
            try:
                resp = handle.stub.call(
                    "get_element",
                    task_id=handle.task_id,
                    job_id=self._job_id,
                    round_index=round_index,
                    consumer_index=self._consumer_index,
                )
                self.metrics.rpcs += 1
            except TransportError:
                handle.failed = True
                continue
            finally:
                self.metrics.stall_time += time.perf_counter() - t0
            status = resp["status"]
            if status == FetchStatus.OK.value:
                self.metrics.batches += 1
                backoff = 0.005
                yield self._decode(resp)
                round_index += 1
            elif status == FetchStatus.PENDING.value:
                self.metrics.retries += 1
                time.sleep(backoff)
                backoff = min(backoff * 2, 0.05)
            else:  # END_OF_TASK: coordinated jobs end at first exhausted worker
                return

    def close(self) -> None:
        self._closed.set()


class DistributedDataset:
    """Iterable returned by ``Dataset.distribute(...)`` (paper Fig. 4)."""

    def __init__(
        self,
        graph: Graph,
        service: Any,
        processing_mode: str = "off",
        job_name: Optional[str] = None,
        num_consumers: int = 0,
        consumer_index: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        target_workers: str = "any",
        max_workers: int = 0,
        resume_offsets: bool = False,
        buffer_size: int = 8,
    ):
        self._graph = graph
        address = getattr(service, "dispatcher_address", service)
        if not isinstance(address, str):
            raise TypeError("service must be a ServiceHandle or dispatcher address")
        self._address = address
        self._kw = dict(
            processing_mode=processing_mode,
            job_name=job_name,
            num_consumers=num_consumers,
            consumer_index=consumer_index,
            sharing=sharing,
            compression=compression,
            target_workers=target_workers,
            max_workers=max_workers,
            resume_offsets=resume_offsets,
            buffer_size=buffer_size,
        )
        self.last_client: Optional[DataServiceClient] = None

    def session(self) -> DataServiceClient:
        self.last_client = DataServiceClient(self._address, self._graph, **self._kw)
        return self.last_client

    def __iter__(self) -> Iterator[Element]:
        return iter(self.session())
