"""Cost model — Equation 1 of the paper (§4.1).

    C = t * ( C_cpu * (n_W * mean_cpu_util_W + n_T * cpu_alloc_T)
            + C_mem * (n_W * mean_mem_util_W + n_T * mem_alloc_T)
            + C_acc * n_T * n_acc_per_T )

Workers are billed on *utilization* (fungible multi-tenant machines return
unused reservation to the pool); trainer hosts are billed on *allocation*
(dedicated accelerator hosts are charged whole).  Defaults follow the paper's
open-source experiment pricing (GCP us-central1, June 2023): TPU v2-8 VM
$4.50/h, n2-standard-8 worker $0.08/h — decomposed into per-unit CPU/MEM
rates for the formula.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict


@dataclass(frozen=True)
class CostRates:
    cpu_per_core_hour: float
    mem_per_gb_hour: float
    acc_per_chip_hour: float


# n2-standard-8: 8 vCPU + 32 GB for $0.08/h in the paper's setup is heavily
# discounted spot-like pricing; we follow GCP's published on-demand split of
# ~$0.0315/vCPU-h and ~$0.0042/GB-h scaled to match the paper's $0.08/h node.
_N2_CPU, _N2_MEM = 8, 32.0
_SCALE = 0.08 / (_N2_CPU * 0.0315 + _N2_MEM * 0.0042)
GCP_RATES = CostRates(
    cpu_per_core_hour=0.0315 * _SCALE,
    mem_per_gb_hour=0.0042 * _SCALE,
    # TPU v2-8 VM: $4.50/h for the host (96 vCPU + 335 GB come with it; the
    # accelerator component dominates — attribute the residual to the chips).
    acc_per_chip_hour=(4.50 - (96 * 0.0315 + 335 * 0.0042) * _SCALE) / 8,
)


@dataclass
class JobResources:
    """Inputs to Eq. 1 for one training job."""

    duration_hours: float
    num_workers: int = 0
    worker_cpu_util_cores: float = 0.0  # mean cores actually busy per worker
    worker_mem_util_gb: float = 0.0  # mean GB actually used per worker
    num_trainers: int = 1
    trainer_cpu_alloc_cores: float = 96.0  # allocated (billed whole)
    trainer_mem_alloc_gb: float = 335.0
    accelerators_per_trainer: int = 8


def job_cost(res: JobResources, rates: CostRates = GCP_RATES) -> Dict[str, float]:
    cpu = rates.cpu_per_core_hour * (
        res.num_workers * res.worker_cpu_util_cores
        + res.num_trainers * res.trainer_cpu_alloc_cores
    )
    mem = rates.mem_per_gb_hour * (
        res.num_workers * res.worker_mem_util_gb
        + res.num_trainers * res.trainer_mem_alloc_gb
    )
    acc = rates.acc_per_chip_hour * res.num_trainers * res.accelerators_per_trainer
    per_hour = cpu + mem + acc
    return {
        "cpu_cost": cpu * res.duration_hours,
        "mem_cost": mem * res.duration_hours,
        "acc_cost": acc * res.duration_hours,
        "total": per_hour * res.duration_hours,
        "per_hour": per_hour,
    }


def cost_saving(colocated: JobResources, disaggregated: JobResources,
                rates: CostRates = GCP_RATES) -> float:
    """Paper's headline metric: colocated cost / disaggregated cost."""
    return job_cost(colocated, rates)["total"] / job_cost(disaggregated, rates)["total"]
