"""Source-data sharding policies (paper §3.3).

The dispatcher owns a ``ShardManager`` per DYNAMIC job: it over-partitions the
source into more shards than workers (load balancing) and hands shards out
first-come-first-served.  Completed shards are journaled; in-flight shards on
a failed worker are *not* re-issued by default — that is exactly the paper's
at-most-once guarantee.  ``resume_offsets=True`` upgrades recovery to
offset-checkpointed resumption (the paper's sketched exactly-once mechanism:
dispatcher logs shard distribution, workers report progress; the shard is
re-issued starting at the last reported element offset).
"""
from __future__ import annotations

import threading
from collections import deque
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..data.graph import Graph
from ..data.sources import list_shards
from .protocol import ShardingPolicy, VisitationGuarantee


def guarantee_for(
    policy: ShardingPolicy, failures_possible: bool, resume_offsets: bool
) -> VisitationGuarantee:
    if policy == ShardingPolicy.OFF:
        return VisitationGuarantee.ZERO_ONCE_OR_MORE
    if policy == ShardingPolicy.DYNAMIC:
        if not failures_possible or resume_offsets:
            return VisitationGuarantee.EXACTLY_ONCE
        return VisitationGuarantee.AT_MOST_ONCE
    # STATIC: fixed partitions; failure loses the partition (at-most-once)
    return (
        VisitationGuarantee.EXACTLY_ONCE
        if not failures_possible
        else VisitationGuarantee.AT_MOST_ONCE
    )


@dataclass
class ShardState:
    shard: Dict[str, Any]
    shard_id: int
    assigned_to: Optional[str] = None  # worker_id
    completed: bool = False
    lost: bool = False
    offset: int = 0  # last checkpointed element offset within the shard


class ShardManager:
    """Dispatcher-side shard book-keeping for one DYNAMIC/STATIC job."""

    def __init__(
        self,
        graph: Graph,
        policy: ShardingPolicy,
        num_workers_hint: int,
        overpartition: int = 4,
        resume_offsets: bool = False,
    ):
        self.policy = policy
        self.resume_offsets = resume_offsets
        self._lock = threading.Lock()
        src = graph.source
        hint = max(1, num_workers_hint) * max(1, overpartition)
        shards = list_shards(src.params, src.op, num_shards_hint=hint)
        self._states = [ShardState(shard=s, shard_id=i) for i, s in enumerate(shards)]
        self._pending: deque[int] = deque(range(len(self._states)))

    # -- dynamic policy ----------------------------------------------------
    def next_shard(self, worker_id: str) -> Optional[Tuple[int, Dict[str, Any], int]]:
        """FCFS hand-out. Returns (shard_id, shard, start_offset) or None."""
        with self._lock:
            while self._pending:
                sid = self._pending.popleft()
                st = self._states[sid]
                if st.completed or st.lost:
                    continue
                st.assigned_to = worker_id
                return sid, st.shard, st.offset
            return None

    def complete_shard(self, shard_id: int, worker_id: str) -> None:
        with self._lock:
            st = self._states[shard_id]
            if st.assigned_to == worker_id:
                st.completed = True
                st.assigned_to = None

    def checkpoint_offset(self, shard_id: int, worker_id: str, offset: int) -> None:
        with self._lock:
            st = self._states[shard_id]
            if st.assigned_to == worker_id:
                st.offset = max(st.offset, offset)

    def worker_failed(self, worker_id: str) -> List[int]:
        """Handle a worker death. Returns shard ids affected.

        Default (at-most-once): in-flight shards are marked LOST — their
        remaining data is never seen (paper §3.4).  With resume_offsets the
        shard re-enters the queue at its checkpointed offset.
        """
        affected = []
        with self._lock:
            for st in self._states:
                if st.assigned_to == worker_id and not st.completed:
                    st.assigned_to = None
                    affected.append(st.shard_id)
                    if self.resume_offsets:
                        self._pending.append(st.shard_id)
                    else:
                        st.lost = True
        return affected

    def assigned_to_worker(self, worker_id: str) -> List[int]:
        """Shard ids currently assigned (in-flight) to ``worker_id``."""
        with self._lock:
            return [
                st.shard_id
                for st in self._states
                if st.assigned_to == worker_id and not st.completed and not st.lost
            ]

    def requeue(self, shard_id: int, worker_id: str) -> bool:
        """Return an assigned shard to the FRONT of the queue.

        Used when the journal says ``worker_id`` holds the shard but the
        worker provably does not (the assignment response was lost with a
        crashed dispatcher): the shard delivered zero elements, so handing
        it out again — at its current offset — is exact, not a replay.
        """
        with self._lock:
            st = self._states[shard_id]
            if st.assigned_to != worker_id or st.completed or st.lost:
                return False
            st.assigned_to = None
            self._pending.appendleft(shard_id)
            return True

    # -- static policy -------------------------------------------------------
    def static_assignment(self, worker_ids: List[str]) -> Dict[str, List[Dict[str, Any]]]:
        """Round-robin all shards across the worker set, up front."""
        out: Dict[str, List[Dict[str, Any]]] = {w: [] for w in worker_ids}
        with self._lock:
            for st in self._states:
                w = worker_ids[st.shard_id % len(worker_ids)]
                st.assigned_to = w
                out[w].append(st.shard)
            self._pending.clear()
        return out

    # -- introspection ---------------------------------------------------------
    def done(self) -> bool:
        with self._lock:
            return all(st.completed or st.lost for st in self._states) and not self._pending

    def stats(self) -> Dict[str, int]:
        with self._lock:
            return {
                "total": len(self._states),
                "completed": sum(s.completed for s in self._states),
                "lost": sum(s.lost for s in self._states),
                "pending": len(self._pending),
                "in_flight": sum(
                    1 for s in self._states
                    if s.assigned_to is not None and not s.completed
                ),
            }

    # -- journal (de)hydration ---------------------------------------------------
    def to_payload(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "policy": self.policy.value,
                "resume_offsets": self.resume_offsets,
                "states": [
                    (s.shard_id, s.shard, s.assigned_to, s.completed, s.lost, s.offset)
                    for s in self._states
                ],
                "pending": list(self._pending),
            }

    @staticmethod
    def from_payload(graph: Graph, payload: Dict[str, Any]) -> "ShardManager":
        mgr = ShardManager.__new__(ShardManager)
        mgr.policy = ShardingPolicy(payload["policy"])
        mgr.resume_offsets = payload["resume_offsets"]
        mgr._lock = threading.Lock()
        mgr._states = [
            ShardState(
                shard=sh, shard_id=sid, assigned_to=asg, completed=c, lost=l, offset=o
            )
            for sid, sh, asg, c, l, o in payload["states"]
        ]
        # in-flight shards at crash time: the worker will re-request; treat
        # assigned-but-not-completed as pending again (workers are stateless
        # and re-register after a dispatcher restart).
        mgr._pending = deque(payload["pending"])
        for st in mgr._states:
            if st.assigned_to is not None and not st.completed:
                st.assigned_to = None
                mgr._pending.append(st.shard_id)
        return mgr
