"""repro.core — the paper's contribution: a disaggregated ML input data
processing service (dispatcher + stateless workers + clients), with
horizontal scale-out, ephemeral data sharing, coordinated reads, relaxed
data-visitation guarantees, and journal-based dispatcher fault tolerance."""
from .autoscaler import Autoscaler, AutoscalerConfig, ScalableOrchestrator
from .cache import SlidingWindowCache
from .client import DataServiceClient, DistributedDataset, materialize
from .codecs import available_codecs, register_codec, resolve_codec
from .cost import CostRates, GCP_RATES, JobResources, cost_saving, job_cost
from .dispatcher import CrashPoints, Dispatcher, DispatcherCrashed, StandbyDispatcher
from .journal import Journal, JournalVersionError
from .protocol import FetchStatus, ShardingPolicy, TaskSpec, VisitationGuarantee
from .scheduler import FleetScheduler, JobDemand, SchedulerConfig
from .service import LocalOrchestrator, ServiceHandle, start_service
from .sharding import ShardManager, guarantee_for
from .transport import Backoff, GrpcServer, Stub, TCPServer, TransportError
from .worker import Worker

__all__ = [
    "Autoscaler",
    "AutoscalerConfig",
    "Backoff",
    "CostRates",
    "CrashPoints",
    "DataServiceClient",
    "Dispatcher",
    "DispatcherCrashed",
    "DistributedDataset",
    "FetchStatus",
    "FleetScheduler",
    "GCP_RATES",
    "Journal",
    "JournalVersionError",
    "JobDemand",
    "JobResources",
    "LocalOrchestrator",
    "SchedulerConfig",
    "ScalableOrchestrator",
    "ServiceHandle",
    "ShardManager",
    "ShardingPolicy",
    "SlidingWindowCache",
    "StandbyDispatcher",
    "GrpcServer",
    "Stub",
    "TCPServer",
    "TaskSpec",
    "TransportError",
    "VisitationGuarantee",
    "Worker",
    "available_codecs",
    "cost_saving",
    "guarantee_for",
    "job_cost",
    "materialize",
    "register_codec",
    "resolve_codec",
    "start_service",
]
