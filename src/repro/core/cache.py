"""Ephemeral data sharing: the per-worker sliding-window cache (paper §3.5).

A worker producing batches for pipeline P keeps the most recent ``capacity``
batches in a window; each attached job holds a pointer (absolute batch index)
into that window.  Reads at the window front trigger production of a new
batch and eviction of the oldest one; slower jobs whose pointer falls behind
the window tail silently skip evicted batches (their pointer snaps to the
tail — the paper's relaxed at-most-once visitation in action).

The cache is the unit of sharing: jobs with the same pipeline fingerprint
attach to the same cache, so preprocessing cost is paid once regardless of
the number of attached jobs (paper's mode (A)).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple


@dataclass
class CacheStats:
    produced: int = 0  # batches computed (the CPU cost proxy)
    served: int = 0  # batches handed to jobs (may exceed produced when shared)
    evicted: int = 0
    skipped: int = 0  # batches jobs never saw due to eviction


class SlidingWindowCache:
    """Thread-safe sliding-window batch cache with per-job read pointers."""

    def __init__(self, producer: Iterator[Any], capacity: int = 16):
        self._producer = producer
        self._capacity = max(1, capacity)
        self._window: List[Any] = []
        self._front = 0  # absolute index of window[0]
        self._pointers: Dict[str, int] = {}
        self._exhausted = False
        self._lock = threading.Lock()
        self.stats = CacheStats()

    # -- job lifecycle ------------------------------------------------------
    def attach(self, job_id: str) -> None:
        with self._lock:
            # New jobs start at the window tail: they see everything still
            # cached plus all future batches (partially-overlapping jobs).
            self._pointers.setdefault(job_id, self._front)

    def detach(self, job_id: str) -> None:
        with self._lock:
            self._pointers.pop(job_id, None)

    # -- the read path (paper Fig. 5) -----------------------------------------
    def read(self, job_id: str) -> Tuple[Optional[Any], bool]:
        """Return (batch, end_of_data) for ``job_id``'s pointer; advance it.

        Exactly mirrors Fig. 5: a read at the cache front computes and
        enqueues a new batch (evicting the oldest when full); a pointer that
        fell behind the tail snaps forward, skipping evicted batches.
        """
        with self._lock:
            if job_id not in self._pointers:
                self._pointers[job_id] = self._front
            ptr = self._pointers[job_id]
            if ptr < self._front:  # fell off the window tail
                self.stats.skipped += self._front - ptr
                ptr = self._front
            back = self._front + len(self._window)
            if ptr == back:
                # pointer at the front of the cache: produce a new batch
                if self._exhausted:
                    return None, True
                try:
                    batch = next(self._producer)
                except StopIteration:
                    self._exhausted = True
                    return None, True
                self._window.append(batch)
                self.stats.produced += 1
                if len(self._window) > self._capacity:
                    self._window.pop(0)
                    self._front += 1
                    self.stats.evicted += 1
                    if ptr < self._front:  # can happen when capacity == 1
                        ptr = self._front
            batch = self._window[ptr - self._front]
            self._pointers[job_id] = ptr + 1
            self.stats.served += 1
            return batch, False

    # -- introspection -----------------------------------------------------
    def pointers(self) -> Dict[str, int]:
        with self._lock:
            return dict(self._pointers)

    def window_range(self) -> Tuple[int, int]:
        with self._lock:
            return self._front, self._front + len(self._window)

    @property
    def num_jobs(self) -> int:
        with self._lock:
            return len(self._pointers)
