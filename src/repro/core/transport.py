"""Pluggable RPC transports.

Components (dispatcher, workers) expose ``handle(method, payload) -> payload``
and are reachable through an address:

* ``inproc://<name>``   — direct function call via a process-local registry
  (default for single-process deployments and tests; zero-copy).
* ``tcp://host:port``   — length-prefixed pickle over a socket; stands in for
  the paper's gRPC channel and makes the deployment genuinely multi-process.
* ``grpc://host:port``  — the paper's actual wire protocol (§3.1: "all
  communication ... is done via gRPC, which uses HTTP/2, and multiplexes
  multiple calls on a single TCP connection").  A single generic unary RPC
  carries (method, pickled payload); uses grpcio's generic handler API so
  no .proto codegen is required.
* ``shm://<segment>``   — data-plane-only ring descriptor (``core.shm_ring``):
  names a shared-memory frame ring negotiated over an existing control
  channel (the ``shm_attach`` RPC).  It carries no request/response channel,
  so ``Stub`` refuses it with a ``TransportError`` explaining the contract.

Client code uses ``Stub(address)`` and never sees the difference.  Schemes
are pluggable: :func:`register_scheme` maps a scheme name to a connection
factory, so deployments can add transports without patching ``Stub``.

Per-scheme error contract (what ``Stub.call`` raises)
-----------------------------------------------------
Uniform rule: **connection-level failures always surface as**
``TransportError`` — never a raw ``OSError``/``socket.error``/``RpcError``
— so every ``Backoff`` retry loop in the codebase triggers on exactly one
exception type, for every scheme:

==========  ===============================  ==============================
scheme      connection loss / connect fail   remote handler exception
==========  ===============================  ==============================
inproc      ``TransportError`` (not bound)   propagates NATIVELY (same
                                             process, same traceback)
tcp         ``TransportError`` (wraps
            ``OSError``, connect+send+recv,  ``TransportError`` carrying
            malformed address, truncated     the remote ``repr``
            stream)
grpc        ``TransportError`` (wraps        ``TransportError`` carrying
            ``RpcError``, missing grpcio,    the remote ``repr``
            undecodable response)
shm         ``TransportError`` always (data plane only — no call channel)
==========  ===============================  ==============================

A failed call drops the cached connection; the next call reconnects
(simple failover).  Callers implement retry on ``TransportError``: clients
ride through dispatcher downtime and mark worker tasks failed (§3.4).
"""
from __future__ import annotations

import pickle
import random
import socket
import socketserver
import struct
import threading
from typing import Any, Callable, Dict, Optional, Protocol

# Re-exported for backwards compatibility: payload compression used to live
# here; it is now a pluggable registry (see codecs.py for negotiation rules).
from .codecs import compress, decompress  # noqa: F401

# Default per-call deadline when a Stub is built without an explicit
# timeout.  Paths whose liveness budget is tighter than this (standby
# journal tail, heartbeats) MUST pass their own — the D003 static pass
# flags retry-critical call sites that rely on this default.
DEFAULT_RPC_TIMEOUT_S = 30.0


class TransportError(Exception):
    """Raised for any transport-level failure (connect, send, remote error).

    Callers implement retry / failover on this: clients ride through
    dispatcher downtime and mark worker tasks failed (paper §3.4).  Remote
    exceptions raised by a handler are shipped back and re-raised as
    ``TransportError`` with the remote ``repr`` in the message.
    """


class Handler(Protocol):
    def handle(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]: ...


class Backoff:
    """Bounded exponential backoff with equal jitter for reconnect loops.

    Delay for attempt ``n`` is drawn from ``[d/2, d]`` where
    ``d = min(cap, base * multiplier**n)`` — the jitter spreads a fleet of
    workers reconnecting to a freshly promoted standby across half a period
    instead of landing them in one thundering herd; the cap bounds how long
    any single retry sleeps once the outage is long.

    ``rng`` is injectable for deterministic tests (defaults to the module
    ``random``; only ``.uniform`` is used).
    """

    def __init__(
        self,
        base: float = 0.05,
        cap: float = 2.0,
        multiplier: float = 2.0,
        rng: Optional[Any] = None,
    ):
        self.base = base
        self.cap = cap
        self.multiplier = multiplier
        self._rng = rng if rng is not None else random
        self._attempt = 0

    @property
    def attempt(self) -> int:
        return self._attempt

    def next_delay(self) -> float:
        d = min(self.cap, self.base * self.multiplier**self._attempt)
        if d < self.cap:
            # stop growing the exponent once capped (a long outage must not
            # overflow float pow after thousands of attempts)
            self._attempt += 1
        return d / 2 + self._rng.uniform(0.0, d / 2)

    def reset(self) -> None:
        self._attempt = 0


# ---------------------------------------------------------------------------
# Scheme registry: pluggable connection factories
# ---------------------------------------------------------------------------
# Maps scheme name -> factory(address, timeout) -> connection.  A connection
# exposes ``call(method, payload) -> payload`` and ``close()``.  Factories
# may raise anything; Stub wraps non-TransportError construction failures.
# A connection with ``native_errors = True`` (inproc) opts out of Stub's
# error wrapping: exceptions from the handler propagate to the caller with
# their original type and traceback.
_SCHEMES: Dict[str, Callable[[str, float], Any]] = {}


def register_scheme(name: str, factory: Callable[[str, float], Any]) -> None:
    """Register (or replace) a transport scheme's connection factory.

    ``factory(address, timeout)`` receives the FULL address (including the
    ``scheme://`` prefix) and the stub's per-call deadline, and returns a
    connection object (``call``/``close``).  Registered names appear in
    ``Stub``'s dispatch; replacing a built-in is allowed (tests inject
    fault-y transports this way).
    """
    _SCHEMES[name] = factory


# ---------------------------------------------------------------------------
# In-process registry transport
# ---------------------------------------------------------------------------
class _InprocRegistry:
    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._handlers: Dict[str, Handler] = {}

    def bind(self, name: str, handler: Handler) -> str:
        with self._lock:
            self._handlers[name] = handler
        return f"inproc://{name}"

    def unbind(self, name: str) -> None:
        with self._lock:
            self._handlers.pop(name, None)

    def get(self, name: str) -> Handler:
        with self._lock:
            h = self._handlers.get(name)
        if h is None:
            raise TransportError(f"inproc endpoint not bound: {name}")
        return h


INPROC = _InprocRegistry()


class _InprocConnection:
    """Stateless 'connection' that dispatches into the inproc registry.

    The handler lookup happens per call (not at construction) so a stub
    built before its endpoint binds — or after a rebind — still resolves.
    Handler exceptions propagate natively (``native_errors``): an inproc
    call IS a function call, and masking e.g. a ``ValueError`` from the
    dispatcher behind ``TransportError`` would break same-process callers
    that branch on the real type.
    """

    native_errors = True

    def __init__(self, address: str, timeout: float):
        self._name = address[len("inproc://") :]

    def call(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        return INPROC.get(self._name).handle(method, payload)

    def close(self) -> None:
        pass


# ---------------------------------------------------------------------------
# TCP transport (length-prefixed pickle; request/response per connection pool)
# ---------------------------------------------------------------------------
def _send_msg(sock: socket.socket, obj: Any) -> None:
    data = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    sock.sendall(struct.pack("<I", len(data)) + data)


def _recv_msg(sock: socket.socket) -> Any:
    hdr = _recv_exact(sock, 4)
    (n,) = struct.unpack("<I", hdr)
    return pickle.loads(_recv_exact(sock, n))


def _recv_exact(sock: socket.socket, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = sock.recv(n - len(buf))
        if not chunk:
            raise TransportError("connection closed mid-message")
        buf += chunk
    return buf


class TCPServer:
    """Threaded TCP server fronting a Handler."""

    def __init__(self, handler: Handler, host: str = "127.0.0.1", port: int = 0):
        self._handler = handler
        outer = self

        class _ReqHandler(socketserver.BaseRequestHandler):
            def handle(self) -> None:  # one connection, many requests
                while True:
                    try:
                        method, payload = _recv_msg(self.request)
                    except (TransportError, EOFError, ConnectionError, OSError):
                        return
                    try:
                        result = outer._handler.handle(method, payload)
                        _send_msg(self.request, ("ok", result))
                    except Exception as e:  # ship the error to the caller
                        _send_msg(self.request, ("err", repr(e)))

        class _Server(socketserver.ThreadingTCPServer):
            daemon_threads = True
            allow_reuse_address = True

        self._server = _Server((host, port), _ReqHandler)
        self.address = f"tcp://{self._server.server_address[0]}:{self._server.server_address[1]}"
        self._thread = threading.Thread(target=self._server.serve_forever, daemon=True)

    def start(self) -> "TCPServer":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._server.shutdown()
        self._server.server_close()


class _TCPConnection:
    def __init__(self, host: str, port: int, timeout: float = DEFAULT_RPC_TIMEOUT_S):
        # the socket timeout bounds connect AND every recv: a peer that
        # accepts but never answers surfaces as TransportError after
        # `timeout`, not a silent hang
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._lock = threading.Lock()

    def call(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        with self._lock:
            _send_msg(self._sock, (method, payload))
            status, result = _recv_msg(self._sock)
        if status != "ok":
            raise TransportError(f"remote error from {method}: {result}")
        return result

    def close(self) -> None:
        try:
            self._sock.close()
        except OSError:
            pass


# ---------------------------------------------------------------------------
# gRPC transport (optional; the paper's production wire protocol)
# ---------------------------------------------------------------------------
_GRPC_METHOD = "/repro.DataService/Call"


class GrpcServer:
    """gRPC server fronting a Handler via one generic unary method.

    Uses grpcio's generic_rpc_handlers so the repo carries no generated
    proto code; the request/response bodies are (method, payload) pickles —
    the same message schema as the TCP transport, over HTTP/2 multiplexing.
    """

    def __init__(self, handler: Handler, host: str = "127.0.0.1", port: int = 0):
        import grpc  # deferred: optional dependency
        from concurrent import futures

        outer_handler = handler

        class _Generic(grpc.GenericRpcHandler):
            def service(self, handler_call_details):
                if handler_call_details.method != _GRPC_METHOD:
                    return None

                def unary(request: bytes, context) -> bytes:
                    method, payload = pickle.loads(request)
                    try:
                        return pickle.dumps(
                            ("ok", outer_handler.handle(method, payload)),
                            protocol=pickle.HIGHEST_PROTOCOL,
                        )
                    except Exception as e:
                        return pickle.dumps(("err", repr(e)))

                return grpc.unary_unary_rpc_method_handler(
                    unary,
                    request_deserializer=lambda b: b,
                    response_serializer=lambda b: b,
                )

        self._server = grpc.server(
            futures.ThreadPoolExecutor(max_workers=16),
            options=[("grpc.max_receive_message_length", 128 * 1024 * 1024),
                     ("grpc.max_send_message_length", 128 * 1024 * 1024)],
        )
        self._server.add_generic_rpc_handlers((_Generic(),))
        bound = self._server.add_insecure_port(f"{host}:{port}")
        self.address = f"grpc://{host}:{bound}"

    def start(self) -> "GrpcServer":
        self._server.start()
        return self

    def stop(self) -> None:
        self._server.stop(grace=0.2)


class _GrpcConnection:
    def __init__(self, target: str, timeout: float = DEFAULT_RPC_TIMEOUT_S):
        import grpc

        self._timeout = timeout

        self._grpc = grpc
        self._channel = grpc.insecure_channel(
            target,
            options=[("grpc.max_receive_message_length", 128 * 1024 * 1024),
                     ("grpc.max_send_message_length", 128 * 1024 * 1024)],
        )
        self._call = self._channel.unary_unary(
            _GRPC_METHOD,
            request_serializer=lambda b: b,
            response_deserializer=lambda b: b,
        )

    def call(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        try:
            resp = self._call(
                pickle.dumps((method, payload), protocol=pickle.HIGHEST_PROTOCOL),
                timeout=self._timeout,
            )
        except self._grpc.RpcError as e:
            raise TransportError(f"grpc call {method} failed: {e.code()}")
        try:
            status, result = pickle.loads(resp)
        except Exception as e:  # truncated/garbage body: connection-level
            raise TransportError(
                f"grpc call {method}: undecodable response: {e!r}"
            ) from e
        if status != "ok":
            raise TransportError(f"remote error from {method}: {result}")
        return result

    def close(self) -> None:
        self._channel.close()


# ---------------------------------------------------------------------------
# Built-in scheme registrations
# ---------------------------------------------------------------------------
def _tcp_factory(address: str, timeout: float) -> _TCPConnection:
    hostport = address[len("tcp://") :]
    try:
        host, port_s = hostport.rsplit(":", 1)
        port = int(port_s)
    except ValueError as e:  # no colon / non-numeric port
        raise TransportError(f"malformed tcp address {address!r}: {e}") from e
    return _TCPConnection(host, port, timeout=timeout)


def _grpc_factory(address: str, timeout: float) -> _GrpcConnection:
    # _GrpcConnection's deferred ``import grpc`` (optional dep) and channel
    # construction errors are wrapped by Stub's factory guard.
    return _GrpcConnection(address[len("grpc://") :], timeout=timeout)


def _shm_factory(address: str, timeout: float) -> Any:
    raise TransportError(
        f"shm:// is a data-plane descriptor, not a call channel: {address!r} "
        "names a shared-memory frame ring (core.shm_ring) negotiated via the "
        "shm_attach RPC on an existing tcp/grpc control connection"
    )


register_scheme("inproc", _InprocConnection)
register_scheme("tcp", _tcp_factory)
register_scheme("grpc", _grpc_factory)
register_scheme("shm", _shm_factory)


# ---------------------------------------------------------------------------
# Stub: uniform client handle over any transport
# ---------------------------------------------------------------------------
class Stub:
    """Uniform client handle over any transport scheme.

    One ``Stub`` owns at most one underlying connection and serializes calls
    on it — a single stub gives strictly request/response semantics.  To
    overlap multiple outstanding requests against the same endpoint (the
    client's pipelined prefetch window), open one ``Stub`` per in-flight
    request: each TCP/gRPC stub gets its own connection/channel, and inproc
    stubs are free.
    """

    def __init__(self, address: str, timeout: Optional[float] = None):
        self.address = address
        # per-stub RPC deadline; retry-critical loops (standby journal tail,
        # heartbeats) pass one derived from their own lease so a hung peer
        # can't stall them for the transport default
        self.timeout = DEFAULT_RPC_TIMEOUT_S if timeout is None else timeout
        self._conn: Optional[Any] = None
        self._lock = threading.Lock()

    def call(self, method: str, **payload: Any) -> Dict[str, Any]:
        """Invoke ``method`` on the remote handler and return its response.

        Connections are opened lazily (via the scheme's registered factory)
        and dropped on error so the next call reconnects (simple failover).
        Per the module's error contract: every connection-level failure —
        connect refused, malformed address, mid-call socket death, missing
        optional transport package, undecodable response — surfaces as
        ``TransportError``, never a raw ``OSError``; remote handler
        exceptions also arrive as ``TransportError`` (carrying the remote
        ``repr``) — EXCEPT over ``inproc://``, where handler exceptions
        propagate natively (same-process call).
        """
        scheme = self.address.split("://", 1)[0] if "://" in self.address else ""
        factory = _SCHEMES.get(scheme)
        if factory is None:
            raise TransportError(f"unsupported address scheme: {self.address}")
        with self._lock:
            if self._conn is None:
                try:
                    self._conn = factory(self.address, self.timeout)
                except TransportError:
                    raise
                except Exception as e:  # OSError, ImportError, bad address...
                    raise TransportError(
                        f"cannot connect to {self.address}: {e}"
                    ) from e
            conn = self._conn
        if getattr(conn, "native_errors", False):
            return conn.call(method, payload)
        try:
            return conn.call(method, payload)
        except TransportError:
            self._drop(conn)
            raise
        except (OSError, EOFError, pickle.UnpicklingError) as e:
            self._drop(conn)
            raise TransportError(str(e)) from e

    def _drop(self, conn: Any) -> None:
        """Discard a failed connection so the next call reconnects."""
        with self._lock:
            if self._conn is conn:
                try:
                    conn.close()
                except Exception:
                    pass
                self._conn = None

    def close(self) -> None:
        """Drop the cached connection (if any); the stub stays usable."""
        with self._lock:
            if self._conn is not None:
                self._conn.close()
                self._conn = None
