"""Shared-memory frame ring: the ``shm://`` data plane (paper §3.1 adjacency).

Co-located client↔worker pairs skip the serialize→socket→deserialize round
trip entirely: the worker encodes each element batch *directly* into a slot
of a POSIX shared-memory segment (``memoryview``-based encode, no
intermediate ``bytes``), and the client decodes buffer views straight out of
the slot.  Only a tiny descriptor — ``(slot, length, seq)`` — travels on the
existing RPC control channel, so ordering, retries and failure handling all
stay on the one code path the ``tcp://`` transport already exercises.

Topology is strictly SPSC per ring: ONE worker produces into it, ONE client
session consumes from it (the client's fetch-window threads share the ring;
worker-side slot allocation is serialized by an internal lock).  Slots are
fixed-size frames; a frame larger than ``slot_bytes`` falls back to the
inline RPC payload transparently.

Lease protocol
--------------
* worker: ``try_acquire()`` → write frame into ``slot_view(slot)`` →
  ``commit(slot, length)`` → ship the descriptor in the RPC response.
  ``try_acquire()`` returning ``None`` (ring full — the consumer is behind)
  means *fall back inline for this response*; production never blocks on
  the ring, so a consumer that stops releasing (crash, abandoned iterator)
  degrades throughput but never deadlocks the worker.
* client: ``payload(slot, length, seq)`` → decode (views borrow the slot) →
  ``release(slot)`` once the decoded views are dead (copied out, or the
  consumer advanced past the zero-copy lease).

Crash safety: slots leased to a dead client are never reclaimed — the
worker simply finds the ring full and serves inline; the segment itself is
``unlink``-ed by the owning worker on ``stop()``.  An attached (non-owner)
ring is explicitly unregistered from the CPython ``resource_tracker`` —
otherwise the *attaching* process's tracker would unlink a segment the
worker still owns when that process exits (CPython registers on attach,
not only on create).
"""
from __future__ import annotations

import struct
import threading
import uuid
from multiprocessing import resource_tracker, shared_memory
from typing import List, Optional

# /dev/shm names created by this module all carry this prefix so test
# harnesses (tests/conftest.py) can sweep for leaked segments without
# tripping over unrelated system segments.
SEGMENT_PREFIX = "repro_ring_"

_MAGIC = 0x52503147  # "RP1G"
_HEADER = struct.Struct("<IIQQ")  # magic, slots, slot_bytes, reserved
_SLOT_REC = struct.Struct("<B3xIQ")  # state, seq, committed length
_PAYLOAD_ALIGN = 4096

FREE, LEASED = 0, 1

# Segment names created by THIS process: lets attach() skip the
# resource-tracker unregister when creator and attacher share a process
# (the common single-process test topology), where unregistering would
# strip the creator's own registration and make its unlink() complain.
_OWNED_NAMES: set = set()

DEFAULT_SLOTS = 8
DEFAULT_SLOT_BYTES = 16 << 20  # generous: ftruncate'd pages cost nothing
MAX_RING_BYTES = 512 << 20  # cap a single attach request


class ShmRingError(RuntimeError):
    """Ring-protocol violation (bad magic, stale seq, bad geometry)."""


def _payload_offset(slots: int) -> int:
    raw = _HEADER.size + slots * _SLOT_REC.size
    return (raw + _PAYLOAD_ALIGN - 1) // _PAYLOAD_ALIGN * _PAYLOAD_ALIGN


class ShmRing:
    """SPSC ring of fixed-size frame slots over ``multiprocessing.shared_memory``."""

    def __init__(
        self, shm: shared_memory.SharedMemory, slots: int, slot_bytes: int, owner: bool
    ):
        self._shm = shm
        self.slots = slots
        self.slot_bytes = slot_bytes
        self.owner = owner
        self._payload_off = _payload_offset(slots)
        self._lock = threading.Lock()  # serializes producer-side allocation
        self._seq = 0
        self._views: List[Optional[memoryview]] = [None] * slots
        self._closed = False

    # ------------------------------------------------------------------
    # Construction
    # ------------------------------------------------------------------
    @classmethod
    def create(
        cls, slots: int = DEFAULT_SLOTS, slot_bytes: int = DEFAULT_SLOT_BYTES
    ) -> "ShmRing":
        """Create and own a new ring segment (worker side)."""
        slots = max(1, int(slots))
        slot_bytes = max(4096, int(slot_bytes))
        size = _payload_offset(slots) + slots * slot_bytes
        if size > MAX_RING_BYTES:
            raise ShmRingError(f"ring geometry too large: {size} bytes")
        name = SEGMENT_PREFIX + uuid.uuid4().hex[:16]
        shm = shared_memory.SharedMemory(name=name, create=True, size=size)
        _OWNED_NAMES.add(shm.name)
        _HEADER.pack_into(shm.buf, 0, _MAGIC, slots, slot_bytes, 0)
        # slot table is already zeroed (fresh pages): every slot starts FREE
        return cls(shm, slots, slot_bytes, owner=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        """Attach to an existing ring by segment name (client side)."""
        shm = shared_memory.SharedMemory(name=name)
        # CPython registers shared memory with the resource tracker on
        # ATTACH as well as create; without this unregister, the attaching
        # process's tracker unlinks the worker's segment at exit.  When the
        # attacher IS the creator's process (single-process deployments),
        # keep the registration — it belongs to the creator.
        if shm.name not in _OWNED_NAMES:
            try:
                resource_tracker.unregister(shm._name, "shared_memory")
            except Exception:
                pass  # tracker bookkeeping only; never fail an attach on it
        magic, slots, slot_bytes, _ = _HEADER.unpack_from(shm.buf, 0)
        if magic != _MAGIC:
            shm.close()
            raise ShmRingError(f"segment {name} is not a repro ring")
        return cls(shm, slots, slot_bytes, owner=False)

    @property
    def name(self) -> str:
        return self._shm.name

    # ------------------------------------------------------------------
    # Producer side (worker)
    # ------------------------------------------------------------------
    def try_acquire(self) -> Optional[int]:
        """Claim a FREE slot for writing, or ``None`` when the ring is full."""
        with self._lock:
            for i in range(self.slots):
                off = _HEADER.size + i * _SLOT_REC.size
                if self._shm.buf[off] == FREE:
                    self._shm.buf[off] = LEASED
                    return i
        return None

    def commit(self, slot: int, length: int) -> int:
        """Publish a written frame; returns the descriptor ``seq``."""
        with self._lock:
            self._seq = (self._seq + 1) & 0xFFFFFFFF
            seq = self._seq
        _SLOT_REC.pack_into(
            self._shm.buf, _HEADER.size + slot * _SLOT_REC.size, LEASED, seq, length
        )
        return seq

    def cancel(self, slot: int) -> None:
        """Return an acquired-but-unwritten slot to the free pool."""
        self.release(slot)

    # ------------------------------------------------------------------
    # Consumer side (client)
    # ------------------------------------------------------------------
    def payload(self, slot: int, length: int, seq: Optional[int] = None) -> memoryview:
        """Borrow a read view of a committed frame.

        The view (and anything decoded zero-copy from it) is valid until
        ``release(slot)``; with ``seq`` the slot record is checked against
        the descriptor so a protocol bug surfaces as ``ShmRingError``
        instead of silent corruption.
        """
        if not 0 <= slot < self.slots or length > self.slot_bytes:
            raise ShmRingError(f"bad descriptor: slot={slot} len={length}")
        if seq is not None:
            state, rec_seq, rec_len = _SLOT_REC.unpack_from(
                self._shm.buf, _HEADER.size + slot * _SLOT_REC.size
            )
            if state != LEASED or rec_seq != seq or rec_len != length:
                raise ShmRingError(
                    f"stale descriptor: slot={slot} seq={seq} "
                    f"(slot record: state={state} seq={rec_seq} len={rec_len})"
                )
        return self.slot_view(slot)[:length]

    def release(self, slot: int) -> None:
        """Return a consumed slot to the producer's free pool."""
        _SLOT_REC.pack_into(
            self._shm.buf, _HEADER.size + slot * _SLOT_REC.size, FREE, 0, 0
        )

    # ------------------------------------------------------------------
    # Shared
    # ------------------------------------------------------------------
    def slot_view(self, slot: int) -> memoryview:
        """Full writable view of one slot's payload area (cached export)."""
        v = self._views[slot]
        if v is None:
            a = self._payload_off + slot * self.slot_bytes
            v = self._views[slot] = self._shm.buf[a : a + self.slot_bytes]
        return v

    def free_slots(self) -> int:
        return sum(
            1
            for i in range(self.slots)
            if self._shm.buf[_HEADER.size + i * _SLOT_REC.size] == FREE
        )

    def close(self) -> None:
        """Drop this process's mapping (best effort).

        Zero-copy consumers may still hold numpy views into the mapping;
        closing then raises ``BufferError`` — we leave the mmap for GC in
        that case rather than invalidating live arrays.
        """
        if self._closed:
            return
        for i, v in enumerate(self._views):
            if v is not None:
                try:
                    v.release()
                except BufferError:
                    self._leave_mapping_to_exit()
                    return
                self._views[i] = None
        try:
            self._shm.close()
        except BufferError:
            self._leave_mapping_to_exit()
            return
        self._closed = True

    def _leave_mapping_to_exit(self) -> None:
        # A borrowed view outlived us; the mapping can only go away at
        # process exit.  Shadow SharedMemory.close so its __del__ doesn't
        # retry the doomed mmap close and print BufferError noise.
        self._shm.close = lambda: None  # type: ignore[method-assign]
        self._closed = True

    def unlink(self) -> None:
        """Remove the segment name (owner side; mappings survive unlink)."""
        if not self.owner:
            return
        _OWNED_NAMES.discard(self._shm.name)
        try:
            self._shm.unlink()
        except FileNotFoundError:
            pass

    def __del__(self) -> None:
        # Release the cached slot views BEFORE SharedMemory.__del__ tries to
        # close its mmap — otherwise every GC'd ring spews "BufferError:
        # cannot close exported pointers exist" noise at interpreter exit.
        try:
            self.close()
        except Exception:
            pass
