"""tf.data-service worker (paper §3.1): stateless data-plane node.

A worker executes *tasks* (one per job) shipped by the dispatcher as
serialized pipeline graphs.  Four runner flavors:

* buffered   — OFF/STATIC policies: background producer into a bounded queue.
* dynamic    — DYNAMIC policy: pulls disjoint shards from the dispatcher
               first-come-first-served, optionally checkpointing element
               offsets for exactly-once-style recovery.
* shared     — ephemeral data sharing (§3.5): jobs attach pointers to a
               worker-global SlidingWindowCache keyed by pipeline fingerprint.
* coordinated— coordinated reads (§3.6): serves round-indexed, same-bucket
               batches; all consumers of round r read from this worker.

Statelessness: a restarted worker re-registers and receives its tasks anew;
it never persists local state (paper §3.4).
"""
from __future__ import annotations

import logging
import socket
import threading
import time
from collections import deque
from typing import Any, Dict, Iterator, List, Optional, Set, Tuple, Type

from ..data.elements import (
    Element,
    FrameTooLarge,
    element_nbytes,
    encode_element,
    encode_elements,
    encode_elements_into,
)
from ..data.executors import make_executor
from ..data.graph import Graph
from ..data.iterators import ExecContext, build_iterator
from ..obs.profiling import attribute_stalls, merge_profiles, profile_ops
from ..obs.registry import MetricsRegistry
from ..obs.tracing import TraceContext, Tracer
from ..snapshot.format import ChunkRecord
from ..snapshot.writer import StreamReassigned, StreamWriter
from .cache import SlidingWindowCache
from .shm_ring import (
    DEFAULT_SLOT_BYTES,
    DEFAULT_SLOTS,
    ShmRing,
    ShmRingError,
)
from .protocol import (
    DATA_PLANE_VERSION,
    DEFAULT_MAX_BATCH,
    FetchStatus,
    ShardingPolicy,
    new_id,
)
from .transport import INPROC, Backoff, Stub, TCPServer, TransportError, compress


logger = logging.getLogger(__name__)


class WorkerMetrics:
    """Cumulative worker counters, hammered concurrently by every runner
    producer thread and every data-plane handler thread.

    Now a facade over :class:`repro.obs.registry.MetricsRegistry` — each
    counter is a registry family named ``worker_<field>`` so the same
    numbers the heartbeat reports are scraped by ``metrics_dump`` / the
    fleet dashboard with no second bookkeeping path.  The exactness
    contract is unchanged: every mutation is serialized per-series (a bare
    ``+=`` loses updates under thread switches, and ``busy_time`` feeds the
    autoscaler's ``cpu_busy`` signal, so lost updates read as idle
    capacity); ``snapshot()`` stays lock-free for readers.
    """

    _COUNTERS = ("batches_produced", "batches_served", "bytes_served", "rpc_count", "busy_time")
    _GAUGES = ("pending_responses",)

    def __init__(self, registry: Optional[MetricsRegistry] = None):
        self.registry = registry if registry is not None else MetricsRegistry()
        self._series: Dict[str, Any] = {}
        for name in self._COUNTERS:
            self._series[name] = self.registry.counter(
                f"worker_{name}", "cumulative worker data-plane counter"
            )
        for name in self._GAUGES:
            self._series[name] = self.registry.gauge(
                f"worker_{name}", "current worker data-plane level"
            )

    def add(self, **deltas: float) -> None:
        for name, delta in deltas.items():
            self._series[name].add(delta)

    def snapshot(self) -> Dict[str, Any]:
        """Point-in-time copy for heartbeats/stats (never blocks writers)."""
        return {name: s.value for name, s in self._series.items()}


class _TaskRunner:
    status: str = "running"  # running | done

    def __init__(self) -> None:
        self._stopped = threading.Event()
        # every pipeline this runner executed keeps its ExecContext here so
        # per-op timings survive shard restarts and roll up in op_profile()
        self._ctxs: List[ExecContext] = []

    def _new_ctx(self) -> ExecContext:
        # fresh context per build_iterator call: sharing one would replay
        # the `cache` op's store across shards; stats are merged at rollup
        ctx = ExecContext()
        self._ctxs.append(ctx)
        return ctx

    def op_profile(self) -> List[Dict[str, Any]]:
        """Per-op wall/CPU/element rollup across every pipeline context this
        runner has executed (feeds metrics_dump + stall attribution)."""
        return merge_profiles(profile_ops(c.stats) for c in list(self._ctxs))

    def get(self, job_id: str, round_index: int, consumer_index: int):
        raise NotImplementedError

    def get_many(self, job_id: str, max_batch: int, timeout: float = 0.0):
        """Drain up to ``max_batch`` ready elements (batched data plane).

        Returns ``(status, elements)``: OK with a non-empty list when
        anything was ready, otherwise the blocking status (PENDING /
        END_OF_TASK) with an empty list.  ``timeout`` is a long-poll bound:
        implementations MAY wait up to that long for the first element
        (the base implementation is non-blocking).
        """
        out: List[Element] = []
        status = FetchStatus.PENDING
        for _ in range(max_batch):
            status, elem = self.get(job_id, -1, -1)
            if status != FetchStatus.OK:
                break
            out.append(elem)
        if out:
            return FetchStatus.OK, out
        return status, out

    def buffer_occupancy(self) -> float:
        return 0.0

    def extra_stats(self) -> Dict[str, Any]:
        return {}

    def stop(self) -> None:
        self._stopped.set()


class _BufferedRunner(_TaskRunner):
    """OFF / STATIC: produce into a bounded deque from a background thread."""

    def __init__(self, worker: "Worker", spec: Dict[str, Any], buffer_size: int):
        super().__init__()
        self._worker = worker
        self._spec = spec
        # the job's root trace context rides in the task spec (journaled
        # dispatcher-side, so it survives failover); pipeline spans parent
        # to it and sample at the minting client's rate
        self._trace = TraceContext.from_wire(spec.get("trace"))
        self._buffer: deque = deque()
        self._buffer_size = buffer_size
        self._cond = threading.Condition()
        self._done = False
        self._thread = threading.Thread(target=self._produce, daemon=True)
        self._thread.start()

    def _iterate(self) -> Iterator[Element]:
        graph = Graph.from_bytes(self._spec["graph_bytes"])
        policy = ShardingPolicy(self._spec["policy"])
        executor = self._worker._executor
        tid = self._spec.get("task_id", "")
        if policy == ShardingPolicy.STATIC:
            for k, shard in enumerate(self._spec.get("static_shards") or []):
                g = graph.bind_shard(shard).bind_seed(self._spec["worker_seed"])
                # per-shard affinity: every element of static shard k comes
                # from the same executor lane, preserving in-thread ordering
                for _seq, elem in executor.iterate(
                    g, self._new_ctx(), affinity=f"{tid}/{k}"
                ):
                    yield elem
        else:  # OFF: whole dataset, worker-specific order
            g = graph.bind_seed(self._spec["worker_seed"])
            for _seq, elem in executor.iterate(
                g, self._new_ctx(), affinity=tid or "off"
            ):
                yield elem

    def _produce(self) -> None:
        try:
            self._pump(self._iterate())
        except Exception as e:  # pipeline failure: surface, then finish
            self._worker._note_error(
                f"task {self._spec.get('task_id')} pipeline", e
            )
        finally:
            with self._cond:
                self._done = True
                self._cond.notify_all()

    def _pump(self, elements: Iterator[Element]) -> None:
        """Drive one element stream into the shared bounded buffer."""
        tracer = self._worker.tracer
        last = time.perf_counter()
        for elem in elements:
            t0 = time.perf_counter()
            with self._cond:
                while len(self._buffer) >= self._buffer_size:
                    if self._worker._stopping.is_set() or self._stopped.is_set():
                        return
                    self._cond.wait(timeout=0.1)
                self._buffer.append(elem)
                self._cond.notify_all()
            self._worker.metrics.add(
                batches_produced=1, busy_time=time.perf_counter() - t0
            )
            if self._trace is not None and tracer.should_sample(self._trace.sample):
                # pipeline-execution span: production time of this
                # element (iterator pull), excluding the buffer wait
                dur = t0 - last
                tracer.record(
                    "worker.pipeline",
                    self._trace.child(),
                    time.time() - dur,
                    dur,
                    parent_id=self._trace.span_id,
                    task_id=self._spec.get("task_id"),
                )
            last = time.perf_counter()
            if self._stopped.is_set():
                return

    def get(self, job_id: str, round_index: int, consumer_index: int):
        with self._cond:
            if self._buffer:
                elem = self._buffer.popleft()
                self._cond.notify_all()
                return FetchStatus.OK, elem
            if self._done:
                self.status = "done"
                return FetchStatus.END_OF_TASK, None
            return FetchStatus.PENDING, None

    def get_many(self, job_id: str, max_batch: int, timeout: float = 0.0):
        # Single lock acquisition for the whole drain (vs. max_batch round
        # trips through get()); the producer refills concurrently.  The
        # long-poll wait releases the lock, so production proceeds while we
        # wait for the first element.
        deadline = time.perf_counter() + max(0.0, timeout)
        with self._cond:
            while not self._buffer and not self._done:
                remaining = deadline - time.perf_counter()
                if remaining <= 0 or self._stopped.is_set():
                    return FetchStatus.PENDING, []
                self._cond.wait(remaining)
            if not self._buffer:  # done and drained
                self.status = "done"
                return FetchStatus.END_OF_TASK, []
            out = []
            while self._buffer and len(out) < max_batch:
                out.append(self._buffer.popleft())
            self._cond.notify_all()
            return FetchStatus.OK, out

    def buffer_occupancy(self) -> float:
        with self._cond:
            return len(self._buffer) / max(1, self._buffer_size)

    def stop(self) -> None:
        self._stopped.set()
        with self._cond:
            self._cond.notify_all()


class _DynamicRunner(_BufferedRunner):
    """DYNAMIC: pull disjoint shards from the dispatcher FCFS (paper §3.3).

    Elements travel through the buffer annotated with (shard, offset) so the
    runner knows exactly how far each shard has been DELIVERED to clients —
    not just produced into the buffer.  Offset checkpoints report the
    delivered watermark (always ≤ delivered, so re-queuing at it never
    skips an undelivered element), and a pruned runner files one final
    truth-report through the redelivery queue so the dispatcher's deferred
    task-retirement reclaim resumes the shard at the exact delivered
    position: 0 duplicates, 0 lost, even when the checkpoints sent during a
    dispatcher outage were dropped.
    """

    CHECKPOINT_EVERY = 64

    def __init__(self, worker: "Worker", spec: Dict[str, Any], buffer_size: int):
        # watermarks must exist before the base ctor starts the producer
        self._delivered: Dict[int, int] = {}  # shard_id -> delivered offset
        # shards currently mid-production, one per pump thread (the pool
        # executor runs several shard streams concurrently)
        self._active_shards: Set[int] = set()
        # serializes get_shard hand-out + _active_shards registration across
        # pump threads: a concurrent get_shard whose `holding` snapshot
        # misses a shard another pump just accepted would trick the
        # dispatcher's reconciliation into re-queuing it (duplicates)
        self._shard_lock = threading.Lock()
        super().__init__(worker, spec, buffer_size)

    def _produce(self) -> None:
        # With a process-pool engine the GIL no longer serializes pipeline
        # work, so run one shard pump per executor lane: each pump pulls its
        # own shards FCFS and pushes into the shared bounded buffer.  Width 1
        # (in-thread engine) keeps the paper's single sequential stream.
        width = max(1, int(getattr(self._worker._executor, "width", 1)))
        if width <= 1:
            super()._produce()
            return
        pumps = [
            threading.Thread(
                target=self._pump_guarded, daemon=True, name=f"dyn-pump-{i}"
            )
            for i in range(width)
        ]
        for t in pumps:
            t.start()
        for t in pumps:
            t.join()
        with self._cond:
            self._done = True
            self._cond.notify_all()

    def _pump_guarded(self) -> None:
        try:
            self._pump(self._iterate())
        except Exception as e:
            self._worker._note_error(
                f"task {self._spec.get('task_id')} pipeline", e
            )

    def _iterate(self) -> Iterator[Element]:
        graph = Graph.from_bytes(self._spec["graph_bytes"])
        job_id = self._spec["job_id"]
        wid = self._worker.worker_id
        backoff = Backoff(base=0.05, cap=1.0)
        while not self._worker._stopping.is_set() and not self._stopped.is_set():
            # the lock spans RPC -> _active_shards registration: the holding
            # snapshot must be consistent with what the dispatcher journals,
            # or a concurrent pump's snapshot re-queues this grant
            with self._shard_lock:
                try:
                    # The lock is per-job, per-worker, and holding it across
                    # the (timeout-bounded) RPC is the whole point: sibling
                    # pumps must not snapshot `holding` mid-grant.
                    # analysis: allow(D001, L003)
                    resp = self._worker._dispatcher.call(
                        "get_shard",
                        job_id=job_id,
                        worker_id=wid,
                        # shard ids we hold: mid-production on any pump, plus
                        # journaled-but-unacked completions — lets a freshly
                        # promoted dispatcher re-queue ONLY assignments whose
                        # response died with the old primary (never received)
                        holding=self._held_shards(job_id),
                    )
                except TransportError:
                    resp = None
                else:
                    if not resp.get("done") and not resp.get("wait"):
                        sid = resp["shard_id"]
                        self._delivered.setdefault(sid, resp.get("offset", 0))
                        self._active_shards.add(sid)
            if resp is None:
                # dispatcher down: no NEW shards can be handed out, but we keep
                # serving what we have (paper §3.4) — retry with jittered
                # backoff so a worker fleet doesn't stampede the standby.
                self._stopped.wait(backoff.next_delay())
                continue
            backoff.reset()
            if resp.get("done"):
                return
            if resp.get("wait"):  # queue empty but a shard may be re-queued
                time.sleep(0.05)
                continue
            sid, shard, offset = resp["shard_id"], resp["shard"], resp.get("offset", 0)
            g = graph.bind_shard(shard).bind_seed(self._spec["worker_seed"] + sid)
            produced = 0
            # shard affinity `{job}/{sid}` pins this shard's whole element
            # stream to one executor lane: per-stream seed + resume offset
            # behave exactly as in-thread.  The executor skips the resumed
            # prefix at the source and yields the absolute offset (i+1).
            for abs_off, elem in self._worker._executor.iterate(
                g,
                self._new_ctx(),
                affinity=f"{job_id}/{sid}",
                offset=offset,
            ):
                produced += 1
                yield (elem, sid, abs_off)  # get()/get_many() strip the tag
                if (
                    self._spec.get("resume_offsets")
                    and produced % self.CHECKPOINT_EVERY == 0
                ):
                    # checkpoint the DELIVERED watermark, not the produced
                    # position: elements still in the buffer would be lost
                    # to a re-queue that skips past them
                    self._try_call(
                        "checkpoint_offset",
                        job_id=job_id,
                        shard_id=sid,
                        worker_id=wid,
                        offset=self._delivered[sid],
                    )
            # complete BEFORE dropping from _active_shards: between the two,
            # another pump's get_shard must still report this shard as held
            # (a lost completion ack re-enters via _pending_control instead)
            self._try_call(
                "complete_shard", job_id=job_id, shard_id=sid, worker_id=wid
            )
            self._active_shards.discard(sid)

    def _unwrap(self, entry: Any) -> Element:
        elem, sid, off = entry
        self._delivered[sid] = off  # pops follow production order: monotonic
        return elem

    def get(self, job_id: str, round_index: int, consumer_index: int):
        status, entry = super().get(job_id, round_index, consumer_index)
        if entry is None:
            return status, None
        return status, self._unwrap(entry)

    def get_many(self, job_id: str, max_batch: int, timeout: float = 0.0):
        status, entries = super().get_many(job_id, max_batch, timeout)
        return status, [self._unwrap(e) for e in entries]

    def stop(self) -> None:
        super().stop()
        if self._spec.get("resume_offsets"):
            # Pruned mid-shard (task retirement): file one final offset
            # truth-report per in-flight shard through the redelivery
            # queue.  It drains on the next heartbeat — before the
            # dispatcher's second-heartbeat reclaim — so the re-queue
            # resumes at exactly the delivered position even though
            # checkpoints sent while the dispatcher was down were dropped.
            for sid in sorted(self._active_shards):
                self._worker._pending_control.append(
                    (
                        "checkpoint_offset",
                        {
                            "job_id": self._spec["job_id"],
                            "shard_id": sid,
                            "worker_id": self._worker.worker_id,
                            "offset": self._delivered.get(sid, 0),
                        },
                    )
                )

    def _held_shards(self, job_id: str) -> List[int]:
        """Shard ids the dispatcher may see as assigned to us that must NOT
        be re-queued: shards mid-production on any pump thread
        (``_active_shards`` — with a process-pool executor several run
        concurrently) plus shards finished but not yet acknowledged (queued
        ``complete_shard`` redeliveries)."""
        held = set(self._active_shards)
        held.update(
            kw["shard_id"]
            for (m, kw) in list(self._worker._pending_control)
            if m == "complete_shard" and kw.get("job_id") == job_id
        )
        return sorted(held)

    def _try_call(self, method: str, **kw: Any) -> None:
        try:
            self._worker._dispatcher.call(method, **kw)
        except TransportError:
            # dispatcher down: completions are liveness-critical (an
            # uncompleted shard blocks job finish) — queue for redelivery
            # from the heartbeat loop once the dispatcher is back.
            if method == "complete_shard":
                self._worker._pending_control.append((method, kw))


class _SharedRunner(_TaskRunner):
    """Ephemeral data sharing (§3.5): read via the worker-global cache."""

    def __init__(self, worker: "Worker", spec: Dict[str, Any]):
        super().__init__()
        self._worker = worker
        self._cache = worker._get_or_create_cache(spec)
        self._cache.attach(spec["job_id"])
        # profile the shared producer pipeline (one ctx per cache, owned by
        # the worker; all attached jobs see the same rollup)
        ctx = worker._cache_ctxs.get(spec["cache_key"] or spec["dataset_id"])
        if ctx is not None:
            self._ctxs.append(ctx)

    def get(self, job_id: str, round_index: int, consumer_index: int):
        t0 = time.perf_counter()
        batch, eos = self._cache.read(job_id)
        self._worker.metrics.add(busy_time=time.perf_counter() - t0)
        if eos:
            # Single monotonic str store (running -> done) read by the
            # heartbeat thread; atomic under the GIL, so no lock needed.
            self.status = "done"  # analysis: allow(L001)
            return FetchStatus.END_OF_TASK, None
        return FetchStatus.OK, batch

    def buffer_occupancy(self) -> float:
        lo, hi = self._cache.window_range()
        return min(1.0, (hi - lo) / max(1, self._cache._capacity))


class _CoordinatedRunner(_TaskRunner):
    """Coordinated reads (§3.6): round-indexed same-bucket batch service.

    The element stream arrives pre-grouped (bucket_by_sequence_length →
    group_by_window(m) → flat_map upstream), so m consecutive elements form
    one round's same-bucket window.  All m consumers of round r read their
    ``consumer_index``-th element of that window from this worker.  Windows
    materialize lazily in round order; finished rounds are GC'd once every
    consumer has read its slot.
    """

    MAX_BUFFERED_ROUNDS = 8

    def __init__(self, worker: "Worker", spec: Dict[str, Any]):
        super().__init__()
        self._worker = worker
        self._m = max(1, int(spec["num_consumers"]))
        graph = Graph.from_bytes(spec["graph_bytes"]).bind_seed(spec["worker_seed"])
        self._it = build_iterator(graph, self._new_ctx())
        self._lock = threading.Lock()
        self._rounds: Dict[int, List[Element]] = {}  # round -> window
        self._consumed: Dict[int, set] = {}
        self._served_rounds: set = set()  # fully-consumed (GC'd) rounds
        self._exhausted = False
        self.evictions = 0

    def _materialize(self, round_index: int) -> bool:
        """Produce ONE window and bind it to ``round_index``.

        Global round numbers are striped across workers (round r is served by
        worker r mod n), so this worker only materializes windows for the
        rounds actually directed at it — window identity per round is what
        matters, not global ordering.

        Skew control: a fast consumer may request rounds far ahead of a slow
        one.  Evicting the slow consumer's pending window would strand it in
        a PENDING retry loop forever, so instead the fast consumer WAITS —
        we refuse to materialize more than MAX_BUFFERED_ROUNDS windows and
        return PENDING, bounding consumer skew (the paper's "predetermined
        round-robin client-side buffer slots" imply the same backpressure).
        """
        if len(self._rounds) >= self.MAX_BUFFERED_ROUNDS:
            self.evictions += 1  # counted as backpressure events
            return False
        window: List[Element] = []
        t0 = time.perf_counter()
        for _ in range(self._m):
            try:
                window.append(next(self._it))
            except StopIteration:
                self._exhausted = True
                break
        self._worker.metrics.add(busy_time=time.perf_counter() - t0)
        if len(window) < self._m:
            return False
        self._rounds[round_index] = window
        self._consumed[round_index] = set()
        self._worker.metrics.add(batches_produced=self._m)
        return True

    def extra_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "coordinated_rounds_served": len(self._served_rounds),
                "coordinated_evictions": self.evictions,
                "coordinated_rounds_buffered": len(self._rounds),
            }

    def get(self, job_id: str, round_index: int, consumer_index: int):
        with self._lock:
            if round_index not in self._rounds:
                if round_index in self._served_rounds:
                    # consumer retry after GC (shouldn't happen with one read
                    # per consumer per round) — treat as pending
                    return FetchStatus.PENDING, None
                if self._exhausted or not self._materialize(round_index):
                    if self._exhausted:
                        self.status = "done"
                        return FetchStatus.END_OF_TASK, None
                    return FetchStatus.PENDING, None
            elem = self._rounds[round_index][consumer_index % self._m]
            self._consumed[round_index].add(consumer_index % self._m)
            if len(self._consumed[round_index]) == self._m:
                del self._rounds[round_index]
                del self._consumed[round_index]
                self._served_rounds.add(round_index)
            return FetchStatus.OK, elem

    def get_many(self, job_id: str, max_batch: int, timeout: float = 0.0):
        raise ValueError(
            "coordinated tasks are round-indexed; use get_element with a "
            "round_index (batched fetch would break same-bucket rounds)"
        )

    def buffer_occupancy(self) -> float:
        with self._lock:
            return len(self._rounds) / self.MAX_BUFFERED_ROUNDS


class _SnapshotStreamRunner:
    """Materializes ONE snapshot stream on this worker (repro.snapshot).

    Runs the stream's pipeline shards through the normal execution engine
    and appends the output into a ``StreamWriter`` (size-bounded chunks,
    atomic commit, manifest update, dispatcher ack).  ``resume_offset``
    skips the element prefix a previous owner already committed — streams
    are seeded per STREAM (not per worker), so a replacement re-produces
    the identical element sequence and commit races converge bytewise.
    """

    def __init__(self, worker: "Worker", spec: Dict[str, Any]):
        self._worker = worker
        self._spec = spec
        self.status = "running"  # running | done | stopped | failed
        self.error: Optional[str] = None
        self._stopped = threading.Event()
        self._ctxs: List[ExecContext] = []
        self.writer = StreamWriter(
            spec["path"],
            spec["stream_id"],
            codec=spec.get("codec"),
            chunk_bytes=spec["chunk_bytes"],
            committed=[ChunkRecord(*c) for c in spec.get("committed", [])],
            on_commit=self._report_commit,
        )
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stopped.set()

    def op_profile(self) -> List[Dict[str, Any]]:
        return merge_profiles(profile_ops(c.stats) for c in list(self._ctxs))

    def _should_stop(self) -> bool:
        return self._worker._stopping.is_set() or self._stopped.is_set()

    def _report_commit(self, rec: ChunkRecord) -> bool:
        sp = self._spec
        kw = dict(
            snapshot_id=sp["snapshot_id"],
            stream_id=sp["stream_id"],
            worker_id=self._worker.worker_id,
            seq=rec.seq,
            count=rec.count,
            nbytes=rec.nbytes,
        )
        if self._worker._pending_control:
            # earlier acks are still queued (dispatcher was down): keep this
            # one BEHIND them so the dispatcher sees seqs in order
            self._worker._pending_control.append(("snapshot_commit_chunk", kw))
            return True
        try:
            resp = self._worker._dispatcher.call("snapshot_commit_chunk", **kw)
        except TransportError:
            # dispatcher down: the chunk is already durable on shared
            # storage; queue the ack for redelivery (heartbeat loop drains
            # in order once the dispatcher is back) and keep writing —
            # the restored dispatcher validates seqs consecutively.
            self._worker._pending_control.append(("snapshot_commit_chunk", kw))
            return True
        if resp.get("ok"):
            return True
        if resp.get("retry"):
            # seq gap dispatcher-side: queued acks haven't drained yet
            self._worker._pending_control.append(("snapshot_commit_chunk", kw))
            return True
        return False  # reassigned: a replacement owns this stream now

    def _run(self) -> None:
        sp = self._spec
        graph = Graph.from_bytes(sp["graph_bytes"])
        skip = int(sp.get("resume_offset", 0))
        produced = 0
        try:
            for shard in sp["shards"]:
                g = graph.bind_shard(shard).bind_seed(sp["seed"])
                ctx = ExecContext()
                self._ctxs.append(ctx)  # retained for op profiling
                # stream affinity: the whole stream (all its shards) runs on
                # one executor lane — per-STREAM seeding stays intact, so a
                # pooled worker re-produces the byte-identical sequence an
                # in-thread one would.  The committed-prefix skip stays
                # parent-side: `produced` must count EVERY element.
                for _seq, elem in self._worker._executor.iterate(
                    g,
                    ctx,
                    affinity=f"snap/{sp['snapshot_id']}/{sp['stream_id']}",
                ):
                    if self._should_stop():
                        self.writer.abort()
                        self.status = "stopped"
                        return
                    produced += 1
                    if produced <= skip:
                        continue  # committed by a previous owner
                    t0 = time.perf_counter()
                    self.writer.append(elem)
                    self._worker.metrics.add(busy_time=time.perf_counter() - t0)
            self.writer.finish()
            self.status = "done"
            self._report_done()
        except StreamReassigned:
            self.status = "stopped"  # a replacement owns the stream now
        except Exception as e:  # surface in worker stats, don't kill the worker
            # Log-first-instance (the autoscaler's pattern): a stream that
            # fails every retry would otherwise die in silence — the status
            # travels in heartbeats, but nobody greps heartbeats.
            self._worker._note_error(
                f"snapshot stream {self._spec['stream_id']}", e
            )
            self.status = "failed"
            self.error = repr(e)

    def _report_done(self) -> None:
        kw = dict(
            snapshot_id=self._spec["snapshot_id"],
            stream_id=self._spec["stream_id"],
            worker_id=self._worker.worker_id,
        )
        if self._worker._pending_control:
            # keep the done-report ordered behind any queued chunk acks
            self._worker._pending_control.append(("snapshot_stream_done", kw))
            return
        try:
            self._worker._dispatcher.call("snapshot_stream_done", **kw)
        except TransportError:
            self._worker._pending_control.append(("snapshot_stream_done", kw))


class Worker:
    def __init__(
        self,
        dispatcher_address: str,
        worker_id: Optional[str] = None,
        transport: str = "inproc",
        buffer_size: int = 8,
        heartbeat_interval: float = 0.5,
        cache_capacity: int = 16,
        tags: Optional[Dict[str, Any]] = None,
        worker_processes: int = 0,
        host_key: Optional[str] = None,
    ):
        self.worker_id = worker_id or new_id("worker")
        self.registry = MetricsRegistry()
        self.metrics = WorkerMetrics(self.registry)
        self.tracer = Tracer(process=f"worker:{self.worker_id}")
        # worker_processes=0 keeps the paper's in-thread engine; N>=1 runs
        # pipelines in a pool of N forked children (data.executors)
        self._executor = make_executor(worker_processes)
        # host identity for client-side shm:// co-location detection;
        # advertised in register_worker tags and the ping response
        self._host_key = host_key or socket.gethostname()
        # shm data-plane channels negotiated by co-located clients:
        # channel_id -> owned ShmRing (created by rpc_shm_attach)
        self._shm_channels: Dict[str, ShmRing] = {}
        self._cache_ctxs: Dict[str, ExecContext] = {}
        # rolling per-op rollup of pruned (finished) tasks, so the stall
        # report still names the bottleneck after a job completes; merged
        # by (op index, name) so it stays a handful of rows, not a history
        self._retired_profiles: List[Dict[str, Any]] = []
        self._dispatcher = Stub(dispatcher_address)
        self._transport = transport
        self._buffer_size = buffer_size
        self._hb_interval = heartbeat_interval
        self._cache_capacity = cache_capacity
        # host rides in tags (NOT journaled beyond worker_id/address — the
        # dispatcher keeps tags in memory only) so list_workers/negotiation
        # can see where each worker runs; explicit user tags win on clash
        self._tags = {"host": self._host_key, **(tags or {})}
        self._tasks: Dict[str, _TaskRunner] = {}
        self._task_specs: Dict[str, Dict[str, Any]] = {}
        self._caches: Dict[str, SlidingWindowCache] = {}
        # (snapshot_id, stream_id) -> runner materializing that stream
        self._snapshot_writers: Dict[Any, _SnapshotStreamRunner] = {}
        self._pending_control: deque = deque()  # control calls to redeliver
        # log-first-instance bookkeeping for background-thread exceptions
        self._logged_errors: Set[Tuple[str, Type[BaseException]]] = set()
        self._lock = threading.RLock()
        self._stopping = threading.Event()
        self._failed = threading.Event()  # simulated crash (tests/benchmarks)
        self._hb_thread: Optional[threading.Thread] = None
        self._tcp: Optional[TCPServer] = None
        self.address = ""

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def start(self) -> "Worker":
        if self._transport == "tcp":
            self._tcp = TCPServer(self).start()
            self.address = self._tcp.address
        elif self._transport == "grpc":
            from .transport import GrpcServer

            self._tcp = GrpcServer(self).start()  # same stop()/address API
            self.address = self._tcp.address
        else:
            self.address = INPROC.bind(self.worker_id, self)
        resp = self._dispatcher.call(
            "register_worker",
            worker_id=self.worker_id,
            address=self.address,
            tags=self._tags,
        )
        for spec in resp.get("tasks", []):
            self._add_task(spec)
        for spec in resp.get("snapshot_streams", []):
            self._add_snapshot_stream(spec)
        self._hb_thread = threading.Thread(target=self._heartbeat_loop, daemon=True)
        self._hb_thread.start()
        return self

    def stop(self) -> None:
        self._stopping.set()
        with self._lock:
            for r in self._tasks.values():
                r.stop()
            for sr in self._snapshot_writers.values():
                sr.stop()
        if self._tcp is not None:
            self._tcp.stop()
        elif self.address:
            INPROC.unbind(self.worker_id)
        self._executor.stop()
        self._release_shm_channels()

    def fail(self) -> None:
        """Simulate a crash: stop serving and heartbeating WITHOUT dispatcher
        notification — failure must be detected via heartbeat timeout."""
        self._failed.set()
        self._stopping.set()
        if self._tcp is not None:
            self._tcp.stop()
        elif self.address:
            INPROC.unbind(self.worker_id)
        # a real crash takes the executor children and /dev/shm segments
        # with it (process death / OS reclaim); emulate that here so the
        # simulated crash leaks neither
        self._executor.stop()
        self._release_shm_channels()

    def _release_shm_channels(self) -> None:
        """Close + unlink every owned shm ring (attached clients keep their
        mappings alive until they release; the NAME disappears now)."""
        with self._lock:
            rings = list(self._shm_channels.values())
            self._shm_channels.clear()
        for ring in rings:
            ring.close()
            ring.unlink()

    # ------------------------------------------------------------------
    # Task management
    # ------------------------------------------------------------------
    def _add_task(self, spec: Dict[str, Any]) -> None:
        with self._lock:
            tid = spec["task_id"]
            if tid in self._tasks:
                return
            if spec.get("shared"):
                runner: _TaskRunner = _SharedRunner(self, spec)
            elif spec.get("round_robin"):
                runner = _CoordinatedRunner(self, spec)
            elif spec["policy"] == ShardingPolicy.DYNAMIC.value:
                runner = _DynamicRunner(self, spec, self._buffer_size)
            else:
                runner = _BufferedRunner(self, spec, self._buffer_size)
            self._tasks[tid] = runner
            self._task_specs[tid] = spec

    def _add_snapshot_stream(self, spec: Dict[str, Any]) -> None:
        key = (spec["snapshot_id"], spec["stream_id"])
        with self._lock:
            existing = self._snapshot_writers.get(key)
            if existing is not None and existing.status in ("running", "done"):
                return  # re-delivery (e.g. after a dispatcher restart)
            self._snapshot_writers[key] = _SnapshotStreamRunner(self, spec)

    def _get_or_create_cache(self, spec: Dict[str, Any]) -> SlidingWindowCache:
        key = spec["cache_key"] or spec["dataset_id"]
        with self._lock:
            if key not in self._caches:
                graph = Graph.from_bytes(spec["graph_bytes"]).bind_seed(
                    spec["worker_seed"]
                )
                ctx = ExecContext()
                self._cache_ctxs[key] = ctx  # retained for op profiling
                producer = build_iterator(graph, ctx)
                self._caches[key] = SlidingWindowCache(
                    producer, capacity=self._cache_capacity
                )
            return self._caches[key]

    def _heartbeat_loop(self) -> None:
        backoff = Backoff(
            base=self._hb_interval, cap=max(1.0, 4 * self._hb_interval)
        )
        delay = self._hb_interval
        while not self._stopping.wait(delay):
            try:
                self._heartbeat_once()
            except TransportError:
                # dispatcher down: keep serving current tasks (§3.4) and
                # retry with jittered backoff — a whole fleet reconnecting
                # to a freshly promoted standby must not thundering-herd it
                delay = backoff.next_delay()
                continue
            backoff.reset()
            delay = self._hb_interval

    def _heartbeat_once(self) -> None:
        """One heartbeat round-trip; raises TransportError when the
        dispatcher is unreachable (the loop above backs off and retries)."""
        while self._pending_control:
            method, kw = self._pending_control[0]
            resp = self._dispatcher.call(method, **kw)  # raises if still down
            self._pending_control.popleft()
            if resp and resp.get("reassigned") and "snapshot_id" in kw:
                # a queued snapshot ack answered "reassigned": a
                # replacement owns the stream — stop our writer
                # (the direct-call path learns this in _report_commit;
                # the queued path must honor it too)
                with self._lock:
                    r = self._snapshot_writers.get(
                        (kw["snapshot_id"], kw["stream_id"])
                    )
                if r is not None:
                    r.stop()
        with self._lock:
            occ = [r.buffer_occupancy() for r in self._tasks.values()]
            completed = [
                tid for tid, r in self._tasks.items() if r.status == "done"
            ]
            # sharing-efficiency counters ride along with every
            # heartbeat so the dispatcher (and the autocache policy)
            # can observe per-fingerprint cache behavior (§3.5)
            cache_stats = {
                k: dict(vars(c.stats), num_jobs=c.num_jobs)
                for k, c in self._caches.items()
            }
            # streams whose writer died on an exception: hand them
            # back so the dispatcher can reassign (possibly to us —
            # a fresh runner retries from the committed offset)
            failed_streams = [
                list(key)
                for key, r in self._snapshot_writers.items()
                if r.status == "failed"
            ]
        resp = self._dispatcher.call(
            "worker_heartbeat",
            worker_id=self.worker_id,
            buffer_occupancy=sum(occ) / len(occ) if occ else 0.0,
            cpu_busy=self.metrics.snapshot()["busy_time"],
            completed_tasks=completed,
            cache_stats=cache_stats,
            failed_streams=failed_streams,
        )
        if failed_streams:
            # the dispatcher has released them; drop the dead
            # runners so a re-assignment starts a fresh one
            with self._lock:
                for key in failed_streams:
                    r = self._snapshot_writers.get(tuple(key))
                    if r is not None and r.status == "failed":
                        del self._snapshot_writers[tuple(key)]
        if resp.get("reregister"):
            resp = self._dispatcher.call(
                "register_worker",
                worker_id=self.worker_id,
                address=self.address,
                tags=self._tags,
            )
            for spec in resp.get("tasks", []):
                self._add_task(spec)
            for spec in resp.get("snapshot_streams", []):
                self._add_snapshot_stream(spec)
            return
        for spec in resp.get("new_tasks", []):
            self._add_task(spec)
        for spec in resp.get("snapshot_streams", []):
            self._add_snapshot_stream(spec)
        valid = resp.get("valid_tasks")
        if valid is not None:
            self._prune_tasks(set(valid))

    def drain_stats(self) -> Dict[str, float]:
        """What scale-in victim selection needs to know (see
        ``LocalOrchestrator.pick_removable``): removing this worker while
        it holds an unfinished snapshot stream forces a stream
        reassignment + re-production, and removing it while it buffers
        unconsumed coordinated rounds stalls every consumer of those
        rounds — both strictly worse than draining an idle worker."""
        with self._lock:
            streams = sum(
                1 for r in self._snapshot_writers.values() if r.status == "running"
            )
            rounds = sum(
                int(r.extra_stats().get("coordinated_rounds_buffered", 0))
                for r in self._tasks.values()
            )
            occ = [r.buffer_occupancy() for r in self._tasks.values()]
        return {
            "active_snapshot_streams": streams,
            "pending_coordinated_rounds": rounds,
            "buffer_occupancy": sum(occ) / len(occ) if occ else 0.0,
        }

    def _note_error(self, context: str, exc: BaseException) -> None:
        """Log the FIRST instance of each (context, exception type) from a
        background thread; repeats are suppressed (the retry loops would
        otherwise flood the log at their poll interval).  Every instance is
        counted in the registry so metrics_dump shows chronic failures the
        log-once policy hides."""
        self.registry.counter(
            "worker_errors_total",
            "swallowed background errors in the worker, by context",
        ).labels(context=context, kind=type(exc).__name__).inc()
        key = (context, type(exc))
        with self._lock:
            if key in self._logged_errors:
                return
            self._logged_errors.add(key)
        logger.warning(
            "worker %s: %s failed with %r (suppressing repeats)",
            self.worker_id, context, exc,
        )

    def _prune_tasks(self, valid: set) -> None:
        """Drop orphaned tasks (finished/garbage-collected jobs), folding
        their op profiles into the retired rollup first."""
        with self._lock:
            pruned = []
            for tid in list(self._tasks):
                if tid not in valid:
                    pruned.append(self._tasks[tid].op_profile())
                    self._tasks[tid].stop()
                    del self._tasks[tid]
                    self._task_specs.pop(tid, None)
            if pruned:
                self._retired_profiles = merge_profiles(
                    [self._retired_profiles, *pruned]
                )

    # ------------------------------------------------------------------
    # RPC entry point (data plane)
    # ------------------------------------------------------------------
    def handle(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        # Same getattr dispatch as Dispatcher.handle: one rpc_* method per
        # wire method, so the RPC-conformance pass sees one uniform surface.
        if self._failed.is_set():
            raise TransportError(f"worker {self.worker_id} is down")
        fn = getattr(self, f"rpc_{method}", None)
        if fn is None:
            raise ValueError(f"worker: unknown method {method}")
        return fn(**payload)

    def rpc_ping(self) -> Dict[str, Any]:
        """Liveness + data-plane version probe (used at worker bring-up and
        by clients negotiating the shm:// data plane: ``host`` is compared
        against the client's own host key, ``shm`` says whether this worker
        can serve ring descriptors at all)."""
        return {
            "worker_id": self.worker_id,
            "data_plane_version": DATA_PLANE_VERSION,
            "host": self._host_key,
            "shm": not self._transport.startswith("inproc"),
        }

    # maximum rings one worker will own at a time: each co-located client
    # session holds one per fetched task, so this bounds /dev/shm usage
    # under a pathological client that attaches without detaching
    MAX_SHM_CHANNELS = 64

    def rpc_shm_attach(
        self,
        slots: int = DEFAULT_SLOTS,
        slot_bytes: int = DEFAULT_SLOT_BYTES,
    ) -> Dict[str, Any]:
        """Create one shm ring for a co-located client (data plane v2+shm).

        Returns ``{ok, channel, segment, slots, slot_bytes}``; the client
        attaches to ``segment`` and passes ``channel`` on every
        ``get_elements`` call that should answer with a ring descriptor.
        Refusals (``ok=False``) mean "use the inline data plane": worker at
        channel capacity, oversize geometry, or shm unavailable.
        """
        if self._stopping.is_set():
            return {"ok": False, "error": "worker stopping"}
        try:
            with self._lock:
                if len(self._shm_channels) >= self.MAX_SHM_CHANNELS:
                    return {"ok": False, "error": "shm channel limit reached"}
            ring = ShmRing.create(slots=int(slots), slot_bytes=int(slot_bytes))
        except (ShmRingError, OSError, ValueError) as e:
            return {"ok": False, "error": repr(e)}
        channel = new_id("shmch")
        with self._lock:
            self._shm_channels[channel] = ring
        return {
            "ok": True,
            "channel": channel,
            "segment": ring.name,
            "slots": ring.slots,
            "slot_bytes": ring.slot_bytes,
        }

    def rpc_shm_detach(self, channel: str) -> Dict[str, Any]:
        """Tear down a ring created by ``shm_attach`` (client session end).

        Idempotent; unknown channels are fine (the worker may have released
        them already at stop()).  Segments of channels never detached are
        reclaimed when the worker stops — the client side only loses the
        fast path, never data.
        """
        with self._lock:
            ring = self._shm_channels.pop(channel, None)
        if ring is not None:
            ring.close()
            ring.unlink()
        return {"ok": True}

    def _shm_serve(
        self,
        out: Dict[str, Any],
        channel: str,
        elems: List[Element],
        compression: Optional[str],
    ) -> bool:
        """Try to answer a fetch via the shm ring; False means go inline.

        Zero-copy path (no codec): the batch frame is encoded straight into
        the leased slot (no intermediate ``bytes``).  Compressed path: the
        frame is built and compressed in memory, then copied into the slot —
        still one socket payload saved, but the client must copy out to
        decompress, so ``shm_codec`` rides in the descriptor.
        """
        with self._lock:
            ring = self._shm_channels.get(channel)
        if ring is None:
            return False
        slot = ring.try_acquire()
        if slot is None:  # ring full: consumer behind (or leases lost)
            return False
        try:
            view = ring.slot_view(slot)
            if compression:
                try:
                    frame = compress(encode_elements(elems), compression)
                except ValueError:
                    frame = compress(encode_elements(elems), None)
                if len(frame) > ring.slot_bytes:
                    raise FrameTooLarge(len(frame))
                view[: len(frame)] = frame
                length = len(frame)
                out["shm_codec"] = True
            else:
                length = encode_elements_into(elems, view)
        except FrameTooLarge:
            ring.cancel(slot)
            out.pop("shm_codec", None)
            return False
        except Exception as e:  # never poison the fetch path: go inline
            ring.cancel(slot)
            out.pop("shm_codec", None)
            self._note_error("shm serve", e)
            return False
        out["shm_slot"] = slot
        out["shm_len"] = length
        out["shm_seq"] = ring.commit(slot, length)
        return True

    def rpc_get_elements(
        self,
        task_id: str,
        job_id: str = "",
        max_batch: int = DEFAULT_MAX_BATCH,
        timeout: float = 0.0,
        shm_channel: str = "",
        trace: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        """Batched fetch (data plane v2): drain up to ``max_batch`` elements.

        ``timeout`` long-polls: the call may wait up to that many seconds
        for the FIRST element before answering PENDING, sparing the client a
        retry/backoff round trip.  With a negotiated codec the whole batch
        is one compressed frame (compressed once, worker-side).

        ``shm_channel`` (from ``shm_attach``) asks for a ring descriptor:
        when a slot is free and the frame fits, the batch is encoded
        directly into shared memory and the response carries
        ``shm_slot``/``shm_len``/``shm_seq`` (plus ``shm_codec`` when the
        frame is compressed) instead of inline bytes.  Ring full, frame too
        large, or unknown channel all degrade to the inline payload — the
        caller never has to retry.

        ``trace`` is present only on SAMPLED fetches (client-minted span
        context): the unsampled hot path pays exactly one None check.
        """
        self.metrics.add(rpc_count=1)
        ctx = TraceContext.from_wire(trace) if trace else None
        sctx = ctx.child() if ctx is not None else None  # our serve span
        wall = time.time() if sctx is not None else 0.0
        t0 = time.perf_counter()
        with self._lock:
            runner = self._tasks.get(task_id)
            spec = self._task_specs.get(task_id)
        if runner is None:
            return {"status": FetchStatus.PENDING.value, "count": 0}
        status, elems = runner.get_many(
            job_id, max(1, int(max_batch)), timeout=min(1.0, float(timeout))
        )
        out: Dict[str, Any] = {"status": status.value, "count": len(elems)}
        nbytes = 0
        if elems:
            nbytes = sum(element_nbytes(e) for e in elems)
            self.metrics.add(batches_served=len(elems), bytes_served=nbytes)
            out["nbytes"] = nbytes
            compression = spec.get("compression") if spec else None
            if shm_channel and self._shm_serve(
                out, shm_channel, elems, compression
            ):
                pass  # descriptor is in `out`; nothing travels inline
            elif compression:
                e0 = time.perf_counter()
                encoded = encode_elements(elems)
                try:
                    frame = compress(encoded, compression)
                except ValueError:
                    # the negotiated codec is not in THIS worker's registry
                    # (heterogeneous pool): ship uncompressed rather than
                    # fail every fetch — frames are tag-prefixed, so the
                    # client decodes either way.
                    frame = compress(encoded, None)
                if sctx is not None:
                    dur = time.perf_counter() - e0
                    self.tracer.record(
                        "worker.encode",
                        sctx.child(),
                        time.time() - dur,
                        dur,
                        parent_id=sctx.span_id,
                        nbytes=nbytes,
                        codec=compression,
                    )
                out["batch_compressed"] = frame
            else:
                out["elements"] = elems
        if sctx is not None:
            self.tracer.record(
                "worker.serve",
                sctx,
                wall,
                time.perf_counter() - t0,
                parent_id=ctx.span_id,
                task_id=task_id,
                count=len(elems),
                nbytes=nbytes,
                status=status.value,
            )
        return out

    def rpc_get_element(
        self,
        task_id: str,
        job_id: str = "",
        round_index: int = -1,
        consumer_index: int = -1,
        trace: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        self.metrics.add(rpc_count=1)
        ctx = TraceContext.from_wire(trace) if trace else None
        sctx = ctx.child() if ctx is not None else None
        wall = time.time() if sctx is not None else 0.0
        t0 = time.perf_counter()
        with self._lock:
            runner = self._tasks.get(task_id)
            spec = self._task_specs.get(task_id)
        if runner is None:
            return {"status": FetchStatus.PENDING.value}
        status, elem = runner.get(job_id, round_index, consumer_index)
        out: Dict[str, Any] = {"status": status.value}
        if elem is not None:
            nbytes = element_nbytes(elem)
            self.metrics.add(batches_served=1, bytes_served=nbytes)
            if spec and spec.get("compression"):
                out["element_compressed"] = compress(
                    encode_element(elem), spec["compression"]
                )
            else:
                out["element"] = elem
            out["nbytes"] = nbytes
        if sctx is not None:
            self.tracer.record(
                "worker.serve",
                sctx,
                wall,
                time.perf_counter() - t0,
                parent_id=ctx.span_id,
                task_id=task_id,
                round_index=round_index,
                status=status.value,
            )
        return out

    def rpc_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "worker_id": self.worker_id,
                "metrics": self.metrics.snapshot(),
                "tasks": {
                    tid: {
                        "status": r.status,
                        "occupancy": r.buffer_occupancy(),
                        "kind": type(r).__name__,
                        **r.extra_stats(),
                    }
                    for tid, r in self._tasks.items()
                },
                "caches": {
                    k: vars(c.stats).copy() for k, c in self._caches.items()
                },
                "snapshot_streams": {
                    f"{sid}/{stream_id}": {
                        "status": r.status,
                        "elements": r.writer.stats.elements,
                        "chunks": r.writer.stats.chunks,
                        "bytes": r.writer.stats.bytes_written,
                        "error": r.error,
                    }
                    for (sid, stream_id), r in self._snapshot_writers.items()
                },
            }

    def rpc_metrics_dump(self) -> Dict[str, Any]:
        """Observability scrape: registry snapshot + per-op pipeline
        profiles + the worker-level stall-attribution report (the op whose
        standalone capacity bounds throughput).  Read-mostly and lock-light:
        safe to poll at dashboard rates while the data plane is hot."""
        with self._lock:
            runners = dict(self._tasks)
            specs = dict(self._task_specs)
            stream_runners = list(self._snapshot_writers.values())
            retired = list(self._retired_profiles)
        tasks: Dict[str, Any] = {}
        profiles: List[List[Dict[str, Any]]] = []
        for tid, r in runners.items():
            prof = r.op_profile()
            profiles.append(prof)
            tasks[tid] = {
                "job_id": (specs.get(tid) or {}).get("job_id"),
                "status": r.status,
                "occupancy": r.buffer_occupancy(),
                "profile": prof,
            }
        for sr in stream_runners:
            profiles.append(sr.op_profile())
        profiles.append(retired)
        return {
            "worker_id": self.worker_id,
            "registry": self.registry.snapshot(),
            "stall_report": attribute_stalls(merge_profiles(profiles)),
            "tasks": tasks,
            "trace": {"buffered": len(self.tracer), "dropped": self.tracer.dropped},
        }

    def rpc_trace_dump(self, max_spans: int = 0) -> Dict[str, Any]:
        """Drain this worker's span ring buffer (consumed by
        ``repro.obs.export``; draining keeps repeat exports disjoint)."""
        return {
            "process": self.tracer.process,
            "spans": self.tracer.drain(max_spans),
        }
