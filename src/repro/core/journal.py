"""Dispatcher write-ahead journal (paper §3.4).

Every dispatcher state change is appended to the journal before it is applied
and acknowledged; a restarted dispatcher replays the journal to recover
registered datasets, jobs, workers, and shard-assignment state.  A snapshot
op compacts the log.

Format: an 8-byte file header ``RJNL`` + u32 version, then
[u32 length][pickled (seq, event_type, payload)] records appended to a
single file, fsync'd per batch.  Corrupt/truncated tails (crash mid-write) are
detected by length underrun and discarded — the WAL contract.  Headerless v0
journals (pre-header format) are still readable; a journal written by a
DIFFERENT format version fails loudly with :class:`JournalVersionError`
instead of mis-unpickling on a standby running other code.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
from typing import Any, Dict, Iterator, List, Optional, Tuple

Event = Tuple[int, str, Dict[str, Any]]

JOURNAL_MAGIC = b"RJNL"
JOURNAL_VERSION = 1
_HEADER = JOURNAL_MAGIC + struct.pack("<I", JOURNAL_VERSION)
HEADER_SIZE = len(_HEADER)


class JournalVersionError(RuntimeError):
    """Journal file was written by an incompatible format version."""


def _check_header(f) -> int:
    """Validate the header of an open binary file positioned at 0.

    Returns the offset where records start (``HEADER_SIZE`` for v1 files,
    ``0`` for headerless v0 journals) and leaves ``f`` positioned there.
    Raises :class:`JournalVersionError` on a version we do not speak.
    """
    head = f.read(HEADER_SIZE)
    if head[:4] == JOURNAL_MAGIC:
        if len(head) < HEADER_SIZE:
            raise JournalVersionError(
                "journal header truncated (magic present, version missing)"
            )
        (version,) = struct.unpack("<I", head[4:8])
        if version != JOURNAL_VERSION:
            raise JournalVersionError(
                f"journal format v{version} != supported v{JOURNAL_VERSION}"
            )
        return HEADER_SIZE
    # Headerless v0 journal: first 4 bytes are a record length.  b"RJNL"
    # as a length would be a ~1.28 GB record — not produced in practice.
    f.seek(0)
    return 0


class Journal:
    def __init__(self, path: Optional[str], fsync: bool = False):
        """``path=None`` disables durability (in-memory dispatcher)."""
        self._path = path
        self._fsync = fsync
        self._lock = threading.Lock()
        self._seq = 0
        self._f = None
        self._mirror = False
        if path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            if os.path.exists(path) and os.path.getsize(path) > 0:
                with open(path, "rb") as f:
                    _check_header(f)  # fail loudly before appending
            self._f = open(path, "ab")
            if self._f.tell() == 0:
                self._f.write(_HEADER)
                self._f.flush()

    # -- append -----------------------------------------------------------
    def append(self, event_type: str, payload: Dict[str, Any], sync: bool = False) -> int:
        """Append one event.  ``sync=True`` forces an fsync for THIS record
        regardless of the journal-wide default — used for records whose loss
        would desynchronize external durable state (e.g. snapshot chunk
        commits, which acknowledge bytes already fsync'd on shared storage)."""
        with self._lock:
            if self._mirror:
                # A mirroring standby derives events while replaying the
                # primary's stream; only replicated records are durable.
                return self._seq
            self._seq += 1
            self._write_record(self._seq, event_type, payload, sync)
            return self._seq

    def append_replica(
        self, seq: int, event_type: str, payload: Dict[str, Any], sync: bool = False
    ) -> None:
        """Append a record replicated from a primary, preserving its seq.
        Out-of-order/duplicate records (seq <= current) are dropped."""
        with self._lock:
            if seq <= self._seq:
                return
            self._seq = seq
            self._write_record(seq, event_type, payload, sync)

    def _write_record(
        self, seq: int, event_type: str, payload: Dict[str, Any], sync: bool
    ) -> None:
        if self._f is None:
            return
        rec = pickle.dumps(
            (seq, event_type, payload), protocol=pickle.HIGHEST_PROTOCOL
        )
        self._f.write(struct.pack("<I", len(rec)))
        self._f.write(rec)
        self._f.flush()
        if self._fsync or sync:
            os.fsync(self._f.fileno())

    # -- mirror mode ------------------------------------------------------
    def set_mirror(self, mirror: bool) -> None:
        """In mirror mode ``append()`` is suppressed (standby replay derives
        events the primary already journaled); ``append_replica`` still
        writes.  Promotion flips mirror off and the journal becomes a normal
        primary WAL continuing at the replicated seq."""
        with self._lock:
            self._mirror = mirror

    # -- replay -----------------------------------------------------------
    @staticmethod
    def replay(path: str) -> Iterator[Event]:
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            _check_header(f)
            yield from Journal._read_records(f)

    @staticmethod
    def _read_records(f) -> Iterator[Event]:
        while True:
            hdr = f.read(4)
            if len(hdr) < 4:
                return  # clean EOF or truncated length header
            (n,) = struct.unpack("<I", hdr)
            rec = f.read(n)
            if len(rec) < n:
                return  # torn tail write — discard (WAL contract)
            try:
                yield pickle.loads(rec)
            except Exception:
                return  # corrupt tail

    @staticmethod
    def read_after(path: str, after_seq: int, max_records: int = 512) -> List[Event]:
        """Read up to ``max_records`` events with seq > ``after_seq``.

        Used by the replication RPC: tolerates concurrent appends and torn
        tails (a torn tail simply ends the batch; the next poll re-reads it
        once complete).  A compaction rewrites seqs from the snapshot record,
        so a caller seeing an empty batch plus a first-record seq <= after_seq
        should restart from seq 0.
        """
        out: List[Event] = []
        for ev in Journal.replay(path):
            if ev[0] > after_seq:
                out.append(ev)
                if len(out) >= max_records:
                    break
        return out

    # -- compaction ---------------------------------------------------------
    def snapshot(self, state_payload: Dict[str, Any]) -> None:
        """Rewrite the journal as a single snapshot event + empty tail."""
        if self._path is None:
            return
        with self._lock:
            tmp = self._path + ".tmp"
            with open(tmp, "wb") as f:
                f.write(_HEADER)
                rec = pickle.dumps(
                    (self._seq, "snapshot", state_payload),
                    protocol=pickle.HIGHEST_PROTOCOL,
                )
                f.write(struct.pack("<I", len(rec)))
                f.write(rec)
                f.flush()
                os.fsync(f.fileno())
            if self._f is not None:
                self._f.close()
            os.replace(tmp, self._path)
            self._f = open(self._path, "ab")

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    @property
    def path(self) -> Optional[str]:
        return self._path

    @property
    def seq(self) -> int:
        return self._seq

    def set_seq(self, seq: int) -> None:
        """Advance the sequence counter to at least ``seq`` (replay path).

        Must hold ``_lock``: ``max`` is a read-modify-write, and a standby
        tail calls ``append_replica`` (which also writes ``_seq`` under the
        lock) concurrently with replay-driven ``set_seq`` — an unlocked
        race here can move ``_seq`` backwards, and the next ``append``
        would then reuse a sequence number already on disk.
        """
        with self._lock:
            self._seq = max(self._seq, seq)
