"""Dispatcher write-ahead journal (paper §3.4).

Every dispatcher state change is appended to the journal before it is applied
and acknowledged; a restarted dispatcher replays the journal to recover
registered datasets, jobs, workers, and shard-assignment state.  A snapshot
op compacts the log.

Format: [u32 length][pickled (seq, event_type, payload)] records appended to a
single file, fsync'd per batch.  Corrupt/truncated tails (crash mid-write) are
detected by length underrun and discarded — the WAL contract.
"""
from __future__ import annotations

import os
import pickle
import struct
import threading
from typing import Any, Callable, Dict, Iterator, List, Optional, Tuple

Event = Tuple[int, str, Dict[str, Any]]


class Journal:
    def __init__(self, path: Optional[str], fsync: bool = False):
        """``path=None`` disables durability (in-memory dispatcher)."""
        self._path = path
        self._fsync = fsync
        self._lock = threading.Lock()
        self._seq = 0
        self._f = None
        if path is not None:
            os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
            self._f = open(path, "ab")

    # -- append -----------------------------------------------------------
    def append(self, event_type: str, payload: Dict[str, Any], sync: bool = False) -> int:
        """Append one event.  ``sync=True`` forces an fsync for THIS record
        regardless of the journal-wide default — used for records whose loss
        would desynchronize external durable state (e.g. snapshot chunk
        commits, which acknowledge bytes already fsync'd on shared storage)."""
        with self._lock:
            self._seq += 1
            if self._f is not None:
                rec = pickle.dumps(
                    (self._seq, event_type, payload), protocol=pickle.HIGHEST_PROTOCOL
                )
                self._f.write(struct.pack("<I", len(rec)))
                self._f.write(rec)
                self._f.flush()
                if self._fsync or sync:
                    os.fsync(self._f.fileno())
            return self._seq

    # -- replay -----------------------------------------------------------
    @staticmethod
    def replay(path: str) -> Iterator[Event]:
        if not os.path.exists(path):
            return
        with open(path, "rb") as f:
            while True:
                hdr = f.read(4)
                if len(hdr) < 4:
                    return  # clean EOF or truncated length header
                (n,) = struct.unpack("<I", hdr)
                rec = f.read(n)
                if len(rec) < n:
                    return  # torn tail write — discard (WAL contract)
                try:
                    yield pickle.loads(rec)
                except Exception:
                    return  # corrupt tail

    # -- compaction ---------------------------------------------------------
    def snapshot(self, state_payload: Dict[str, Any]) -> None:
        """Rewrite the journal as a single snapshot event + empty tail."""
        if self._path is None:
            return
        with self._lock:
            tmp = self._path + ".tmp"
            with open(tmp, "wb") as f:
                rec = pickle.dumps(
                    (self._seq, "snapshot", state_payload),
                    protocol=pickle.HIGHEST_PROTOCOL,
                )
                f.write(struct.pack("<I", len(rec)))
                f.write(rec)
                f.flush()
                os.fsync(f.fileno())
            if self._f is not None:
                self._f.close()
            os.replace(tmp, self._path)
            self._f = open(self._path, "ab")

    def close(self) -> None:
        with self._lock:
            if self._f is not None:
                self._f.close()
                self._f = None

    @property
    def seq(self) -> int:
        return self._seq

    def set_seq(self, seq: int) -> None:
        self._seq = max(self._seq, seq)
