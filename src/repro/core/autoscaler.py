"""Worker-pool autoscaling (the paper's Autopilot / Cachew role).

Two signals, in priority order:

1. **Client latency** (Cachew-style, the primary signal when present):
   feeders (``repro.feed.DeviceFeeder``) report per-window accelerator
   stall fractions through client heartbeats; the dispatcher aggregates
   them per job (``stats()["jobs"][..]["client_stall"]``).  Consumers
   stalling means the service is the bottleneck — scale OUT; consumers
   never stalling while worker buffers sit full means over-provisioned —
   scale IN.  This is the signal that actually tracks what the paper
   optimizes (keep accelerators fed), and it is robust to the failure mode
   of buffer occupancy alone: a pipeline whose workers are slow AND whose
   client is slow can show comfortable buffers while the accelerator
   starves on transfer latency.

2. **Worker buffer occupancy** (fallback, the pre-feed policy): with no
   fresh client reports — non-feeder clients, snapshot-write pools, plain
   ``ScalableOrchestrator`` implementations — scale OUT while buffers run
   empty and IN while they sit full.

Hysteresis + cooldown prevent flapping; min/max bound the pool.  The
scaler observes only dispatcher-aggregated signals, so it works unchanged
over any transport — and against ANY orchestrator exposing the small
signal interface below (the in-process ``LocalOrchestrator``, a
snapshot-write worker pool, a k8s shim, ...).

**Two-level scaling** (multi-tenant deployments): when the orchestrator
exposes ``rebalance()`` and the deployment runs the fleet scheduler
(``scheduling=True``), every step first rebalances per-job worker SHARES
inside the current fleet (weighted max-min fair — see
``core.scheduler``), and only resizes the global pool when the plan
reports aggregate demand the fleet cannot satisfy (``unmet``, from
starving jobs) or capacity no job wants (``surplus``).  One starving
tenant therefore first takes workers from comfortable tenants, and only
then grows the fleet.

**Drain-aware scale-in**: when the orchestrator exposes
``pick_removable()``, the victim is an idle worker (no unfinished
snapshot streams, no pending coordinated rounds, lowest buffer
occupancy) instead of blindly the last of ``live_workers`` — removing a
mid-stream snapshot writer forces a stream reassignment and removing the
only holder of a materialized coordinated round stalls every consumer of
that round.  If nothing is drainable, scale-in waits for the next step.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Protocol, Set, runtime_checkable

from ..obs.registry import get_registry

logger = logging.getLogger(__name__)


@runtime_checkable
class ScalableOrchestrator(Protocol):
    """The signal/actuation surface the autoscaler needs — nothing more.

    ``stats()`` must return a dict with a ``"workers"`` mapping whose values
    carry ``"buffer_occupancy"`` (and MAY return a ``"jobs"`` mapping whose
    values carry ``"client_stall"`` aggregates — see ``Dispatcher``);
    ``live_workers`` sizes the pool; ``add_worker``/``remove_worker``
    actuate.  ``LocalOrchestrator`` satisfies this structurally; so can any
    deployment-specific pool (e.g. a dedicated snapshot-write pool).

    Two OPTIONAL methods (looked up dynamically, absence is fine):
    ``rebalance() -> dict|None`` runs one fleet-scheduling round and
    returns the plan view (``{"scheduled": True, "unmet": .., "surplus":
    ..}``) or None when scheduling is off; ``pick_removable() ->
    worker|None`` returns a drain-safe scale-in victim or None when no
    live worker is drainable.
    """

    def stats(self) -> Dict[str, Any]: ...

    def add_worker(self) -> Any: ...

    def remove_worker(self, worker: Any) -> None: ...

    @property
    def live_workers(self) -> List[Any]: ...


@dataclass
class AutoscalerConfig:
    min_workers: int = 1
    max_workers: int = 64
    # client-latency signal (primary): consumer-observed stall fraction
    stall_out_threshold: float = 0.05  # accelerators idle >5% => starved
    stall_in_threshold: float = 0.01  # ~never idle => candidate for scale-in
    # buffer-occupancy signal (fallback / scale-in corroboration)
    scale_out_threshold: float = 0.25  # mean buffer occupancy below => starved
    scale_in_threshold: float = 0.9  # above => over-provisioned
    cooldown_s: float = 1.0
    step: int = 1
    interval_s: float = 0.5


class Autoscaler:
    def __init__(self, orch: ScalableOrchestrator, config: Optional[AutoscalerConfig] = None):
        self._orch = orch
        self.config = config or AutoscalerConfig()
        self._last_action = 0.0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._logged_errors: Set[type] = set()
        self.decisions: list = []
        # Serializes scaling decisions: tests and benchmarks drive step()
        # synchronously while the start()ed background loop also calls it;
        # unserialized, both read the same stale pool size / _last_action
        # and can double-actuate one decision.
        self._step_lock = threading.Lock()

    # -- signal extraction --------------------------------------------------
    @staticmethod
    def _mean_occupancy(stats: Dict[str, Any]) -> Optional[float]:
        """Mean worker buffer occupancy; entries without the key (a worker
        mid-registration has not reported yet) are EXCLUDED rather than
        counted as 0.0 — defaulting them would bias the mean toward
        "starved" and feed a scale-out loop."""
        workers = stats.get("workers") or {}
        occ = [
            float(w["buffer_occupancy"])
            for w in workers.values()
            if isinstance(w, dict) and "buffer_occupancy" in w
        ]
        return sum(occ) / len(occ) if occ else None

    @staticmethod
    def _client_stall(stats: Dict[str, Any]) -> Optional[float]:
        """Worst fresh per-job consumer stall fraction, or None when no
        feeder has reported (max, not mean: one starving training job is a
        reason to scale even if an eval job is comfortable)."""
        fracs = []
        for j in (stats.get("jobs") or {}).values():
            if not isinstance(j, dict) or j.get("finished"):
                continue
            cs = j.get("client_stall")
            if isinstance(cs, dict) and cs.get("clients"):
                fracs.append(float(cs.get("stall_frac", 0.0)))
        return max(fracs) if fracs else None

    # -- one scaling decision (callable synchronously from tests) ----------
    def step(self) -> int:
        """Returns the delta applied to the worker pool (-step, 0, +step)."""
        with self._step_lock:
            return self._step_inner()

    def _step_inner(self) -> int:
        """One decision.  Caller must hold ``self._step_lock``."""
        cfg = self.config
        now = time.monotonic()
        if now - self._last_action < cfg.cooldown_s:
            return 0
        # level 1: per-job share rebalancing inside the current fleet
        # (multi-tenant deployments); the plan says whether the GLOBAL
        # pool needs to move at all
        rebalance = getattr(self._orch, "rebalance", None)
        plan = rebalance() if callable(rebalance) else None
        if isinstance(plan, dict) and plan.get("scheduled"):
            return self._fleet_step(plan, now)
        stats = self._orch.stats()
        mean_occ = self._mean_occupancy(stats)
        stall = self._client_stall(stats)
        if stall is None and mean_occ is None:
            return 0  # nothing has reported yet
        if stall is not None:
            # primary: what the consumers observe.  The stall signal alone
            # decides scale-OUT — a fleet whose workers are all
            # mid-registration (occupancy unavailable) must still be able
            # to scale out of a consumer stall.  Scale IN additionally
            # needs worker buffers to corroborate, so unknown occupancy
            # never triggers removal.
            starving = stall > cfg.stall_out_threshold
            sated = (
                stall < cfg.stall_in_threshold
                and mean_occ is not None
                and mean_occ > cfg.scale_in_threshold
            )
        else:
            # fallback: worker-side buffer occupancy only
            starving = mean_occ < cfg.scale_out_threshold
            sated = mean_occ > cfg.scale_in_threshold
        n = len(self._orch.live_workers)
        delta = 0
        if starving and n < cfg.max_workers:
            delta = min(cfg.step, cfg.max_workers - n)
            for _ in range(delta):
                self._orch.add_worker()
        elif sated and n > cfg.min_workers:
            delta = -self._remove_workers(min(cfg.step, n - cfg.min_workers))
        if delta:
            self._last_action = now
            self.decisions.append(
                {
                    "t": now,
                    "occupancy": mean_occ,
                    "client_stall": stall,
                    "signal": "client_stall" if stall is not None else "occupancy",
                    "workers_before": n,
                    "delta": delta,
                }
            )
        return delta

    def _fleet_step(self, plan: Dict[str, Any], now: float) -> int:
        """Level 2: resize the global pool only on aggregate imbalance.
        Caller must hold ``self._step_lock``.

        ``unmet`` > 0 means a starving job wanted workers the (already
        rebalanced) fleet could not provide — grow.  ``surplus`` > 0 means
        capacity no tenant wants — shrink, but only through drainable
        workers (a surplus fleet with every worker mid-snapshot keeps its
        size until a writer finishes).
        """
        cfg = self.config
        n = len(self._orch.live_workers)
        delta = 0
        if plan.get("unmet", 0) > 0 and n < cfg.max_workers:
            delta = min(cfg.step, cfg.max_workers - n, int(plan["unmet"]))
            for _ in range(delta):
                self._orch.add_worker()
        elif plan.get("surplus", 0) > 0 and n > cfg.min_workers:
            delta = -self._remove_workers(
                min(cfg.step, n - cfg.min_workers, int(plan["surplus"]))
            )
        if delta:
            self._last_action = now
            self.decisions.append(
                {
                    "t": now,
                    "signal": "fleet_demand",
                    "demand": plan.get("demand"),
                    "capacity": plan.get("capacity"),
                    "unmet": plan.get("unmet"),
                    "surplus": plan.get("surplus"),
                    "workers_before": n,
                    "delta": delta,
                }
            )
        return delta

    def _remove_workers(self, count: int) -> int:
        """Drain-aware removal of up to ``count`` workers; returns how many
        actually went (0 when nothing is currently drainable)."""
        removed = 0
        for _ in range(count):
            victim = self._pick_victim()
            if victim is None:
                break
            self._orch.remove_worker(victim)
            removed += 1
        return removed

    def _pick_victim(self) -> Optional[Any]:
        picker = getattr(self._orch, "pick_removable", None)
        if callable(picker):
            return picker()  # None = nothing drainable: skip this round
        live = self._orch.live_workers
        return live[-1] if live else None

    # -- background loop -----------------------------------------------------
    def start(self) -> "Autoscaler":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.step()
            except Exception as e:
                # scaling must never kill the deployment, but going silent
                # forever on e.g. a malformed stats() dict hid real bugs —
                # count every occurrence, log the first of each type
                get_registry().counter(
                    "autoscaler_errors_total",
                    "swallowed autoscaler step failures, by exception type",
                ).labels(kind=type(e).__name__).inc()
                if type(e) not in self._logged_errors:
                    self._logged_errors.add(type(e))
                    logger.warning(
                        "autoscaler step failed with %r "
                        "(further %s suppressed)",
                        e,
                        type(e).__name__,
                    )

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)
