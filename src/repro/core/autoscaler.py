"""Worker-pool autoscaling (the paper's Autopilot / Cachew role).

Policy (Cachew-style, batch-latency driven): scale OUT while clients starve
(worker buffers run empty — the service is the bottleneck); scale IN when
buffers sit full (over-provisioned).  Hysteresis + cooldown prevent flapping;
min/max bound the pool.  The scaler observes only dispatcher-aggregated
signals, so it works unchanged over any transport — and against ANY
orchestrator exposing the small signal interface below (the in-process
``LocalOrchestrator``, a snapshot-write worker pool, a k8s shim, ...).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Protocol, runtime_checkable


@runtime_checkable
class ScalableOrchestrator(Protocol):
    """The signal/actuation surface the autoscaler needs — nothing more.

    ``stats()`` must return a dict with a ``"workers"`` mapping whose values
    carry ``"buffer_occupancy"``; ``live_workers`` sizes the pool;
    ``add_worker``/``remove_worker`` actuate.  ``LocalOrchestrator``
    satisfies this structurally; so can any deployment-specific pool
    (e.g. a dedicated snapshot-write pool).
    """

    def stats(self) -> Dict[str, Any]: ...

    def add_worker(self) -> Any: ...

    def remove_worker(self, worker: Any) -> None: ...

    @property
    def live_workers(self) -> List[Any]: ...


@dataclass
class AutoscalerConfig:
    min_workers: int = 1
    max_workers: int = 64
    scale_out_threshold: float = 0.25  # mean buffer occupancy below => starved
    scale_in_threshold: float = 0.9  # above => over-provisioned
    cooldown_s: float = 1.0
    step: int = 1
    interval_s: float = 0.5


class Autoscaler:
    def __init__(self, orch: ScalableOrchestrator, config: Optional[AutoscalerConfig] = None):
        self._orch = orch
        self.config = config or AutoscalerConfig()
        self._last_action = 0.0
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self.decisions: list = []

    # -- one scaling decision (callable synchronously from tests) ----------
    def step(self) -> int:
        """Returns the delta applied to the worker pool (-step, 0, +step)."""
        cfg = self.config
        now = time.monotonic()
        if now - self._last_action < cfg.cooldown_s:
            return 0
        stats = self._orch.stats()
        workers = stats.get("workers", {})
        if not workers:
            return 0
        occ = [w["buffer_occupancy"] for w in workers.values()]
        mean_occ = sum(occ) / len(occ)
        n = len(self._orch.live_workers)
        delta = 0
        if mean_occ < cfg.scale_out_threshold and n < cfg.max_workers:
            delta = min(cfg.step, cfg.max_workers - n)
            for _ in range(delta):
                self._orch.add_worker()
        elif mean_occ > cfg.scale_in_threshold and n > cfg.min_workers:
            delta = -min(cfg.step, n - cfg.min_workers)
            for _ in range(-delta):
                self._orch.remove_worker(self._orch.live_workers[-1])
        if delta:
            self._last_action = now
            self.decisions.append(
                {"t": now, "occupancy": mean_occ, "workers_before": n, "delta": delta}
            )
        return delta

    # -- background loop -----------------------------------------------------
    def start(self) -> "Autoscaler":
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()
        return self

    def _run(self) -> None:
        while not self._stop.wait(self.config.interval_s):
            try:
                self.step()
            except Exception:
                continue

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)
