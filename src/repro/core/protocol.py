"""Wire protocol between clients, dispatcher, and workers.

All control-plane and data-plane calls are method-name + dict payloads over a
pluggable transport (in-proc direct call, or length-prefixed pickle over TCP —
standing in for the paper's gRPC/HTTP2 channel).  Payloads are plain dicts of
python/numpy values so both transports serialize them identically.

Naming follows the paper's architecture (§3.1): clients register *datasets*
and join *jobs*; the dispatcher creates per-worker *tasks*; workers serve
*elements* (batches) to clients.

This docstring is the protocol spec of record: every ``rpc_*`` handler on
the dispatcher and the workers must be named here (the ``repro.analysis``
R001 pass enforces it).

Control-plane methods exposed by the dispatcher:

* ``get_or_register_dataset`` — register a serialized pipeline definition;
  idempotent by fingerprint, so N clients sharing one input pipeline get
  the same ``dataset_id`` (the paper's ephemeral-sharing precondition).
* ``get_or_create_job``       — create/join a job over a dataset (name-keyed
  get-or-create); returns the task list.  Accepts ``weight`` (fleet-
  scheduler share) next to ``max_workers``; both are journaled.
* ``client_heartbeat``        — client liveness + consumption progress; the
  response carries the refreshed task list (worker set changes ride this
  pull, there is no dispatcher→client push) and round-advance info for
  coordinated reads.
* ``register_worker`` / ``worker_heartbeat`` — worker bring-up and liveness;
  responses carry task assignments and ``snapshot_streams``, heartbeats
  carry ``cache_stats`` back up (see below).
* ``remove_worker``           — administrative scale-in: deregister a worker
  so its tasks migrate immediately instead of waiting for the heartbeat
  timeout sweep.
* ``complete_shard``          — dynamic sharding: a worker reports a shard
  exhausted; the dispatcher journals the completion (at-most-once bookkeeping).
* ``checkpoint_offset``       — client-side offset checkpoint for the
  exactly-once visitation path; journaled so a restarted dispatcher
  resumes handing out elements after the checkpoint.
* ``stats``                   — aggregate observability snapshot (jobs,
  workers, cache sharing, autoscaler state); read-only, safe to poll.
* ``list_workers``            — admin view of registered workers and their
  tags/liveness; read-only (``LocalOrchestrator.list_workers`` wraps it).

Data-plane methods exposed by workers:

* ``get_element``  — v1: one element per RPC (kept as the compatibility
  fallback; also the coordinated-reads path, which is round-indexed).
* ``get_elements`` — v2: drains up to ``max_batch`` ready elements per RPC.
  When the job negotiated a compression codec, the worker encodes the whole
  batch into one frame (``data.elements.encode_elements``) and compresses it
  once; the response carries ``batch_compressed``.  Otherwise the response
  carries the raw ``elements`` list (zero-copy over ``inproc://``).

  Shared-memory data plane: a co-located client that attached a ring (see
  ``shm_attach`` below) passes ``shm_channel`` in the request.  When the
  worker can serve the batch through the ring it encodes the frame directly
  into a ring slot and the response carries a DESCRIPTOR instead of bytes:
  ``shm_slot`` / ``shm_len`` / ``shm_seq`` (slot index, frame length,
  commit sequence — validated by ``ShmRing.payload`` on the client), plus
  ``shm_codec: True`` when the frame is a compressed blob rather than a raw
  element frame.  Ring-full, oversized frames, or an unknown/detached
  channel all degrade to the inline fields above on a per-response basis;
  the client needs no special handling beyond "no ``shm_slot`` in response
  means inline".

Clients discover a v1-only worker by the unknown-method error and fall back
to ``get_element`` for that task (see ``client.DataServiceClient``).

Workers also answer two control-plane probes: ``ping`` (liveness + advertised
data-plane version, used by the orchestrator at worker bring-up; the reply
also carries ``host`` — the worker's host identity key — and ``shm`` — True
when the worker can serve a shared-memory ring, i.e. it is not in-proc —
which clients use to auto-negotiate the ``shm://`` data plane when
co-located) and ``stats`` (the worker-local metrics snapshot mirrored into
heartbeats).

Shared-memory channel lifecycle (worker-side, negotiated per client task
handle after a ``ping`` host match):

* ``shm_attach`` — create a per-consumer ring segment.  Accepts optional
  ``slots`` / ``slot_bytes`` geometry; returns ``{ok, channel, segment,
  slots, slot_bytes}`` where ``segment`` is the ``/dev/shm`` name the
  client attaches (the ``shm://`` descriptor) and ``channel`` is the opaque
  id to pass in ``get_elements``.  Refused (``ok: False``) over in-proc
  transport or past the per-worker channel cap; refusal just means the
  client stays on the inline path.
* ``shm_detach`` — drop a channel and unlink its segment; idempotent (an
  unknown channel is a no-op ack), called best-effort at client close.
  In-flight ``get_elements`` racing a detach degrade to inline.

``register_worker`` note: the worker advertises its host identity as
``tags["host"]`` (tags are NOT journaled — host identity is ephemeral by
design, so a journal replayed on another machine never resurrects a stale
co-location claim); clients compare it against their own host key only via
``ping``, keeping the dispatcher out of the data-plane negotiation.

Snapshot / materialization RPCs (dispatcher-side, see ``repro.snapshot``):

* ``start_snapshot``        — partition a dataset into streams and begin
  materializing it to shared storage (get-or-start: idempotent per path).
* ``snapshot_status``       — progress view (streams, chunks, finished).
* ``snapshot_commit_chunk`` — a worker's chunk-commit report; the dispatcher
  validates stream ownership + sequence, journals it (fsync'd), and acks.
  A negative ack tells a zombie writer its stream was reassigned.
* ``snapshot_stream_done``  — a worker finished a stream; when the last
  stream completes the dispatcher finalizes the snapshot (DONE marker).

Workers receive snapshot stream assignments alongside tasks in
``register_worker`` / ``worker_heartbeat`` responses
(``snapshot_streams``), and worker heartbeats additionally carry
SlidingWindowCache counters (``cache_stats``) so the dispatcher and the
autocache policy can observe sharing efficiency per pipeline fingerprint.

Fleet scheduling (multi-tenant deployments, ``scheduling=True``):

* ``get_or_create_job`` accepts ``weight`` (fleet-scheduler share weight)
  next to ``max_workers``; both are journaled with the job.
* ``retire_task``     — administrative task retirement.  The scheduler's
  ``rebalance()`` retires tasks through the same journaled path when it
  shrinks a job's share; the affected worker learns on its next heartbeat
  (the task disappears from ``valid_tasks``, pruning the runner) and
  clients stop fetching when the dispatcher view stops listing the task.
  There is no dispatcher→worker push: retirement, like every other
  assignment change, rides the existing heartbeat pull.

Dispatcher HA (hot-standby failover, paper §3.4):

* ``journal_fetch`` — replication stream for a hot standby: returns the
  primary's journal records with ``seq > after_seq`` (bounded by
  ``max_records``) plus the primary's current ``seq``.  Read lock-free
  from the journal file; a torn tail just ends the batch early and the
  standby re-polls.  When the primary stops answering for longer than its
  lease the standby finishes replaying and promotes itself at the same
  service address.
* ``get_shard`` carries ``holding`` — the shard ids the worker actually
  has in flight.  The promoted (or restarted) dispatcher reconciles its
  journaled view against it: a ``shard_assigned`` whose response the
  crash ate delivered zero bytes worker-side, so those shards are
  re-queued exactly, each journaled as a ``shard_requeued`` event (the
  journal-only event type; it never travels as an RPC).

Observability (``repro.obs``):

* ``metrics_dump`` — full metrics snapshot, answered by BOTH processes.
  The dispatcher returns ``{process, stats, workers, registry, trace}``
  (``workers`` maps worker_id → address so a scraper can fan out);
  workers return ``{worker_id, registry, stall_report, tasks, trace}``
  where ``stall_report`` is the per-op bottleneck attribution and
  ``tasks`` carries per-task op profiles.  ``registry`` is the
  ``MetricsRegistry`` snapshot (counter/gauge/histogram families, with
  labeled series); read-only, safe to poll — the fleet dashboard
  (``python -m repro.obs.top``) scrapes it every interval.
* ``trace_dump``   — drain up to ``max_spans`` buffered trace spans (0 =
  all), answered by both processes; returns ``{process, spans}``.  The
  Chrome-trace exporter (``python -m repro.obs.export``) collects these
  from the dispatcher and every worker into one Perfetto-loadable file.
  Draining is destructive by design: each span is exported once.

Trace context propagation: ``get_or_create_job``, ``client_heartbeat``,
``get_elements``, and ``get_element`` all accept an OPTIONAL ``trace``
payload field — ``{trace_id, span_id, sample}`` minted by the client's
tracer.  It is omitted entirely when the client samples the call out, so
the unsampled hot path's payload is byte-identical to pre-tracing
builds.  The job-level context rides ``get_or_create_job``, is journaled
with ``job_created`` (a promoted standby keeps stamping the same
trace_id), and returns to workers inside task specs.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional


class ShardingPolicy(str, enum.Enum):
    OFF = "off"  # every worker processes the full dataset (zero-once-or-more)
    DYNAMIC = "dynamic"  # dispatcher hands out disjoint shards FCFS (at-most-once)
    STATIC = "static"  # up-front mod-partition across workers

    @staticmethod
    def parse(v: "str | ShardingPolicy") -> "ShardingPolicy":
        return v if isinstance(v, ShardingPolicy) else ShardingPolicy(str(v).lower())


class VisitationGuarantee(str, enum.Enum):
    """What each policy provides (paper §3.3/§3.4); asserted in tests."""

    ZERO_ONCE_OR_MORE = "zero-once-or-more"
    AT_MOST_ONCE = "at-most-once"
    EXACTLY_ONCE = "exactly-once"  # only without failures, or with offset ckpt


# Data-plane element fetch status codes.
class FetchStatus(str, enum.Enum):
    OK = "ok"
    PENDING = "pending"  # not yet produced; client should retry
    END_OF_TASK = "end_of_task"


# Data-plane protocol version advertised by workers (2 = batched get_elements).
DATA_PLANE_VERSION = 2

# Default number of elements a worker may return per get_elements RPC.
DEFAULT_MAX_BATCH = 16

# Default number of overlapped outstanding get_elements requests a client
# keeps in flight per worker task (each on its own connection).
DEFAULT_FETCH_WINDOW = 2

# Default worker-side long-poll: a get_elements call waits up to this many
# seconds for the first element instead of bouncing PENDING back to the
# client (kills the client-side retry/backoff latency on a hot path).
DEFAULT_POLL_TIMEOUT = 0.05

# Default size bound for one snapshot chunk file (compressed payload grows
# until the ENCODED pending elements exceed this, then the chunk commits).
DEFAULT_CHUNK_BYTES = 1 << 20


@dataclass
class WorkerInfo:
    worker_id: str
    address: str
    tags: Dict[str, Any] = field(default_factory=dict)


@dataclass
class TaskSpec:
    """One worker's processing assignment for one job."""

    task_id: str
    job_id: str
    dataset_id: str
    worker_id: str
    worker_address: str
    policy: str = ShardingPolicy.OFF.value
    # coordinated reads
    num_consumers: int = 0
    round_robin: bool = False
    # ephemeral sharing
    shared: bool = False
    cache_key: Optional[str] = None
    worker_seed: int = 0


@dataclass
class JobView:
    """Client-visible job state returned by the dispatcher."""

    job_id: str
    dataset_id: str
    policy: str
    tasks: List[TaskSpec] = field(default_factory=list)
    worker_list_version: int = 0
    finished: bool = False
    num_consumers: int = 0


def new_id(prefix: str) -> str:
    import uuid

    return f"{prefix}-{uuid.uuid4().hex[:10]}"
