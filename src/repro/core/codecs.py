"""Pluggable compression codec registry for the data plane.

The paper (§3.1) notes that worker→client payload compression is a
deployment-dependent trade: it pays for itself on cross-region or
bandwidth-constrained links and is usually OFF inside a datacenter.  Rather
than hardcoding one algorithm, the data plane negotiates a *codec* per job:

* the client requests a codec by name (or ``"auto"`` to let the service pick),
* the dispatcher resolves the request against the codecs available in the
  deployment (``resolve_codec``) and records the agreed name on the job,
* workers compress each response frame once with the agreed codec,
* clients decode by the self-describing one-byte tag on the frame, so a
  client can always decode any frame a worker produced.

Built-in codecs:

========  ===  ==========================================================
name      tag  notes
========  ===  ==========================================================
none      0x00 identity (default; in-datacenter deployments)
zlib      0x01 stdlib, level 1 — cheap CPU, moderate ratio
lz4       0x02 optional (``lz4.frame``); registered only when importable
========  ===  ==========================================================

New codecs register via :func:`register_codec`; tags must be unique and
stable across versions because they appear on the wire.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional


@dataclass(frozen=True)
class Codec:
    """One compression algorithm usable on the data plane."""

    name: str
    tag: bytes  # single wire byte prefixed to every compressed frame
    compress: Callable[[bytes], bytes]
    decompress: Callable[[bytes], bytes]


_BY_NAME: Dict[str, Codec] = {}
_BY_TAG: Dict[bytes, Codec] = {}


def register_codec(codec: Codec) -> Codec:
    """Add a codec to the registry. Name and tag must be unused."""
    if len(codec.tag) != 1:
        raise ValueError(f"codec tag must be one byte, got {codec.tag!r}")
    if codec.name in _BY_NAME:
        raise ValueError(f"codec already registered: {codec.name}")
    if codec.tag in _BY_TAG:
        raise ValueError(f"codec tag already registered: {codec.tag!r}")
    _BY_NAME[codec.name] = codec
    _BY_TAG[codec.tag] = codec
    return codec


register_codec(Codec("none", b"\x00", lambda d: d, lambda d: d))
register_codec(
    Codec(
        "zlib",
        b"\x01",
        lambda d: zlib.compress(d, 1),
        zlib.decompress,
    )
)

try:  # optional: not baked into every container
    import lz4.frame as _lz4frame

    register_codec(
        Codec("lz4", b"\x02", _lz4frame.compress, _lz4frame.decompress)
    )
except Exception:  # pragma: no cover - environment-dependent
    pass


def available_codecs() -> List[str]:
    """Names of codecs usable in this process, ``none`` first."""
    return sorted(_BY_NAME, key=lambda n: _BY_NAME[n].tag)


def get_codec(name: Optional[str]) -> Codec:
    """Look up a codec by name (``None`` means ``none``)."""
    c = _BY_NAME.get(name or "none")
    if c is None:
        raise ValueError(f"unknown codec: {name!r} (have {available_codecs()})")
    return c


# Names that are legitimate codecs even when the backing package is not
# installed in this process — degrade instead of treating them as typos.
_KNOWN_OPTIONAL = frozenset({"lz4", "zstd"})


def resolve_codec(
    requested: Optional[str], client_codecs: Optional[List[str]] = None
) -> Optional[str]:
    """Dispatcher-side negotiation: map a client's request to an agreed codec.

    ``client_codecs`` is the requesting client's ``available_codecs()``;
    the agreed codec must be decodable by the CLIENT as well as encodable
    here, so the choice is restricted to the intersection (``None`` — e.g.
    a pre-negotiation client — means "assume same registry as ours").

    * ``None`` / ``"none"``   -> ``None`` (no compression).
    * ``"auto"``              -> best non-identity codec both sides have
      (``lz4`` when possible, else ``zlib``).
    * a usable name           -> itself.
    * a known name either side lacks (e.g. ``lz4`` without the package)
      -> ``zlib`` (always present: stdlib) — degrade, don't fail the job.
    * an unknown name         -> ``ValueError`` (caller bug).
    """
    if requested in (None, "none"):
        return None
    usable = set(_BY_NAME)
    if client_codecs is not None:
        usable &= set(client_codecs)
    if requested == "auto":
        return "lz4" if "lz4" in usable else "zlib"
    if requested in usable:
        return requested
    if requested in _BY_NAME or requested in _KNOWN_OPTIONAL:
        return "zlib"
    raise ValueError(f"unknown compression codec: {requested!r}")


def compress(data: Any, method: Optional[str]) -> bytes:
    """Compress ``data`` with the named codec; output is tag-prefixed.

    ``data`` may be any bytes-like object (``bytes``, ``bytearray``,
    ``memoryview`` — e.g. a borrowed shm ring-slot view); the output is
    always ``bytes``.
    """
    c = get_codec(method)
    out = c.compress(data)
    if not isinstance(out, bytes):  # identity codec echoes the input view
        out = bytes(out)
    return c.tag + out


def decompress(data: Any) -> bytes:
    """Decompress a tag-prefixed frame produced by :func:`compress`.

    Accepts any bytes-like input.  For ``bytes`` input the result is
    ``bytes`` (unchanged contract); a ``memoryview``/``bytearray`` input
    through the identity codec returns a view of the input rather than a
    copy — downstream decode (``data.elements``) accepts either.
    """
    tag = bytes(data[:1])
    body = data[1:]
    c = _BY_TAG.get(tag)
    if c is None:
        raise ValueError(f"unknown compression tag {tag!r}")
    return c.decompress(body)
