"""Named crash-point injection for the chaos harness (tests/chaos.py).

The dispatcher calls ``self._crash("<point>")`` at seams where a crash
between the journal append and the in-memory apply (or the RPC response)
exercises the widest torn-state window.  A ``CrashPoints`` registry armed by
the harness fires at the Nth hit of a named point: it invokes ``on_fire``
(the orchestrator marks the dispatcher failed and unbinds its transport —
the process "dies") and raises :class:`DispatcherCrashed`, which subclasses
``TransportError`` so every existing client/worker retry path rides through
it exactly as it would a real connection loss.
"""
from __future__ import annotations

import threading
from typing import Callable, Dict, Optional

from ..transport import TransportError


class DispatcherCrashed(TransportError):
    """The dispatcher crashed (injected fault or post-crash call)."""


class CrashPoints:
    """Countdown-armed named crash points.

    ``arm(point, countdown)`` makes the ``countdown``-th hit of ``point``
    fire.  Only one crash fires per registry instance — after that every
    further hit is a no-op (the dispatcher's ``_failed`` gate rejects calls
    anyway).  Thread-safe: RPCs hit points from many handler threads.
    """

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._armed: Dict[str, int] = {}
        self.on_fire: Optional[Callable[[str], None]] = None
        self.fired: Optional[str] = None
        self.hits: Dict[str, int] = {}

    def arm(self, point: str, countdown: int = 1) -> None:
        with self._lock:
            self._armed[point] = max(1, int(countdown))

    def hit(self, point: str) -> None:
        with self._lock:
            self.hits[point] = self.hits.get(point, 0) + 1
            if self.fired is not None:
                return
            n = self._armed.get(point)
            if n is None:
                return
            if n > 1:
                self._armed[point] = n - 1
                return
            del self._armed[point]
            self.fired = point
            cb = self.on_fire
        if cb is not None:
            cb(point)
        raise DispatcherCrashed(f"injected crash at {point!r}")
