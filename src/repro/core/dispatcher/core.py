"""The tf.data-service dispatcher (paper §3.1, §3.3, §3.4).

Control plane only — never touches data.  Composed from three seams:
  * :class:`ControlPlaneMixin` — datasets, jobs, workers, shard hand-out,
  * :class:`CommitterMixin` — snapshot streams and chunk commits,
  * :class:`FleetMixin` — multi-tenant fleet scheduling,
plus the pieces this module keeps: the RPC entry point, the write-ahead
journal restore/compaction, the replication RPC a hot standby tails
(``rpc_journal_fetch``), and crash-point instrumentation for the chaos
harness.

Threading model: a single lock guards dispatcher state (control-plane calls
are small and rare relative to data-plane traffic, which goes directly from
clients to workers — the dispatcher is deliberately off the data path).
"""
from __future__ import annotations

import threading
import time
from typing import Any, Dict, Optional

from ...data.graph import Graph
from ...obs.registry import MetricsRegistry, get_registry
from ...obs.tracing import Tracer
from ...snapshot.manager import SnapshotState
from ...snapshot.policy import AutocacheConfig, AutocachePolicy
from ..journal import Journal
from ..scheduler import FleetScheduler, SchedulerConfig
from ..sharding import ShardManager
from .committer import CommitterMixin
from .control import ControlPlaneMixin
from .crashpoints import CrashPoints, DispatcherCrashed
from .fleet import FleetMixin
from .state import _Dataset, _Job, _Worker


class Dispatcher(ControlPlaneMixin, FleetMixin, CommitterMixin):
    def __init__(
        self,
        journal_path: Optional[str] = None,
        heartbeat_timeout: float = 5.0,
        overpartition: int = 4,
        snapshot_root: Optional[str] = None,
        autocache_config: Optional[AutocacheConfig] = None,
        scheduling: bool = False,
        scheduler_config: Optional[SchedulerConfig] = None,
        crash_points: Optional[CrashPoints] = None,
        standby: bool = False,
    ):
        self.registry = MetricsRegistry()
        self.tracer = Tracer(process="dispatcher")
        self._rpc_counter = self.registry.counter(
            "dispatcher_rpcs_total", "control-plane RPCs handled, by method"
        )
        self._lock = threading.RLock()
        self._datasets: Dict[str, _Dataset] = {}
        self._datasets_by_fp: Dict[str, str] = {}
        self._jobs: Dict[str, _Job] = {}
        self._jobs_by_name: Dict[str, str] = {}
        self._workers: Dict[str, _Worker] = {}
        self._snapshots: Dict[str, SnapshotState] = {}
        self._snapshots_by_path: Dict[str, str] = {}
        # autocache: jobs opting in get a compute / write-through / read
        # decision keyed by pipeline fingerprint (requires snapshot_root)
        self._autocache: Optional[AutocachePolicy] = (
            AutocachePolicy(snapshot_root, autocache_config)
            if snapshot_root
            else None
        )
        # multi-tenant fleet scheduling: when enabled, schedulable jobs get
        # a demand-driven worker SHARE (weighted max-min fair) instead of a
        # task on every worker; rebalance() is the entry point (driven by
        # the two-level Autoscaler, or called directly)
        self._scheduler: Optional[FleetScheduler] = (
            FleetScheduler(scheduler_config) if scheduling else None
        )
        self._worker_list_version = 0
        self._heartbeat_timeout = heartbeat_timeout
        self._overpartition = overpartition
        # set after a journal restore that found shards assigned to workers
        # not (yet) re-registered: those workers get one heartbeat-timeout of
        # grace to come back before their in-flight shards are reclaimed
        self._orphan_sweep_deadline: Optional[float] = None
        # set after a journal restore that found jobs with tasks: until it
        # expires, capped/scheduled jobs count their JOURNALED tasks (not
        # just re-registered workers' tasks) so a worker that registers
        # before its peers cannot steal a slot a returning owner will
        # reclaim — allocations must survive the restart intact
        self._task_grace_deadline: Optional[float] = None
        # (job_id, worker_id) -> armed: shard reclamation deferred until
        # one heartbeat AFTER the one that tears the retired runner down.
        # A retired worker is ALIVE (unlike the worker-failure path) and
        # keeps serving its in-flight shard until the prune; re-queuing
        # that shard immediately would have a replacement replay it
        # concurrently (duplicate rows under resume_offsets).
        self._pending_reclaims: Dict[Any, bool] = {}
        # chaos harness: named crash points armed by tests; None in
        # production (every _crash() call is then a no-op)
        self._crash_points = crash_points
        self._failed = False
        self._journal = Journal(journal_path)
        if journal_path:
            # a standby replays the stream incrementally and runs the
            # post-restore fixups only at promotion (finalize_restore)
            self._restore(journal_path, finalize=not standby)
        if standby:
            self._journal.set_mirror(True)

    # ------------------------------------------------------------------
    # RPC entry point
    # ------------------------------------------------------------------
    def handle(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        if self._failed:
            raise DispatcherCrashed("dispatcher crashed")
        fn = getattr(self, f"rpc_{method}", None)
        if fn is None:
            raise ValueError(f"dispatcher: unknown method {method}")
        self._rpc_counter.labels(method=method).inc()
        return fn(**payload)

    # ------------------------------------------------------------------
    # Crash injection (chaos harness)
    # ------------------------------------------------------------------
    def _crash(self, point: str) -> None:
        if self._crash_points is not None:
            self._crash_points.hit(point)

    def fail(self) -> None:
        """Simulate process death: reject every further call.

        The journal file handle is left OPEN on purpose — a real crashed
        process simply stops writing; in-flight handler threads racing the
        crash must not hit a closed-file error that escapes the
        TransportError retry contract.
        """
        self._failed = True

    # ------------------------------------------------------------------
    # Replication (hot standby tails the journal)
    # ------------------------------------------------------------------
    def rpc_journal_fetch(
        self, after_seq: int = 0, max_records: int = 512
    ) -> Dict[str, Any]:
        """Stream journal records with seq > ``after_seq`` to a standby.

        Reads the journal FILE without taking the dispatcher lock: appends
        only ever add complete records ahead of the reader, and a torn tail
        (crash mid-write) just ends the batch early — the standby re-polls.
        """
        path = self._journal.path
        if path is None:
            return {"events": [], "seq": self._journal.seq}
        events = Journal.read_after(path, int(after_seq), int(max_records))
        return {"events": events, "seq": self._journal.seq}

    # ------------------------------------------------------------------
    # Journal restore (paper §3.4: replay on restart / standby tail)
    # ------------------------------------------------------------------
    def apply_event(self, seq: int, etype: str, p: Dict[str, Any]) -> None:
        """Apply one journal event to in-memory state (caller holds
        ``self._lock``).  Shared by restart replay and the standby tail."""
        self._journal.set_seq(seq)
        if etype == "snapshot":
            # compaction record: full state payload replaces everything
            # replayed so far (only ever first in a file, but a standby
            # can observe one mid-stream after a primary compaction)
            self._reset_state()
            self._restore_snapshot(p)
            return
        if self.apply_control_event(etype, p):
            return
        if self.apply_committer_event(etype, p):
            return
        # Every journaled event type must be claimed by a branch above —
        # the worker_registered/worker_removed no-ops included (see
        # apply_control_event).  The analysis journal pass (J001) enforces
        # the append<->apply correspondence statically.

    def _reset_state(self) -> None:
        self._datasets.clear()
        self._datasets_by_fp.clear()
        self._jobs.clear()
        self._jobs_by_name.clear()
        self._snapshots.clear()
        self._snapshots_by_path.clear()
        self._pending_reclaims.clear()

    def _restore(self, path: str, finalize: bool = True) -> None:
        events = list(Journal.replay(path))
        if not events:
            return
        with self._lock:
            for seq, etype, p in events:
                self.apply_event(seq, etype, p)
            if finalize:
                self.finalize_restore()

    def finalize_restore(self) -> None:
        """Post-replay fixups that assume the replayed state is now LIVE.

        Run after a restart's full replay, or at standby promotion (not
        while tailing: e.g. a half-finished snapshot would be "finalized"
        by the standby while the primary's writers are still appending).
        Caller holds ``self._lock``.
        """
        # crash window between the last stream_done and snapshot_finished:
        # finish the finalization the dead dispatcher never got to
        for snap in self._snapshots.values():
            if snap.all_streams_done and not snap.finished:
                self._journal.append(
                    "snapshot_finished", {"snapshot_id": snap.snapshot_id}, sync=True
                )
                self._finalize_snapshot(snap)
        # fleet scheduling: allocations survive the restart — the
        # replayed grant/retire history IS the allocation, so seed each
        # job's share from it (re-registering workers reclaim exactly
        # their journaled tasks; rebalance() adjusts from there)
        if self._scheduler is not None:
            for job in self._jobs.values():
                if self._schedulable(job) and job.tasks:
                    live = [
                        t
                        for t in job.tasks.values()
                        if t.task_id not in job.completed_tasks
                    ]
                    if live:
                        job.target_share = len(live)
        if any(
            st.assigned_to and not st.completed
            for job in self._jobs.values()
            if job.shard_mgr is not None
            for st in job.shard_mgr._states
        ) or any(
            s.assigned_to and not s.done
            for snap in self._snapshots.values()
            if not snap.finished
            for s in snap.streams
        ):
            self._orphan_sweep_deadline = (
                time.monotonic() + self._heartbeat_timeout
            )
        if any(job.tasks and not job.finished for job in self._jobs.values()):
            self._task_grace_deadline = (
                time.monotonic() + self._heartbeat_timeout
            )
        # shards assigned to a worker holding NO task for the job are a
        # retirement whose deferred reclaim died with the dispatcher:
        # re-arm it (the worker's heartbeats drive it; the orphan sweep
        # covers workers that never come back)
        for job in self._jobs.values():
            if job.shard_mgr is None or job.finished:
                continue
            with job.shard_mgr._lock:
                owners = {
                    st.assigned_to
                    for st in job.shard_mgr._states
                    if st.assigned_to and not st.completed
                }
            for wid in owners:
                if wid not in job.tasks_by_worker:
                    self._pending_reclaims[(job.job_id, wid)] = False

    def _restore_snapshot(self, p: Dict[str, Any]) -> None:
        for ds in p.get("datasets", []):
            self._apply_dataset(ds["dataset_id"], ds["graph_bytes"], ds["fingerprint"])
        for jp in p.get("jobs", []):
            job = self._apply_job(jp["payload"])
            job.finished = jp["finished"]
            if jp.get("shard_mgr") and job.shard_mgr is not None:
                graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
                job.shard_mgr = ShardManager.from_payload(graph, jp["shard_mgr"])
        for sp in p.get("snapshots", []):
            snap = SnapshotState.from_payload(sp)
            self._snapshots[snap.snapshot_id] = snap
            self._snapshots_by_path[snap.path] = snap.snapshot_id

    def snapshot(self) -> None:
        with self._lock:
            payload = {
                "datasets": [vars(d) for d in self._datasets.values()],
                "jobs": [
                    {
                        "payload": {
                            "job_id": j.job_id,
                            "job_name": j.job_name,
                            "dataset_id": j.dataset_id,
                            "policy": j.policy.value,
                            "num_consumers": j.num_consumers,
                            "sharing": j.sharing,
                            "compression": j.compression,
                            "max_workers": j.max_workers,
                            "weight": j.weight,
                            "resume_offsets": j.resume_offsets,
                            "autocache_decision": j.autocache_decision,
                            "target_share": j.target_share,
                            "trace": j.trace,
                        },
                        "finished": j.finished,
                        "shard_mgr": j.shard_mgr.to_payload() if j.shard_mgr else None,
                    }
                    for j in self._jobs.values()
                ],
                "snapshots": [s.to_payload() for s in self._snapshots.values()],
            }
            self._journal.snapshot(payload)

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def rpc_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "num_workers": len(self._workers),
                "worker_list_version": self._worker_list_version,
                "num_jobs": len(self._jobs),
                "jobs": {
                    j.job_id: {
                        "name": j.job_name,
                        "policy": j.policy.value,
                        "finished": j.finished,
                        "tasks": len(j.tasks),
                        "active_tasks": len(self._active_tasks(j)),
                        "completed_tasks": len(j.completed_tasks),
                        "weight": j.weight,
                        "target_share": j.target_share,
                        "clients": len(j.clients),
                        "shards": j.shard_mgr.stats() if j.shard_mgr else None,
                        # feed-side consumer latency (repro.feed reports);
                        # None until a feeder has reported recently
                        "client_stall": self._aggregate_client_stall(j),
                    }
                    for j in self._jobs.values()
                },
                "workers": {
                    wid: {
                        "address": w.info.address,
                        "buffer_occupancy": w.buffer_occupancy,
                        "cpu_busy": w.cpu_busy,
                        "cache_stats": w.cache_stats,
                    }
                    for wid, w in self._workers.items()
                },
                # sharing efficiency per pipeline fingerprint, aggregated
                # from worker heartbeats (feeds the autocache hot signal)
                "sharing": {
                    key: self._aggregate_cache_stats(key)
                    for key in sorted(
                        {k for w in self._workers.values() for k in w.cache_stats}
                    )
                },
                "snapshots": {
                    s.snapshot_id: s.view() for s in self._snapshots.values()
                },
            }

    def rpc_list_workers(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "workers": [vars(w.info) for w in self._workers.values()],
                "version": self._worker_list_version,
            }

    def rpc_metrics_dump(self) -> Dict[str, Any]:
        """Observability scrape (``python -m repro.obs.top``): the control-
        plane stats view + the merged registry snapshot.  The process-
        default registry rides along so background singletons that share
        the dispatcher's process (autoscaler, autotuner, orchestrator
        error counters) surface in the same dump."""
        with self._lock:
            workers = {
                wid: w.info.address for wid, w in self._workers.items()
            }
        return {
            "process": "dispatcher",
            "stats": self.rpc_stats(),
            "workers": workers,
            "registry": {**get_registry().snapshot(), **self.registry.snapshot()},
            "trace": {"buffered": len(self.tracer), "dropped": self.tracer.dropped},
        }

    def rpc_trace_dump(self, max_spans: int = 0) -> Dict[str, Any]:
        """Drain the dispatcher's span ring buffer (``repro.obs.export``)."""
        return {
            "process": self.tracer.process,
            "spans": self.tracer.drain(max_spans),
        }

    def close(self) -> None:
        self._journal.close()
