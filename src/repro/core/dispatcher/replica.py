"""Hot-standby dispatcher: journal tailing, lease expiry, promotion.

The standby wraps a :class:`Dispatcher` constructed in ``standby`` mode
(mirrored journal — it records REPLICATED events, never derives its own)
and a tailing thread that polls the primary's ``journal_fetch`` replication
RPC.  Replicated events are applied incrementally under the dispatcher
lock, so at any instant the standby's in-memory state equals the primary's
journal prefix it has consumed.

Failover: when the primary stops answering for longer than the lease
timeout, the standby promotes itself —

  1. catch-up replay straight from the primary's journal FILE (shared
     durable storage, paper §3.4).  The RPC tail can lag the fsync'd log by
     one poll interval; the file read closes that window, which is what
     makes failover exactly-once rather than merely crash-consistent;
  2. ``set_mirror(False)`` — the standby's journal becomes a primary WAL
     continuing at the replicated seq;
  3. ``finalize_restore()`` — the restart fixups (orphan-shard grace,
     allocation seeding, half-finished snapshot finalization);
  4. the orchestrator (``on_promote``) rebinds the service address; clients
     and workers ride through via their existing reconnect/backoff paths.
"""
from __future__ import annotations

import threading
import time
from typing import Any, Callable, Dict, Optional

from ..journal import Journal
from ..transport import Stub, TransportError
from .core import Dispatcher


class StandbyDispatcher:
    def __init__(
        self,
        journal_path: str,
        primary_address: str,
        primary_journal_path: Optional[str] = None,
        lease_timeout: float = 1.0,
        poll_interval: float = 0.05,
        max_records: int = 512,
        on_promote: Optional[Callable[["StandbyDispatcher"], None]] = None,
        **dispatcher_kwargs: Any,
    ) -> None:
        self.dispatcher = Dispatcher(
            journal_path=journal_path, standby=True, **dispatcher_kwargs
        )
        self.journal_path = journal_path
        self.primary_journal_path = primary_journal_path
        # RPC deadline tied to the lease: failover detection is only as
        # fast as the slowest journal_fetch, so a primary that ACCEPTS
        # connections but never answers (half-dead host) must fail the
        # tail within the lease budget, not the 30s transport default
        self._stub = Stub(
            primary_address, timeout=max(0.05, min(lease_timeout, 30.0))
        )
        self._lease_timeout = lease_timeout
        self._poll_interval = poll_interval
        self._max_records = max_records
        self._on_promote = on_promote
        self.promoted = threading.Event()
        self._stop = threading.Event()
        # replication progress: highest primary seq applied via the RPC tail
        self.applied_seq = 0
        self.replicated_records = 0
        self.promote_stats: Dict[str, float] = {}
        self._thread = threading.Thread(
            target=self._run, name="standby-tail", daemon=True
        )

    def start(self) -> "StandbyDispatcher":
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop.set()

    def join(self, timeout: Optional[float] = None) -> None:
        self._thread.join(timeout)

    # ------------------------------------------------------------------
    def _run(self) -> None:
        last_ok = time.monotonic()
        while not self._stop.is_set():
            try:
                resp = self._stub.call(
                    "journal_fetch",
                    after_seq=self.applied_seq,
                    max_records=self._max_records,
                )
            except TransportError:
                if time.monotonic() - last_ok > self._lease_timeout:
                    self.promote()
                    return
                self._stop.wait(self._poll_interval)
                continue
            last_ok = time.monotonic()
            events = resp.get("events", [])
            for seq, etype, payload in events:
                self._apply(seq, etype, payload)
            if len(events) < self._max_records:
                self._stop.wait(self._poll_interval)

    def _apply(self, seq: int, etype: str, payload: Dict[str, Any]) -> None:
        if seq <= self.applied_seq and etype != "snapshot":
            return
        with self.dispatcher._lock:
            self.dispatcher.apply_event(seq, etype, payload)
        self.dispatcher._journal.append_replica(seq, etype, payload)
        self.applied_seq = max(self.applied_seq, seq)
        self.replicated_records += 1

    # ------------------------------------------------------------------
    def promote(self) -> None:
        """Take over as primary (idempotent; also callable directly in
        tests to skip the lease wait)."""
        if self.promoted.is_set():
            return
        t0 = time.monotonic()
        catchup = 0
        if self.primary_journal_path is not None:
            events = list(Journal.replay(self.primary_journal_path))
            if (
                events
                and events[0][1] == "snapshot"
                and events[0][0] <= self.applied_seq
            ):
                # the primary compacted after we started tailing: the
                # incremental records we applied were folded into this
                # snapshot record, whose seq K <= applied_seq would be
                # skipped below.  Rebuild from scratch — compaction
                # preserves monotonic seqs, so the snapshot plus the tail
                # events reproduce exactly the state we had, plus anything
                # the RPC tail had not fetched yet.
                with self.dispatcher._lock:
                    self.dispatcher._reset_state()
                self.applied_seq = 0
            for seq, etype, payload in events:
                if seq <= self.applied_seq and etype != "snapshot":
                    continue
                self._apply(seq, etype, payload)
                catchup += 1
        self.dispatcher._journal.set_mirror(False)
        with self.dispatcher._lock:
            self.dispatcher.finalize_restore()
        self.promote_stats = {
            "catchup_records": float(catchup),
            "promote_s": time.monotonic() - t0,
        }
        if self._on_promote is not None:
            self._on_promote(self)
        self.promoted.set()
