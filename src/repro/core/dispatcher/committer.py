"""Dispatcher snapshot committer (repro.snapshot integration).

``CommitterMixin`` owns the materialization control plane: stream
partitioning and assignment, fsync'd chunk-commit acknowledgements, stream
completion, and finalization.  ``apply_committer_event`` replays the same
transitions from the journal.
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, List, Optional

from ...data.graph import Graph
from ...snapshot.format import write_done, write_metadata
from ...snapshot.manager import (
    SnapshotState,
    StreamState,
    apply_chunk_committed,
    partition_streams,
)
from ..codecs import resolve_codec
from ..protocol import DEFAULT_CHUNK_BYTES, new_id
from .state import _Worker


class CommitterMixin:
    # ------------------------------------------------------------------
    # Snapshots / materialization (repro.snapshot): the committer layer
    # ------------------------------------------------------------------
    def rpc_start_snapshot(
        self,
        path: str,
        dataset_id: Optional[str] = None,
        graph_bytes: Optional[bytes] = None,
        num_streams: int = 0,
        compression: Optional[str] = None,
        client_codecs: Optional[List[str]] = None,
        chunk_bytes: int = 0,
        seed_base: int = 0,
        replace_stale_s: Optional[float] = None,
    ) -> Dict[str, Any]:
        """Get-or-start materializing a dataset to ``path`` (idempotent
        per (path, pipeline fingerprint)).

        Partitions the source into ``num_streams`` streams (default: one
        per registered worker), journals the plan, and assigns streams to
        workers round-robin; workers receive their assignments via
        heartbeat and start appending committed chunks.

        A path already holding a DIFFERENT pipeline's snapshot is an error
        (manifests merge by seq — mixing pipelines would silently
        interleave their batches).  A path with an unfinished snapshot no
        dispatcher tracks (a dead deployment's partial write) is refused
        unless ``replace_stale_s`` is given and the write has been idle at
        least that long, in which case the stale directory is cleared and
        the snapshot restarts.
        """
        from ...snapshot.format import read_metadata
        from ...snapshot.reader import last_progress_unix, snapshot_finished

        with self._lock:
            path = os.path.abspath(path)
            if dataset_id is None:
                if graph_bytes is None:
                    raise ValueError("start_snapshot needs dataset_id or graph_bytes")
                dataset_id = self.rpc_get_or_register_dataset(graph_bytes)["dataset_id"]
            ds = self._datasets[dataset_id]
            if path in self._snapshots_by_path:
                snap = self._snapshots[self._snapshots_by_path[path]]
                if snap.fingerprint != ds.fingerprint:
                    raise ValueError(
                        f"snapshot path {path} already materializes pipeline "
                        f"{snap.fingerprint}, not {ds.fingerprint} — use a "
                        f"different path per pipeline"
                    )
                return dict(snap.view(), existing=True)
            meta = read_metadata(path)
            if meta is not None:  # on-disk snapshot this dispatcher doesn't track
                if meta.get("fingerprint") != ds.fingerprint:
                    raise ValueError(
                        f"snapshot path {path} holds pipeline "
                        f"{meta.get('fingerprint')}, not {ds.fingerprint}"
                    )
                if snapshot_finished(path):
                    # adopt the finished snapshot read-only: report success
                    from ...snapshot.reader import snapshot_status

                    return dict(snapshot_status(path), existing=True, path=path)
                # wall clock on purpose: last_progress_unix is an mtime
                # stamped by whichever process owned the write — epoch time
                # is the only clock both sides share
                idle = time.time() - last_progress_unix(path)
                if replace_stale_s is None or idle < replace_stale_s:
                    raise ValueError(
                        f"snapshot path {path} holds an unfinished write this "
                        f"dispatcher doesn't track (idle {idle:.0f}s); pass "
                        f"replace_stale_s to restart it or use a fresh path"
                    )
                import shutil

                # Rare admin path (restarting a stale snapshot); holding the
                # dispatcher lock across the tree delete is acceptable — it
                # runs once per start_snapshot, not on any hot path.
                shutil.rmtree(path, ignore_errors=True)  # analysis: allow(L003)
            num_streams = int(num_streams) or max(1, len(self._workers))
            streams = partition_streams(
                Graph.from_bytes(ds.graph_bytes), num_streams, self._overpartition
            )
            payload = {
                "snapshot_id": new_id("snap"),
                "path": path,
                "dataset_id": dataset_id,
                "fingerprint": ds.fingerprint,
                "codec": resolve_codec(compression, client_codecs),
                "chunk_bytes": int(chunk_bytes) or DEFAULT_CHUNK_BYTES,
                "seed_base": int(seed_base),
                "streams": streams,
                # minted HERE, before journaling: replay re-writes the
                # on-disk metadata and must stamp the SAME creation time,
                # not its own clock
                "created_unix": time.time(),
            }
            self._journal.append("snapshot_started", payload, sync=True)
            snap = self._apply_snapshot_started(payload)
            # initial round-robin assignment over the current worker pool;
            # workers registering later pick up unassigned streams on
            # heartbeat (and reassignment after failures does the same)
            workers = sorted(self._workers)
            for i, stream in enumerate(snap.streams):
                if workers:
                    self._assign_stream(snap, stream, workers[i % len(workers)])
            return dict(snap.view(), existing=False)

    def _apply_snapshot_started(self, p: Dict[str, Any]) -> SnapshotState:
        snap = SnapshotState(
            snapshot_id=p["snapshot_id"],
            path=p["path"],
            dataset_id=p["dataset_id"],
            fingerprint=p["fingerprint"],
            codec=p.get("codec"),
            chunk_bytes=p["chunk_bytes"],
            seed_base=p.get("seed_base", 0),
            streams=[
                StreamState(stream_id=i, shards=shards)
                for i, shards in enumerate(p["streams"])
            ],
        )
        self._snapshots[snap.snapshot_id] = snap
        self._snapshots_by_path[snap.path] = snap.snapshot_id
        # idempotent: (re)write the immutable on-disk metadata so readers on
        # the shared FS can discover the snapshot without the dispatcher
        write_metadata(
            snap.path,
            snap.snapshot_id,
            snap.fingerprint,
            snap.codec,
            snap.chunk_bytes,
            len(snap.streams),
            snap.seed_base,
            # journaled by rpc_start_snapshot; 0.0 only for pre-upgrade logs
            created_unix=p.get("created_unix", 0.0),
        )
        return snap

    def _assign_stream(
        self, snap: SnapshotState, stream: StreamState, worker_id: str
    ) -> None:
        self._journal.append(
            "snapshot_stream_assigned",
            {
                "snapshot_id": snap.snapshot_id,
                "stream_id": stream.stream_id,
                "worker_id": worker_id,
            },
        )
        stream.assigned_to = worker_id
        # the spec must be (re)shipped with fresh resume state
        key = (snap.snapshot_id, stream.stream_id)
        for w in self._workers.values():
            w.delivered_streams.discard(key)

    def _assign_snapshot_streams(self, worker_id: str) -> None:
        """Hand unowned streams to a live worker, keeping the load fair.

        Streams lose their owner on worker failure (or were never assigned
        because no worker was registered at start).  Each heartbeat tops the
        calling worker up to its fair share of the remaining streams.  A
        stream whose recorded owner has not (re-)registered is NOT up for
        grabs here: after a dispatcher restart the owner usually comes back
        within a heartbeat, and the orphan sweep reclaims it after the
        grace period if it doesn't (stealing a live writer's stream would
        force a pointless re-production of its whole uncommitted suffix).
        """
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            unowned = [s for s in snap.streams if not s.done and s.assigned_to is None]
            if not unowned:
                continue
            fair = -(-len(snap.undone_streams()) // max(1, len(self._workers)))
            owned = len(snap.streams_for_worker(worker_id))
            for s in unowned:
                if owned >= fair:
                    break
                self._assign_stream(snap, s, worker_id)
                owned += 1

    def _undelivered_snapshot_streams(self, w: _Worker) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            ds = self._datasets[snap.dataset_id]
            for s in snap.streams:
                if s.done or s.assigned_to != w.info.worker_id:
                    continue
                key = (snap.snapshot_id, s.stream_id)
                if key in w.delivered_streams:
                    continue
                w.delivered_streams.add(key)
                out.append(snap.stream_spec(s, ds.graph_bytes))
        return out

    def rpc_snapshot_commit_chunk(
        self,
        snapshot_id: str,
        stream_id: int,
        worker_id: str,
        seq: int,
        count: int,
        nbytes: int = 0,
    ) -> Dict[str, Any]:
        """Acknowledge one committed chunk (journaled with fsync BEFORE the
        ack — the ack is the writer's license to treat the chunk as durable
        committer state).  A non-owner report means the stream was
        reassigned: the (zombie) writer must stop."""
        with self._lock:
            snap = self._snapshots.get(snapshot_id)
            if snap is None or stream_id >= len(snap.streams):
                return {"ok": False, "reassigned": True}
            stream = snap.streams[stream_id]
            if stream.done or stream.assigned_to != worker_id:
                return {"ok": False, "reassigned": True}
            if seq < stream.next_seq:
                return {"ok": True, "dup": True}  # redelivered report
            if seq != stream.next_seq:
                # gap: acks for earlier chunks are still in flight (queued
                # worker-side while the dispatcher was down, draining via
                # heartbeat) — tell the writer to re-queue this one BEHIND
                # them rather than treating the stream as lost
                return {"ok": False, "retry": True}
            self._crash("commit_chunk.pre")
            self._journal.append(
                "snapshot_chunk_committed",
                {
                    "snapshot_id": snapshot_id,
                    "stream_id": stream_id,
                    "seq": seq,
                    "count": count,
                    "nbytes": nbytes,
                },
                sync=True,
            )
            self._crash("commit_chunk.journaled")
            apply_chunk_committed(stream, seq, count, nbytes)
            return {"ok": True}

    def rpc_snapshot_stream_done(
        self, snapshot_id: str, stream_id: int, worker_id: str
    ) -> Dict[str, Any]:
        with self._lock:
            snap = self._snapshots.get(snapshot_id)
            if snap is None or stream_id >= len(snap.streams):
                return {"ok": False, "reassigned": True}
            stream = snap.streams[stream_id]
            if stream.done:
                return {"ok": True, "dup": True}
            if stream.assigned_to != worker_id:
                return {"ok": False, "reassigned": True}
            self._journal.append(
                "snapshot_stream_done",
                {"snapshot_id": snapshot_id, "stream_id": stream_id},
                sync=True,
            )
            self._apply_stream_done(snap, stream_id)
            return {"ok": True}

    def _apply_stream_done(self, snap: SnapshotState, stream_id: int) -> None:
        stream = snap.streams[stream_id]
        stream.done = True
        stream.assigned_to = None
        if snap.all_streams_done and not snap.finished:
            self._journal.append(
                "snapshot_finished", {"snapshot_id": snap.snapshot_id}, sync=True
            )
            self._finalize_snapshot(snap)

    def _finalize_snapshot(self, snap: SnapshotState) -> None:
        snap.finished = True
        # the DONE marker is what detached readers key "finished" off;
        # idempotent so a restored dispatcher can re-run it
        write_done(snap.path, snap.summary())

    def rpc_snapshot_status(
        self, snapshot_id: Optional[str] = None, path: Optional[str] = None
    ) -> Dict[str, Any]:
        with self._lock:
            if snapshot_id is None and path is not None:
                snapshot_id = self._snapshots_by_path.get(os.path.abspath(path))
            snap = self._snapshots.get(snapshot_id or "")
            if snap is None:
                return {"exists": False, "finished": False}
            return dict(snap.view(), exists=True)

    def _release_failed_stream(
        self, snapshot_id: str, stream_id: int, worker_id: str
    ) -> None:
        snap = self._snapshots.get(snapshot_id)
        if snap is None or snap.finished or stream_id >= len(snap.streams):
            return
        stream = snap.streams[stream_id]
        if stream.done or stream.assigned_to != worker_id:
            return
        self._journal.append(
            "snapshot_stream_released",
            {"snapshot_id": snapshot_id, "stream_id": stream_id},
        )
        stream.assigned_to = None
        key = (snapshot_id, stream_id)
        for w in self._workers.values():
            w.delivered_streams.discard(key)
        # reassignment happens via _assign_snapshot_streams on the next
        # heartbeat of any worker (including the one that just failed)

    def _release_worker_streams(self, worker_id: str) -> None:
        """Worker died: orphan its streams and reassign them immediately so
        materialization continues (replacements resume at the committed
        offset — the journal has every acknowledged chunk)."""
        survivors = sorted(self._workers)
        i = 0
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            for s in snap.streams:
                if s.assigned_to == worker_id and not s.done:
                    self._journal.append(
                        "snapshot_stream_released",
                        {"snapshot_id": snap.snapshot_id, "stream_id": s.stream_id},
                    )
                    s.assigned_to = None
                    if survivors:
                        self._assign_stream(snap, s, survivors[i % len(survivors)])
                        i += 1

    # ------------------------------------------------------------------
    # Journal replay (committer events)
    # ------------------------------------------------------------------
    def apply_committer_event(self, etype: str, p: Dict[str, Any]) -> bool:
        """Apply one replayed committer event.  Returns False for event
        types this module does not own.  Caller holds ``self._lock``."""
        if etype == "snapshot_started":
            self._apply_snapshot_started(p)
        elif etype == "snapshot_stream_assigned":
            snap = self._snapshots.get(p["snapshot_id"])
            if snap is not None:
                # keep the assignment: a live writer continues
                # seamlessly; a dead one is reclaimed by the orphan
                # sweep / check_workers like in-flight shards
                snap.streams[p["stream_id"]].assigned_to = p["worker_id"]
        elif etype == "snapshot_stream_released":
            snap = self._snapshots.get(p["snapshot_id"])
            if snap is not None:
                snap.streams[p["stream_id"]].assigned_to = None
        elif etype == "snapshot_chunk_committed":
            snap = self._snapshots.get(p["snapshot_id"])
            if snap is not None:
                apply_chunk_committed(
                    snap.streams[p["stream_id"]],
                    p["seq"],
                    p["count"],
                    p.get("nbytes", 0),
                )
        elif etype == "snapshot_stream_done":
            snap = self._snapshots.get(p["snapshot_id"])
            if snap is not None:
                stream = snap.streams[p["stream_id"]]
                stream.done = True
                stream.assigned_to = None
        elif etype == "snapshot_finished":
            snap = self._snapshots.get(p["snapshot_id"])
            if snap is not None:
                # re-runs write_done: idempotent, covers a crash
                # between the journal append and the DONE marker
                self._finalize_snapshot(snap)
        else:
            return False
        return True
