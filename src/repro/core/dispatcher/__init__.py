"""Dispatcher package: control plane, committer, fleet scheduling, HA.

Split from a single-module dispatcher so state transitions have narrow,
testable seams:

  * ``state``       — in-memory records (_Dataset, _Job, _Worker)
  * ``control``     — datasets, jobs, workers, DYNAMIC shard hand-out
  * ``committer``   — snapshot streams and fsync'd chunk commits
  * ``fleet``       — multi-tenant fleet-scheduling integration
  * ``core``        — the composed :class:`Dispatcher` + journal replay
  * ``replica``     — :class:`StandbyDispatcher` (hot-standby failover)
  * ``crashpoints`` — chaos-harness crash injection

``from repro.core.dispatcher import Dispatcher`` keeps working unchanged.
"""
from .core import Dispatcher
from .crashpoints import CrashPoints, DispatcherCrashed
from .replica import StandbyDispatcher
from .state import _Dataset, _Job, _Worker

__all__ = [
    "Dispatcher",
    "StandbyDispatcher",
    "CrashPoints",
    "DispatcherCrashed",
]
