"""Dispatcher in-memory state records.

Shared by the control-plane, committer, and fleet-scheduling modules; every
mutation that must survive a restart is journaled by the code that performs
it — these dataclasses are pure book-keeping.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from ..protocol import ShardingPolicy, TaskSpec, WorkerInfo
from ..sharding import ShardManager


@dataclass
class _Dataset:
    dataset_id: str
    graph_bytes: bytes
    fingerprint: str


@dataclass
class _Job:
    job_id: str
    job_name: str
    dataset_id: str
    policy: ShardingPolicy
    num_consumers: int = 0
    sharing: bool = False
    compression: Optional[str] = None
    max_workers: int = 0  # 0 = use all registered workers
    weight: float = 1.0  # fleet-scheduler share weight (multi-tenant fairness)
    resume_offsets: bool = False
    tasks: Dict[str, TaskSpec] = field(default_factory=dict)  # by task_id
    tasks_by_worker: Dict[str, str] = field(default_factory=dict)
    completed_tasks: Set[str] = field(default_factory=set)
    shard_mgr: Optional[ShardManager] = None
    finished: bool = False
    clients: Set[str] = field(default_factory=set)
    seq: int = 0  # task seeds
    static_assignment: Optional[Dict[str, List[Dict[str, Any]]]] = None
    autocache_decision: Optional[str] = None  # compute | write_through | read
    # latest feed-stall report per client (repro.feed heartbeat payloads),
    # each stamped with the monotonic receive time for staleness filtering
    client_stall: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    # fleet-scheduler worker share: None = unscheduled (task on every
    # worker, the pre-scheduler behavior); an int caps auto-granted tasks
    target_share: Optional[int] = None
    # the job-level trace context (wire dict) minted by the registering
    # client; journaled with job_created so task specs shipped by a
    # promoted standby keep stamping spans with the same trace_id
    trace: Optional[Dict[str, Any]] = None


@dataclass
class _Worker:
    info: WorkerInfo
    last_heartbeat: float = field(default_factory=time.monotonic)
    buffer_occupancy: float = 0.0
    cpu_busy: float = 0.0
    delivered: Set[str] = field(default_factory=set)  # task ids shipped
    # (snapshot_id, stream_id) assignments shipped to this worker
    delivered_streams: Set[Any] = field(default_factory=set)
    # latest heartbeat-reported SlidingWindowCache counters, by cache key
    # (pipeline fingerprint) — feeds sharing-efficiency introspection and
    # the autocache policy's hot-pipeline signal
    cache_stats: Dict[str, Dict[str, Any]] = field(default_factory=dict)
