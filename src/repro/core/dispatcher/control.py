"""Dispatcher control plane: datasets, jobs, workers, shard hand-out.

``ControlPlaneMixin`` owns every client/worker-facing state transition that
is not snapshot materialization (``committer.py``) or fleet scheduling
(``fleet.py``).  Mutations are journaled before they are applied and
acknowledged; ``apply_control_event`` replays the same transitions from the
journal — on restart, or incrementally on a tailing hot standby.
"""
from __future__ import annotations

import time
from typing import Any, Dict, List, Optional

from ...data.graph import Graph, Node
from ...obs.tracing import TraceContext
from ..protocol import ShardingPolicy, TaskSpec, WorkerInfo, new_id
from ..sharding import ShardManager
from ..codecs import resolve_codec
from ...snapshot.policy import Decision
from .state import _Dataset, _Job, _Worker


class ControlPlaneMixin:
    # ------------------------------------------------------------------
    # Datasets & jobs (client-facing)
    # ------------------------------------------------------------------
    def rpc_get_or_register_dataset(self, graph_bytes: bytes) -> Dict[str, Any]:
        """Register the RAW client graph; optimize once, dispatcher-side.

        The content fingerprint is taken over the bytes the client sent —
        BEFORE optimization — because optimizer passes synthesize fresh
        fused closures whose serialization is not content-stable.  Two jobs
        submitting identical pipelines must land on the same dataset_id, or
        ephemeral data sharing (§3.5) silently degrades to one cache per
        job.  Workers receive the optimized graph.
        """
        g = Graph.from_bytes(graph_bytes)
        fp = g.fingerprint()
        with self._lock:
            if fp in self._datasets_by_fp:
                return {"dataset_id": self._datasets_by_fp[fp], "fingerprint": fp}
            from ...data.optimizer import optimize_graph

            opt_bytes = optimize_graph(g).to_bytes()
            ds_id = new_id("ds")
            self._journal.append(
                "dataset_registered",
                {"dataset_id": ds_id, "graph_bytes": opt_bytes, "fingerprint": fp},
            )
            self._apply_dataset(ds_id, opt_bytes, fp)
            return {"dataset_id": ds_id, "fingerprint": fp}

    def _apply_dataset(self, ds_id: str, graph_bytes: bytes, fp: str) -> None:
        self._datasets[ds_id] = _Dataset(ds_id, graph_bytes, fp)
        self._datasets_by_fp[fp] = ds_id

    def rpc_get_or_create_job(
        self,
        dataset_id: str,
        job_name: Optional[str] = None,
        policy: str = "off",
        num_consumers: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        max_workers: int = 0,
        weight: float = 1.0,
        resume_offsets: bool = False,
        client_id: Optional[str] = None,
        client_codecs: Optional[List[str]] = None,
        autocache: bool = False,
        trace: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        with self._lock:
            if job_name and job_name in self._jobs_by_name:
                job = self._jobs[self._jobs_by_name[job_name]]
                if client_id:
                    job.clients.add(client_id)
                return self._job_view(job)
            decision = None
            if autocache and self._autocache is not None:
                dataset_id, decision = self._autocache_decide(
                    dataset_id, compression=compression, client_codecs=client_codecs
                )
            payload = dict(
                job_id=new_id("job"),
                job_name=job_name or "",
                dataset_id=dataset_id,
                policy=str(ShardingPolicy.parse(policy).value),
                num_consumers=num_consumers,
                sharing=sharing,
                # codec negotiation (restricted to what the requesting
                # client can decode): the journaled payload carries the
                # RESOLVED codec so workers joining after a dispatcher
                # restart compress with the same algorithm
                compression=resolve_codec(compression, client_codecs),
                max_workers=max_workers,
                weight=max(1e-3, float(weight)),
                resume_offsets=resume_offsets,
                # journaled so a restored dispatcher partitions the source
                # into the SAME shards (ids must stay aligned with the log)
                shard_hint=max(1, len(self._workers)) * self._overpartition,
                autocache_decision=decision,
                # job-level trace root (observability): journaled so a
                # restarted/promoted dispatcher ships task specs carrying
                # the SAME trace_id the client minted
                trace=trace,
            )
            self._journal.append("job_created", payload)
            job = self._apply_job(payload)
            self._grant_initial_tasks(job)
            if client_id:
                job.clients.add(client_id)
            return self._job_view(job)

    def _autocache_decide(
        self,
        dataset_id: str,
        compression: Optional[str],
        client_codecs: Optional[List[str]],
    ) -> "tuple[str, Optional[str]]":
        """Resolve an autocache job's effective dataset.

        READ swaps the job onto a snapshot-source dataset (registered and
        journaled like any other); WRITE_THROUGH starts materializing the
        pipeline (get-or-start) while the job computes as usual.
        """
        ds = self._datasets[dataset_id]
        d = self._autocache.decide(
            ds.fingerprint, cache_stats=self._aggregate_cache_stats(ds.fingerprint)
        )
        if d.decision == Decision.READ:
            snap_graph = Graph([Node("snapshot", {"path": d.snapshot_path})])
            resp = self.rpc_get_or_register_dataset(snap_graph.to_bytes())
            return resp["dataset_id"], d.value
        if d.decision == Decision.WRITE_THROUGH:
            self.rpc_start_snapshot(
                path=d.snapshot_path,
                dataset_id=dataset_id,
                compression=compression,
                client_codecs=client_codecs,
                # the policy only answers WRITE_THROUGH for an existing dir
                # when the write is abandoned — allow clearing it
                replace_stale_s=self._autocache.config.stale_write_timeout_s,
            )
        return dataset_id, d.value

    def _aggregate_cache_stats(self, cache_key: str) -> Optional[Dict[str, Any]]:
        """Sum heartbeat-reported SlidingWindowCache counters for one key."""
        agg: Dict[str, float] = {}
        found = False
        for w in self._workers.values():
            st = w.cache_stats.get(cache_key)
            if not st:
                continue
            found = True
            for k, v in st.items():
                if isinstance(v, (int, float)):
                    agg[k] = agg.get(k, 0) + v
        return agg if found else None

    # feed-stall reports older than this are ignored by the aggregate — a
    # finished/stuck consumer must not pin the autoscaler's view forever
    STALL_REPORT_TTL_S = 10.0

    def _aggregate_client_stall(self, job: _Job) -> Optional[Dict[str, float]]:
        """Mean of the job's fresh per-client feed-stall windows.

        Expired entries are pruned, not just filtered: client churn on a
        long-lived job (every feeder session is a fresh client_id) must
        not grow the dict without bound.  Callers hold ``self._lock``.
        """
        now = time.monotonic()
        for cid in [
            cid
            for cid, r in job.client_stall.items()
            if now - r.get("t", 0.0) > self.STALL_REPORT_TTL_S
        ]:
            del job.client_stall[cid]
        fresh = list(job.client_stall.values())
        if not fresh:
            return None
        n = len(fresh)

        def mean(key: str) -> float:
            return sum(float(r.get(key, 0.0)) for r in fresh) / n

        return {
            "clients": float(n),
            "stall_frac": mean("stall_frac"),
            "idle_s_per_step": mean("idle_s_per_step"),
            "fetch_s_per_step": mean("fetch_s_per_step"),
            "transfer_s_per_step": mean("transfer_s_per_step"),
            "queue_depth": mean("queue_depth"),
        }

    def _apply_job(self, p: Dict[str, Any]) -> _Job:
        job = _Job(
            job_id=p["job_id"],
            job_name=p["job_name"],
            dataset_id=p["dataset_id"],
            policy=ShardingPolicy(p["policy"]),
            num_consumers=p["num_consumers"],
            sharing=p["sharing"],
            compression=p.get("compression"),
            max_workers=p.get("max_workers", 0),
            weight=p.get("weight", 1.0),
            resume_offsets=p.get("resume_offsets", False),
            autocache_decision=p.get("autocache_decision"),
            target_share=p.get("target_share"),
            trace=p.get("trace"),
        )
        if job.policy in (ShardingPolicy.DYNAMIC, ShardingPolicy.STATIC):
            graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
            hint = p.get("shard_hint") or max(1, len(self._workers)) * self._overpartition
            job.shard_mgr = ShardManager(
                graph,
                job.policy,
                num_workers_hint=hint,
                overpartition=1,
                resume_offsets=job.resume_offsets,
            )
        self._jobs[job.job_id] = job
        if job.job_name:
            self._jobs_by_name[job.job_name] = job.job_id
        return job

    def _grant_initial_tasks(self, job: _Job) -> None:
        """Initial task grants for a freshly created job.

        Called from the RPC path only, NEVER from replay: task grants mint
        fresh ids and journal ``task_created`` records, and on replay the
        tasks are reconstructed verbatim from those records (the worker
        pool is empty during replay anyway, so granting there is at best a
        no-op and at worst a source of divergence).
        """
        # a new schedulable job starts at its weighted fair share of the
        # fleet, placed on the least-loaded workers (rebalance() adjusts it
        # from demand); unscheduled jobs (and non-scheduling deployments)
        # get a task on every worker (scale-out)
        if self._scheduler is not None and self._schedulable(job):
            if job.target_share is None:
                job.target_share = self._initial_share(job)
            if job.target_share is not None:
                self._apply_share(job, job.target_share)
        else:
            for w in self._workers.values():
                self._ensure_task(job, w.info)

    def _ensure_task(self, job: _Job, w: WorkerInfo) -> Optional[TaskSpec]:
        if job.finished or w.worker_id in job.tasks_by_worker:
            return None
        if (job.job_id, w.worker_id) in self._pending_reclaims:
            # this worker is still draining a retired task for the job:
            # granting a fresh one now would hand the new runner shards
            # while the pending reclaim is about to yank them back
            return None
        # count only ACTIVE tasks (live workers, not completed): tasks left
        # behind by dead workers must not eat into the cap, or a capped job
        # ends up permanently under-provisioned after worker churn
        if job.max_workers or job.target_share is not None:
            active = self._slot_count(job)
            if job.max_workers and active >= job.max_workers:
                return None
            if (
                self._scheduler is not None
                and job.target_share is not None
                and self._schedulable(job)
                and active >= job.target_share
            ):
                return None
        ds = self._datasets[job.dataset_id]
        job.seq += 1
        task = TaskSpec(
            task_id=new_id("task"),
            job_id=job.job_id,
            dataset_id=job.dataset_id,
            worker_id=w.worker_id,
            worker_address=w.address,
            policy=job.policy.value,
            num_consumers=job.num_consumers,
            round_robin=job.num_consumers > 0,
            shared=job.sharing,
            cache_key=ds.fingerprint if job.sharing else None,
            worker_seed=job.seq,
        )
        # journal task creation: task ids must be STABLE across dispatcher
        # restarts so live workers/clients keep their handles (§3.4)
        self._journal.append("task_created", vars(task).copy())
        self._apply_task(job, task)
        return task

    def _apply_task(self, job: _Job, task: TaskSpec) -> None:
        job.tasks[task.task_id] = task
        job.tasks_by_worker[task.worker_id] = task.task_id

    def _job_view(self, job: _Job) -> Dict[str, Any]:
        return {
            "job_id": job.job_id,
            "dataset_id": job.dataset_id,
            "policy": job.policy.value,
            "num_consumers": job.num_consumers,
            "finished": job.finished,
            "worker_list_version": self._worker_list_version,
            "compression": job.compression,
            "autocache": job.autocache_decision,
            "tasks": [vars(t) for t in self._visible_tasks(job)],
        }

    def _visible_tasks(self, job: _Job) -> List[TaskSpec]:
        """Tasks listed to clients.

        Within the post-restore grace window journaled uncompleted tasks
        are listed even though their workers have not re-registered yet:
        only the dispatcher restarted — the workers (and the buffers they
        hold) are still alive at their journaled addresses.  Dropping them
        from the view here would make clients fail their handles, and
        coordinated consumers that heartbeat at different moments during
        the window would remap rounds to different workers (breaking the
        same-bucket-per-round guarantee).  If a worker really did die, the
        grace expires and the next view drops it.
        """
        if (
            self._task_grace_deadline is not None
            and time.monotonic() < self._task_grace_deadline
        ):
            return [
                t for t in job.tasks.values() if t.task_id not in job.completed_tasks
            ]
        return self._active_tasks(job)

    def _active_tasks(self, job: _Job) -> List[TaskSpec]:
        return [
            t
            for t in job.tasks.values()
            if t.task_id not in job.completed_tasks
            and t.worker_id in self._workers
        ]

    def _slot_count(self, job: _Job) -> int:
        """Tasks counted against the job's worker cap/share.

        Normally the ACTIVE tasks; within the post-restore grace window
        every journaled (uncompleted) task holds its slot even though its
        worker has not re-registered yet — the owner is probably mid-
        reconnect, and handing its slot to a faster-registering worker
        would inflate the job past its journaled allocation.
        """
        if (
            self._task_grace_deadline is not None
            and time.monotonic() < self._task_grace_deadline
        ):
            return len(
                [t for t in job.tasks.values() if t.task_id not in job.completed_tasks]
            )
        self._task_grace_deadline = None
        return len(self._active_tasks(job))

    def rpc_client_heartbeat(
        self,
        job_id: str,
        client_id: str,
        starving: bool = False,
        stall_stats: Optional[Dict[str, Any]] = None,
        trace: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        self._crash("client_heartbeat")
        ctx = TraceContext.from_wire(trace) if trace else None
        wall = time.time() if ctx is not None else 0.0
        t0 = time.perf_counter()
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown job {job_id}")
            job.clients.add(client_id)
            if stall_stats:
                job.client_stall[client_id] = {
                    "t": time.monotonic(),
                    **stall_stats,
                }
            self._maybe_finish(job)
            view = self._job_view(job)
            view["starving_ack"] = starving
        if ctx is not None:
            # control-plane span: the chaos suite asserts these keep the
            # job's trace_id across a standby promotion
            self.tracer.record(
                "dispatcher.heartbeat",
                ctx.child(),
                wall,
                time.perf_counter() - t0,
                parent_id=ctx.span_id,
                job_id=job_id,
                client_id=client_id,
            )
        return view

    # ------------------------------------------------------------------
    # Workers
    # ------------------------------------------------------------------
    def rpc_register_worker(
        self, worker_id: str, address: str, tags: Optional[Dict[str, Any]] = None
    ) -> Dict[str, Any]:
        with self._lock:
            self._journal.append(
                "worker_registered", {"worker_id": worker_id, "address": address}
            )
            is_new = worker_id not in self._workers
            # (re)registration resets delivery state — stateless workers that
            # restart must receive their tasks again (paper §3.4)
            self._workers[worker_id] = _Worker(WorkerInfo(worker_id, address, tags or {}))
            if is_new:
                self._worker_list_version += 1
            w = self._workers[worker_id]
            tasks = self._undelivered_tasks(w)
            self._assign_snapshot_streams(worker_id)
            return {
                "tasks": tasks,
                "snapshot_streams": self._undelivered_snapshot_streams(w),
                "worker_list_version": self._worker_list_version,
            }

    def _undelivered_tasks(self, w: _Worker) -> List[Dict[str, Any]]:
        """Tasks for every active job not yet shipped to this worker."""
        out: List[Dict[str, Any]] = []
        for job in self._jobs.values():
            if job.finished:
                continue
            t = self._ensure_task(job, w.info)
            if t is None:
                tid = job.tasks_by_worker.get(w.info.worker_id)
                if tid and tid not in job.completed_tasks:
                    t = job.tasks[tid]
            if t is not None and t.task_id not in w.delivered:
                w.delivered.add(t.task_id)
                out.append(self._task_payload(t, job))
        return out

    def _task_payload(self, t: TaskSpec, job: _Job) -> Dict[str, Any]:
        ds = self._datasets[job.dataset_id]
        p = vars(t).copy()
        p["graph_bytes"] = ds.graph_bytes
        p["compression"] = job.compression
        p["resume_offsets"] = job.resume_offsets
        p["static_shards"] = None
        if job.trace:
            # worker pipeline spans parent to the job's root trace context
            p["trace"] = job.trace
        if job.policy == ShardingPolicy.STATIC and job.shard_mgr is not None:
            # computed ONCE over the workers present at first hand-out (the
            # paper's "up-front" semantics) and journaled for restart stability
            if job.static_assignment is None:
                assignment = job.shard_mgr.static_assignment(
                    sorted(job.tasks_by_worker)
                )
                self._journal.append(
                    "static_assignment",
                    {"job_id": job.job_id, "assignment": assignment},
                )
                job.static_assignment = assignment
            p["static_shards"] = job.static_assignment.get(t.worker_id, [])
        return p

    def rpc_worker_heartbeat(
        self,
        worker_id: str,
        buffer_occupancy: float = 0.0,
        cpu_busy: float = 0.0,
        completed_tasks: Optional[List[str]] = None,
        cache_stats: Optional[Dict[str, Dict[str, Any]]] = None,
        failed_streams: Optional[List[List[Any]]] = None,
    ) -> Dict[str, Any]:
        self._crash("worker_heartbeat")
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                # unknown worker (e.g. dispatcher restarted): ask it to re-register
                return {"reregister": True}
            w.last_heartbeat = time.monotonic()
            w.buffer_occupancy = buffer_occupancy
            w.cpu_busy = cpu_busy
            if cache_stats is not None:
                w.cache_stats = cache_stats
            self._step_pending_reclaims(worker_id)
            for tid in completed_tasks or []:
                self._complete_task(tid, journal=True)
            for sid, stream_id in failed_streams or []:
                # the worker's writer died on an exception: release the
                # stream so it can be retried (here or elsewhere) from the
                # last committed offset
                self._release_failed_stream(sid, int(stream_id), worker_id)
            new_tasks = self._undelivered_tasks(w)
            self._assign_snapshot_streams(worker_id)
            valid = [
                job.tasks_by_worker[worker_id]
                for job in self._jobs.values()
                if worker_id in job.tasks_by_worker and not job.finished
            ]
            return {
                "new_tasks": new_tasks,
                "snapshot_streams": self._undelivered_snapshot_streams(w),
                "valid_tasks": valid,
                "worker_list_version": self._worker_list_version,
                "reregister": False,
            }

    def _complete_task(self, task_id: str, journal: bool) -> None:
        for job in self._jobs.values():
            if task_id in job.tasks and task_id not in job.completed_tasks:
                if journal:
                    self._journal.append("task_completed", {"task_id": task_id})
                job.completed_tasks.add(task_id)
                self._maybe_finish(job)

    def _maybe_finish(self, job: _Job) -> None:
        if job.finished or not job.tasks:
            return
        live = [t for t in job.tasks.values() if t.worker_id in self._workers]
        all_done = all(t.task_id in job.completed_tasks for t in live) and live
        if job.policy == ShardingPolicy.DYNAMIC and job.shard_mgr is not None:
            if job.shard_mgr.done() and all_done:
                self._finish_job(job)
        elif all_done:
            self._finish_job(job)

    def _finish_job(self, job: _Job) -> None:
        self._journal.append("job_finished", {"job_id": job.job_id})
        job.finished = True

    # -- failure detection ------------------------------------------------
    def check_workers(self) -> List[str]:
        """Mark workers dead after heartbeat timeout. Returns removed ids.

        Called by the orchestrator's GC loop (or tests directly).
        """
        if self._failed:
            return []  # crashed dispatcher: the GC loop must not mutate state
        now = time.monotonic()
        removed = []
        with self._lock:
            for wid, w in list(self._workers.items()):
                if now - w.last_heartbeat > self._heartbeat_timeout:
                    removed.append(wid)
                    self._remove_worker(wid)
            self._sweep_orphan_shards(now)
        return removed

    def _sweep_orphan_shards(self, now: float) -> None:
        """Reclaim shards AND snapshot streams assigned (pre-restart, per
        the journal) to workers that never re-registered.  check_workers
        can't see them — they are not in self._workers — so without this
        sweep such shards stay in-flight forever and the job (or snapshot)
        never finishes."""
        if self._orphan_sweep_deadline is None or now < self._orphan_sweep_deadline:
            return
        self._orphan_sweep_deadline = None
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            orphan_owners = {
                s.assigned_to
                for s in snap.streams
                if s.assigned_to and not s.done
                and s.assigned_to not in self._workers
            }
            # sorted: release order feeds journaled stream reassignment, and
            # set order is hash-seed dependent (differs across processes)
            for wid in sorted(orphan_owners):
                self._release_worker_streams(wid)
        for job in self._jobs.values():
            mgr = job.shard_mgr
            if mgr is None or job.finished:
                continue
            orphans = {
                st.assigned_to
                for st in mgr._states
                if st.assigned_to and not st.completed
                and st.assigned_to not in self._workers
            }
            # sorted: shard_lost records land in the journal in this order,
            # and two runs of the same primary must journal identically
            for wid in sorted(orphans):
                for sid in mgr.worker_failed(wid):
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": wid},
                    )
            if orphans:
                self._maybe_finish(job)
        # deferred retirement reclaims whose worker never re-registered
        # were just covered by the orphan sweep above
        for key in [k for k in self._pending_reclaims if k[1] not in self._workers]:
            del self._pending_reclaims[key]

    def rpc_remove_worker(self, worker_id: str) -> Dict[str, Any]:
        """Administrative removal (tests / orchestrator-initiated)."""
        with self._lock:
            self._remove_worker(worker_id)
        return {"ok": True}

    def _remove_worker(self, worker_id: str) -> None:
        if worker_id not in self._workers:
            return
        self._journal.append("worker_removed", {"worker_id": worker_id})
        del self._workers[worker_id]
        self._worker_list_version += 1
        # worker death supersedes any deferred retirement reclaim: the
        # worker_failed sweep below covers every job's in-flight shards
        for key in [k for k in self._pending_reclaims if k[1] == worker_id]:
            del self._pending_reclaims[key]
        self._release_worker_streams(worker_id)
        for job in self._jobs.values():
            if job.shard_mgr is not None:
                lost = job.shard_mgr.worker_failed(worker_id)
                for sid in lost:
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": worker_id},
                    )
            self._maybe_finish(job)

    # ------------------------------------------------------------------
    # DYNAMIC sharding hand-out (worker-facing)
    # ------------------------------------------------------------------
    def rpc_get_shard(
        self, job_id: str, worker_id: str, holding: Optional[List[int]] = None
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.shard_mgr is None:
                return {"done": True}
            if worker_id not in job.tasks_by_worker:
                # the worker's task was retired (fleet scheduler) but its
                # runner has not been pruned yet — handing it a shard would
                # strand that shard in-flight forever once the runner stops
                return {"done": True}
            if holding is not None:
                # Reconciliation: shards the journal says this worker holds
                # but the worker does NOT (a "shard_assigned" was journaled
                # and the crash ate the response, or a queued completion ack
                # was lost with the worker) delivered zero bytes worker-side,
                # so re-queuing them is exact — without this they would stay
                # in-flight forever and the job could never finish.
                held = set(holding)
                for sid in job.shard_mgr.assigned_to_worker(worker_id):
                    if sid in held:
                        continue
                    self._journal.append(
                        "shard_requeued",
                        {"job_id": job_id, "shard_id": sid, "worker_id": worker_id},
                    )
                    job.shard_mgr.requeue(sid, worker_id)
            nxt = job.shard_mgr.next_shard(worker_id)
            if nxt is None:
                # resume_offsets: an in-flight shard on a dying worker can
                # RE-ENTER the queue — "empty now" is not "drained".  Tell
                # workers to poll again instead of retiring their task.
                if job.shard_mgr.resume_offsets and not job.shard_mgr.done():
                    return {"done": False, "wait": True}
                return {"done": True}
            sid, shard, offset = nxt
            self._journal.append(
                "shard_assigned",
                {"job_id": job_id, "shard_id": sid, "worker_id": worker_id},
            )
            self._crash("get_shard.journaled")
            return {"done": False, "shard_id": sid, "shard": shard, "offset": offset}

    def rpc_complete_shard(
        self, job_id: str, shard_id: int, worker_id: str
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_completed",
                    {"job_id": job_id, "shard_id": shard_id, "worker_id": worker_id},
                )
                job.shard_mgr.complete_shard(shard_id, worker_id)
            return {"ok": True}

    def rpc_checkpoint_offset(
        self, job_id: str, shard_id: int, worker_id: str, offset: int
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_offset",
                    {"job_id": job_id, "shard_id": shard_id, "offset": offset},
                )
                job.shard_mgr.checkpoint_offset(shard_id, worker_id, offset)
            return {"ok": True}

    # ------------------------------------------------------------------
    # Journal replay (control-plane events)
    # ------------------------------------------------------------------
    def apply_control_event(self, etype: str, p: Dict[str, Any]) -> bool:
        """Apply one replayed control-plane event.  Returns False for event
        types this module does not own.  Caller holds ``self._lock``."""
        if etype == "dataset_registered":
            self._apply_dataset(p["dataset_id"], p["graph_bytes"], p["fingerprint"])
        elif etype == "job_created":
            self._apply_job(p)
        elif etype == "job_finished":
            if p["job_id"] in self._jobs:
                self._jobs[p["job_id"]].finished = True
        elif etype == "task_created":
            job = self._jobs.get(p["job_id"])
            if job is not None:
                task = TaskSpec(**p)
                self._apply_task(job, task)
                job.seq = max(job.seq, task.worker_seed)
        elif etype == "task_retired":
            job = self._jobs.get(p["job_id"])
            if job is not None:
                self._apply_task_retired(job, p["task_id"])
        elif etype == "static_assignment":
            job = self._jobs.get(p["job_id"])
            if job is not None:
                job.static_assignment = p["assignment"]
        elif etype == "task_completed":
            self._complete_task(p["task_id"], journal=False)
        elif etype == "shard_assigned":
            job = self._jobs.get(p["job_id"])
            if job and job.shard_mgr:
                # keep the assignment: the worker is (presumably) still
                # alive and processing; heartbeat timeout reclaims it
                mgr = job.shard_mgr
                with mgr._lock:
                    for st in mgr._states:
                        if st.shard_id == p["shard_id"]:
                            st.assigned_to = p["worker_id"]
                    try:
                        mgr._pending.remove(p["shard_id"])
                    except ValueError:
                        pass
        elif etype == "shard_requeued":
            job = self._jobs.get(p["job_id"])
            if job and job.shard_mgr:
                job.shard_mgr.requeue(p["shard_id"], p["worker_id"])
        elif etype == "shard_completed":
            job = self._jobs.get(p["job_id"])
            if job and job.shard_mgr:
                job.shard_mgr.complete_shard(p["shard_id"], p["worker_id"])
        elif etype == "shard_lost":
            job = self._jobs.get(p["job_id"])
            if job and job.shard_mgr:
                for st in job.shard_mgr._states:
                    if st.shard_id == p["shard_id"] and not st.completed:
                        st.lost = True
                        st.assigned_to = None
        elif etype == "shard_offset":
            job = self._jobs.get(p["job_id"])
            if job and job.shard_mgr:
                for st in job.shard_mgr._states:
                    if st.shard_id == p["shard_id"]:
                        st.offset = max(st.offset, p["offset"])
        elif etype in ("worker_registered", "worker_removed"):
            # Deliberate no-ops: workers are transient; they re-register
            # via heartbeat after a dispatcher restart, so replay must NOT
            # resurrect self._workers entries nobody is heartbeating for.
            # Tasks and in-flight shard assignments are preserved verbatim
            # (live workers continue seamlessly); workers that don't come
            # back are invisible to check_workers, and finalize_restore
            # arms the orphan sweep — one heartbeat-timeout of grace, then
            # their in-flight shards are reclaimed.  The events are still
            # journaled because the fleet-membership history is what the
            # orphan sweep and the chaos harness audit.
            pass
        else:
            return False
        return True
