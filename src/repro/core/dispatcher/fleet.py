"""Dispatcher fleet-scheduling integration (multi-tenant worker allocation).

``FleetMixin`` realizes the :class:`~repro.scheduler.FleetScheduler`'s
weighted max-min shares against live dispatcher state: granting tasks on the
least-loaded workers, retiring them from the most-loaded ones, and running
the deferred two-heartbeat shard-reclaim protocol that keeps retirement
exactly-once.
"""
from __future__ import annotations

import time
from typing import Any, Dict, Optional, Set

from ..protocol import ShardingPolicy, TaskSpec
from ..scheduler import JobDemand
from .state import _Job


class FleetMixin:
    # ------------------------------------------------------------------
    # Fleet scheduling (multi-tenant worker allocation)
    # ------------------------------------------------------------------
    def _schedulable(self, job: _Job) -> bool:
        """Jobs the fleet scheduler may grow/shrink.

        Coordinated-read jobs stripe rounds over the sorted worker set and
        STATIC jobs fix their partitions up front — resizing either would
        break their placement contract, so they keep the task-on-every-
        worker behavior and pin the fleet instead.
        """
        return (
            not job.finished
            and job.num_consumers == 0
            and job.policy != ShardingPolicy.STATIC
        )

    def _initial_share(self, job: _Job) -> Optional[int]:
        """Fair-share entry allocation for a newly created job."""
        capacity = len(self._workers)
        if capacity == 0:
            return None  # no fleet yet: first rebalance sets the share
        demands = [
            JobDemand(
                job_id=j.job_id,
                weight=j.weight,
                allocated=0 if j is job else len(self._active_tasks(j)),
                max_workers=j.max_workers,
            )
            for j in self._jobs.values()
            if self._schedulable(j)
        ]
        return self._scheduler.plan(capacity, demands).shares.get(job.job_id)

    def rebalance(self) -> Optional[Dict[str, Any]]:
        """One fleet-scheduling round; returns the plan view or None when
        scheduling is disabled.

        Each schedulable job's demand is derived from its own fresh
        ``client_stall`` aggregate; weighted max-min fairness arbitrates
        the demands over the current fleet, and the dispatcher realizes
        the resulting shares by granting tasks on the least-loaded workers
        and retiring tasks from the most-loaded ones.  The returned
        ``unmet``/``surplus`` feed the two-level Autoscaler: per-job share
        adjustment happened HERE; the global pool only needs to move when
        aggregate demand and fleet capacity disagree.
        """
        if self._failed:
            from .crashpoints import DispatcherCrashed

            raise DispatcherCrashed("dispatcher crashed")
        with self._lock:
            if self._scheduler is None:
                return None
            capacity = len(self._workers)
            if (
                self._task_grace_deadline is not None
                and time.monotonic() < self._task_grace_deadline
            ):
                # post-restore grace: journaled task owners are still
                # re-registering — rebalancing against a half-returned
                # fleet would shuffle allocations that are about to be
                # reclaimed verbatim
                return {
                    "scheduled": True,
                    "capacity": capacity,
                    "demand": 0,
                    "unmet": 0,
                    "surplus": 0,
                    "shares": {},
                }
            sched_jobs = [j for j in self._jobs.values() if self._schedulable(j)]
            if capacity == 0:
                return {
                    "scheduled": True,
                    "capacity": 0,
                    "demand": len(sched_jobs),
                    "unmet": len(sched_jobs),
                    "surplus": 0,
                    "shares": {},
                }
            demands = []
            for job in sched_jobs:
                cs = self._aggregate_client_stall(job)
                demands.append(
                    JobDemand(
                        job_id=job.job_id,
                        weight=job.weight,
                        allocated=len(self._active_tasks(job)),
                        max_workers=job.max_workers,
                        stall_frac=None if cs is None else float(cs["stall_frac"]),
                    )
                )
            plan = self._scheduler.plan(capacity, demands)
            load = self._worker_load()  # one map, updated as tasks move
            for job in sched_jobs:
                target = plan.shares.get(job.job_id)
                if target is None:
                    continue
                job.target_share = target
                self._apply_share(job, target, load)
            # unscheduled tenants (coordinated/STATIC jobs, unfinished
            # snapshots) use the whole fleet: they pin it against scale-in
            pinned = any(
                not j.finished and not self._schedulable(j)
                for j in self._jobs.values()
            ) or any(not s.finished for s in self._snapshots.values())
            return {
                "scheduled": True,
                "capacity": capacity,
                "demand": plan.total_demand,
                "unmet": plan.unmet,
                "surplus": 0 if pinned else plan.surplus,
                "shares": dict(plan.shares),
            }

    def _worker_load(self) -> Dict[str, int]:
        load = {wid: 0 for wid in self._workers}
        for j in self._jobs.values():
            if j.finished:
                continue
            for t in self._active_tasks(j):
                load[t.worker_id] = load.get(t.worker_id, 0) + 1
        return load

    def _apply_share(
        self, job: _Job, target: int, load: Optional[Dict[str, int]] = None
    ) -> None:
        """Grow/shrink one job's task set toward ``target`` workers.

        ``load`` (per-worker active-task counts) is updated in place as
        tasks move, so one map computed per rebalance round serves every
        job's adjustment.
        """
        if load is None:
            load = self._worker_load()
        active = self._active_tasks(job)
        if len(active) > target:
            # victim order: first workers NOT holding an in-flight shard
            # for this job (cheapest to stop — nothing to re-queue), then
            # by descending total load (free the contended hosts)
            inflight: Set[str] = set()
            if job.shard_mgr is not None:
                with job.shard_mgr._lock:
                    inflight = {
                        st.assigned_to
                        for st in job.shard_mgr._states
                        if st.assigned_to and not st.completed
                    }
            victims = sorted(
                active,
                key=lambda t: (
                    t.worker_id in inflight,
                    -load.get(t.worker_id, 0),
                    t.worker_id,
                ),
            )
            for t in victims[: len(active) - target]:
                self._retire_task(job, t)
                load[t.worker_id] = load.get(t.worker_id, 1) - 1
        elif len(active) < target:
            have = set(job.tasks_by_worker)
            free = sorted(
                (w for wid, w in self._workers.items() if wid not in have),
                key=lambda w: (load.get(w.info.worker_id, 0), w.info.worker_id),
            )
            # iterate past candidates _ensure_task refuses (e.g. a worker
            # still draining this job's retired task): a blocked candidate
            # must not burn one of the grant slots
            need = target - len(active)
            for w in free:
                if need <= 0:
                    break
                if self._ensure_task(job, w.info) is not None:
                    load[w.info.worker_id] = load.get(w.info.worker_id, 0) + 1
                    need -= 1

    def _retire_task(self, job: _Job, task: TaskSpec) -> None:
        """Shrink a job by one worker (journaled, like task creation).

        The worker tears its runner down on the next heartbeat (the task
        disappears from ``valid_tasks``) and the client stops fetching
        when the dispatcher view stops listing it.  The worker's in-flight
        shards are reclaimed with worker-failure semantics — re-queued at
        the checkpointed offset with ``resume_offsets``, lost otherwise
        (the documented at-most-once stance) — but only AFTER the worker's
        runner has verifiably stopped (one heartbeat after the prune was
        delivered): the retiree is alive, and re-queuing a shard it is
        still serving would double-deliver its suffix.  A shard the
        retiree completes before the prune lands counts as completed.
        """
        self._crash("retire_task.pre")
        self._journal.append(
            "task_retired", {"job_id": job.job_id, "task_id": task.task_id}
        )
        self._crash("retire_task.journaled")
        self._apply_task_retired(job, task.task_id)
        if job.shard_mgr is not None:
            if task.worker_id in self._workers:
                self._pending_reclaims[(job.job_id, task.worker_id)] = False
            else:
                self._reclaim_shards(job, task.worker_id)
        self._maybe_finish(job)

    def _reclaim_shards(self, job: _Job, worker_id: str) -> None:
        """Reclaim a drained/retired worker's in-flight shards for one job
        (worker-failure semantics; callers hold ``self._lock``)."""
        if job.shard_mgr is None:
            return
        for sid in job.shard_mgr.worker_failed(worker_id):
            self._journal.append(
                "shard_lost",
                {"job_id": job.job_id, "shard_id": sid, "worker_id": worker_id},
            )
        self._maybe_finish(job)

    def _step_pending_reclaims(self, worker_id: str) -> None:
        """Advance deferred reclaims on a heartbeat from ``worker_id``.

        The first heartbeat after retirement returns a ``valid_tasks``
        list without the retired task — the worker prunes the runner on
        receipt — so the SECOND heartbeat proves the runner is gone and
        its shards are safe to re-queue.
        """
        for key in [k for k in self._pending_reclaims if k[1] == worker_id]:
            if not self._pending_reclaims[key]:
                self._pending_reclaims[key] = True
                continue
            del self._pending_reclaims[key]
            job = self._jobs.get(key[0])
            if job is not None:
                self._reclaim_shards(job, worker_id)

    def _apply_task_retired(self, job: _Job, task_id: str) -> None:
        task = job.tasks.pop(task_id, None)
        if task is None:
            return
        if job.tasks_by_worker.get(task.worker_id) == task_id:
            del job.tasks_by_worker[task.worker_id]
        job.completed_tasks.discard(task_id)

    def rpc_retire_task(self, task_id: str) -> Dict[str, Any]:
        """Administrative task retirement (tests / external tooling); the
        scheduler's rebalance() uses the same journaled path internally.

        Under ``scheduling=True`` the job's target share is pinned to the
        shrunk allocation so the next heartbeat doesn't re-grant the slot.
        In a non-scheduling deployment the every-worker-has-a-task
        invariant re-grants on the next heartbeat — retirement is durable
        only for capped jobs already at ``max_workers``.
        """
        with self._lock:
            for job in self._jobs.values():
                if task_id in job.tasks:
                    self._retire_task(job, job.tasks[task_id])
                    if self._scheduler is not None and self._schedulable(job):
                        job.target_share = len(self._active_tasks(job))
                    return {"ok": True}
            return {"ok": False}
