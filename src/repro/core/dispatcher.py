"""The tf.data-service dispatcher (paper §3.1, §3.3, §3.4).

Control plane only — never touches data.  Manages:
  * registered datasets (pipeline graphs, keyed by content fingerprint),
  * jobs (clients with the same ``job_name`` join the same job),
  * the worker pool (registration, heartbeats, failure detection),
  * per-job shard hand-out for the DYNAMIC policy (ShardManager),
  * multi-tenant fleet scheduling (opt-in ``scheduling=True``): per-job
    demand-driven worker shares (weighted max-min fair, see
    ``core.scheduler``), realized by granting and retiring tasks; task
    grants AND retirements are journaled so allocations survive restart,
  * a write-ahead journal so a restarted dispatcher recovers its state.

Threading model: a single lock guards dispatcher state (control-plane calls
are small and rare relative to data-plane traffic, which goes directly from
clients to workers — the dispatcher is deliberately off the data path).
"""
from __future__ import annotations

import os
import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from ..data.graph import Graph, Node
from ..snapshot.format import write_done, write_metadata
from ..snapshot.manager import (
    SnapshotState,
    StreamState,
    apply_chunk_committed,
    partition_streams,
)
from ..snapshot.policy import AutocacheConfig, AutocachePolicy, Decision
from .codecs import resolve_codec
from .journal import Journal
from .protocol import (
    DEFAULT_CHUNK_BYTES,
    FetchStatus,
    JobView,
    ShardingPolicy,
    TaskSpec,
    WorkerInfo,
    new_id,
)
from .scheduler import FleetScheduler, JobDemand, SchedulerConfig
from .sharding import ShardManager


@dataclass
class _Dataset:
    dataset_id: str
    graph_bytes: bytes
    fingerprint: str


@dataclass
class _Job:
    job_id: str
    job_name: str
    dataset_id: str
    policy: ShardingPolicy
    num_consumers: int = 0
    sharing: bool = False
    compression: Optional[str] = None
    max_workers: int = 0  # 0 = use all registered workers
    weight: float = 1.0  # fleet-scheduler share weight (multi-tenant fairness)
    resume_offsets: bool = False
    tasks: Dict[str, TaskSpec] = field(default_factory=dict)  # by task_id
    tasks_by_worker: Dict[str, str] = field(default_factory=dict)
    completed_tasks: Set[str] = field(default_factory=set)
    shard_mgr: Optional[ShardManager] = None
    finished: bool = False
    clients: Set[str] = field(default_factory=set)
    seq: int = 0  # task seeds
    static_assignment: Optional[Dict[str, List[Dict[str, Any]]]] = None
    autocache_decision: Optional[str] = None  # compute | write_through | read
    # latest feed-stall report per client (repro.feed heartbeat payloads),
    # each stamped with the monotonic receive time for staleness filtering
    client_stall: Dict[str, Dict[str, Any]] = field(default_factory=dict)
    # fleet-scheduler worker share: None = unscheduled (task on every
    # worker, the pre-scheduler behavior); an int caps auto-granted tasks
    target_share: Optional[int] = None


@dataclass
class _Worker:
    info: WorkerInfo
    last_heartbeat: float = field(default_factory=time.monotonic)
    buffer_occupancy: float = 0.0
    cpu_busy: float = 0.0
    delivered: Set[str] = field(default_factory=set)  # task ids shipped
    # (snapshot_id, stream_id) assignments shipped to this worker
    delivered_streams: Set[Any] = field(default_factory=set)
    # latest heartbeat-reported SlidingWindowCache counters, by cache key
    # (pipeline fingerprint) — feeds sharing-efficiency introspection and
    # the autocache policy's hot-pipeline signal
    cache_stats: Dict[str, Dict[str, Any]] = field(default_factory=dict)


class Dispatcher:
    def __init__(
        self,
        journal_path: Optional[str] = None,
        heartbeat_timeout: float = 5.0,
        overpartition: int = 4,
        snapshot_root: Optional[str] = None,
        autocache_config: Optional[AutocacheConfig] = None,
        scheduling: bool = False,
        scheduler_config: Optional[SchedulerConfig] = None,
    ):
        self._lock = threading.RLock()
        self._datasets: Dict[str, _Dataset] = {}
        self._datasets_by_fp: Dict[str, str] = {}
        self._jobs: Dict[str, _Job] = {}
        self._jobs_by_name: Dict[str, str] = {}
        self._workers: Dict[str, _Worker] = {}
        self._snapshots: Dict[str, SnapshotState] = {}
        self._snapshots_by_path: Dict[str, str] = {}
        # autocache: jobs opting in get a compute / write-through / read
        # decision keyed by pipeline fingerprint (requires snapshot_root)
        self._autocache: Optional[AutocachePolicy] = (
            AutocachePolicy(snapshot_root, autocache_config)
            if snapshot_root
            else None
        )
        # multi-tenant fleet scheduling: when enabled, schedulable jobs get
        # a demand-driven worker SHARE (weighted max-min fair) instead of a
        # task on every worker; rebalance() is the entry point (driven by
        # the two-level Autoscaler, or called directly)
        self._scheduler: Optional[FleetScheduler] = (
            FleetScheduler(scheduler_config) if scheduling else None
        )
        self._worker_list_version = 0
        self._heartbeat_timeout = heartbeat_timeout
        self._overpartition = overpartition
        # set after a journal restore that found shards assigned to workers
        # not (yet) re-registered: those workers get one heartbeat-timeout of
        # grace to come back before their in-flight shards are reclaimed
        self._orphan_sweep_deadline: Optional[float] = None
        # set after a journal restore that found jobs with tasks: until it
        # expires, capped/scheduled jobs count their JOURNALED tasks (not
        # just re-registered workers' tasks) so a worker that registers
        # before its peers cannot steal a slot a returning owner will
        # reclaim — allocations must survive the restart intact
        self._task_grace_deadline: Optional[float] = None
        # (job_id, worker_id) -> armed: shard reclamation deferred until
        # one heartbeat AFTER the one that tears the retired runner down.
        # A retired worker is ALIVE (unlike the worker-failure path) and
        # keeps serving its in-flight shard until the prune; re-queuing
        # that shard immediately would have a replacement replay it
        # concurrently (duplicate rows under resume_offsets).
        self._pending_reclaims: Dict[Any, bool] = {}
        self._journal = Journal(journal_path)
        if journal_path:
            self._restore(journal_path)

    # ------------------------------------------------------------------
    # RPC entry point
    # ------------------------------------------------------------------
    def handle(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        fn = getattr(self, f"rpc_{method}", None)
        if fn is None:
            raise ValueError(f"dispatcher: unknown method {method}")
        return fn(**payload)

    # ------------------------------------------------------------------
    # Datasets & jobs (client-facing)
    # ------------------------------------------------------------------
    def rpc_get_or_register_dataset(self, graph_bytes: bytes) -> Dict[str, Any]:
        """Register the RAW client graph; optimize once, dispatcher-side.

        The content fingerprint is taken over the bytes the client sent —
        BEFORE optimization — because optimizer passes synthesize fresh
        fused closures whose serialization is not content-stable.  Two jobs
        submitting identical pipelines must land on the same dataset_id, or
        ephemeral data sharing (§3.5) silently degrades to one cache per
        job.  Workers receive the optimized graph.
        """
        g = Graph.from_bytes(graph_bytes)
        fp = g.fingerprint()
        with self._lock:
            if fp in self._datasets_by_fp:
                return {"dataset_id": self._datasets_by_fp[fp], "fingerprint": fp}
            from ..data.optimizer import optimize_graph

            opt_bytes = optimize_graph(g).to_bytes()
            ds_id = new_id("ds")
            self._journal.append(
                "dataset_registered",
                {"dataset_id": ds_id, "graph_bytes": opt_bytes, "fingerprint": fp},
            )
            self._apply_dataset(ds_id, opt_bytes, fp)
            return {"dataset_id": ds_id, "fingerprint": fp}

    def _apply_dataset(self, ds_id: str, graph_bytes: bytes, fp: str) -> None:
        self._datasets[ds_id] = _Dataset(ds_id, graph_bytes, fp)
        self._datasets_by_fp[fp] = ds_id

    def rpc_get_or_create_job(
        self,
        dataset_id: str,
        job_name: Optional[str] = None,
        policy: str = "off",
        num_consumers: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        max_workers: int = 0,
        weight: float = 1.0,
        resume_offsets: bool = False,
        client_id: Optional[str] = None,
        client_codecs: Optional[List[str]] = None,
        autocache: bool = False,
    ) -> Dict[str, Any]:
        with self._lock:
            if job_name and job_name in self._jobs_by_name:
                job = self._jobs[self._jobs_by_name[job_name]]
                if client_id:
                    job.clients.add(client_id)
                return self._job_view(job)
            decision = None
            if autocache and self._autocache is not None:
                dataset_id, decision = self._autocache_decide(
                    dataset_id, compression=compression, client_codecs=client_codecs
                )
            payload = dict(
                job_id=new_id("job"),
                job_name=job_name or "",
                dataset_id=dataset_id,
                policy=str(ShardingPolicy.parse(policy).value),
                num_consumers=num_consumers,
                sharing=sharing,
                # codec negotiation (restricted to what the requesting
                # client can decode): the journaled payload carries the
                # RESOLVED codec so workers joining after a dispatcher
                # restart compress with the same algorithm
                compression=resolve_codec(compression, client_codecs),
                max_workers=max_workers,
                weight=max(1e-3, float(weight)),
                resume_offsets=resume_offsets,
                # journaled so a restored dispatcher partitions the source
                # into the SAME shards (ids must stay aligned with the log)
                shard_hint=max(1, len(self._workers)) * self._overpartition,
                autocache_decision=decision,
            )
            self._journal.append("job_created", payload)
            job = self._apply_job(payload)
            if client_id:
                job.clients.add(client_id)
            return self._job_view(job)

    def _autocache_decide(
        self,
        dataset_id: str,
        compression: Optional[str],
        client_codecs: Optional[List[str]],
    ) -> "tuple[str, Optional[str]]":
        """Resolve an autocache job's effective dataset.

        READ swaps the job onto a snapshot-source dataset (registered and
        journaled like any other); WRITE_THROUGH starts materializing the
        pipeline (get-or-start) while the job computes as usual.
        """
        ds = self._datasets[dataset_id]
        d = self._autocache.decide(
            ds.fingerprint, cache_stats=self._aggregate_cache_stats(ds.fingerprint)
        )
        if d.decision == Decision.READ:
            snap_graph = Graph([Node("snapshot", {"path": d.snapshot_path})])
            resp = self.rpc_get_or_register_dataset(snap_graph.to_bytes())
            return resp["dataset_id"], d.value
        if d.decision == Decision.WRITE_THROUGH:
            self.rpc_start_snapshot(
                path=d.snapshot_path,
                dataset_id=dataset_id,
                compression=compression,
                client_codecs=client_codecs,
                # the policy only answers WRITE_THROUGH for an existing dir
                # when the write is abandoned — allow clearing it
                replace_stale_s=self._autocache.config.stale_write_timeout_s,
            )
        return dataset_id, d.value

    def _aggregate_cache_stats(self, cache_key: str) -> Optional[Dict[str, Any]]:
        """Sum heartbeat-reported SlidingWindowCache counters for one key."""
        agg: Dict[str, float] = {}
        found = False
        for w in self._workers.values():
            st = w.cache_stats.get(cache_key)
            if not st:
                continue
            found = True
            for k, v in st.items():
                if isinstance(v, (int, float)):
                    agg[k] = agg.get(k, 0) + v
        return agg if found else None

    # feed-stall reports older than this are ignored by the aggregate — a
    # finished/stuck consumer must not pin the autoscaler's view forever
    STALL_REPORT_TTL_S = 10.0

    def _aggregate_client_stall(self, job: _Job) -> Optional[Dict[str, float]]:
        """Mean of the job's fresh per-client feed-stall windows.

        Expired entries are pruned, not just filtered: client churn on a
        long-lived job (every feeder session is a fresh client_id) must
        not grow the dict without bound.  Callers hold ``self._lock``.
        """
        now = time.monotonic()
        for cid in [
            cid
            for cid, r in job.client_stall.items()
            if now - r.get("t", 0.0) > self.STALL_REPORT_TTL_S
        ]:
            del job.client_stall[cid]
        fresh = list(job.client_stall.values())
        if not fresh:
            return None
        n = len(fresh)

        def mean(key: str) -> float:
            return sum(float(r.get(key, 0.0)) for r in fresh) / n

        return {
            "clients": float(n),
            "stall_frac": mean("stall_frac"),
            "idle_s_per_step": mean("idle_s_per_step"),
            "fetch_s_per_step": mean("fetch_s_per_step"),
            "transfer_s_per_step": mean("transfer_s_per_step"),
            "queue_depth": mean("queue_depth"),
        }

    def _apply_job(self, p: Dict[str, Any]) -> _Job:
        job = _Job(
            job_id=p["job_id"],
            job_name=p["job_name"],
            dataset_id=p["dataset_id"],
            policy=ShardingPolicy(p["policy"]),
            num_consumers=p["num_consumers"],
            sharing=p["sharing"],
            compression=p.get("compression"),
            max_workers=p.get("max_workers", 0),
            weight=p.get("weight", 1.0),
            resume_offsets=p.get("resume_offsets", False),
            autocache_decision=p.get("autocache_decision"),
            target_share=p.get("target_share"),
        )
        if job.policy in (ShardingPolicy.DYNAMIC, ShardingPolicy.STATIC):
            graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
            hint = p.get("shard_hint") or max(1, len(self._workers)) * self._overpartition
            job.shard_mgr = ShardManager(
                graph,
                job.policy,
                num_workers_hint=hint,
                overpartition=1,
                resume_offsets=job.resume_offsets,
            )
        self._jobs[job.job_id] = job
        if job.job_name:
            self._jobs_by_name[job.job_name] = job.job_id
        # a new schedulable job starts at its weighted fair share of the
        # fleet, placed on the least-loaded workers (rebalance() adjusts it
        # from demand); unscheduled jobs (and non-scheduling deployments)
        # get a task on every worker (scale-out)
        if self._scheduler is not None and self._schedulable(job):
            if job.target_share is None:
                job.target_share = self._initial_share(job)
            if job.target_share is not None:
                self._apply_share(job, job.target_share)
        else:
            for w in self._workers.values():
                self._ensure_task(job, w.info)
        return job

    def _ensure_task(self, job: _Job, w: WorkerInfo) -> Optional[TaskSpec]:
        if job.finished or w.worker_id in job.tasks_by_worker:
            return None
        if (job.job_id, w.worker_id) in self._pending_reclaims:
            # this worker is still draining a retired task for the job:
            # granting a fresh one now would hand the new runner shards
            # while the pending reclaim is about to yank them back
            return None
        # count only ACTIVE tasks (live workers, not completed): tasks left
        # behind by dead workers must not eat into the cap, or a capped job
        # ends up permanently under-provisioned after worker churn
        if job.max_workers or job.target_share is not None:
            active = self._slot_count(job)
            if job.max_workers and active >= job.max_workers:
                return None
            if (
                self._scheduler is not None
                and job.target_share is not None
                and self._schedulable(job)
                and active >= job.target_share
            ):
                return None
        ds = self._datasets[job.dataset_id]
        job.seq += 1
        task = TaskSpec(
            task_id=new_id("task"),
            job_id=job.job_id,
            dataset_id=job.dataset_id,
            worker_id=w.worker_id,
            worker_address=w.address,
            policy=job.policy.value,
            num_consumers=job.num_consumers,
            round_robin=job.num_consumers > 0,
            shared=job.sharing,
            cache_key=ds.fingerprint if job.sharing else None,
            worker_seed=job.seq,
        )
        # journal task creation: task ids must be STABLE across dispatcher
        # restarts so live workers/clients keep their handles (§3.4)
        self._journal.append("task_created", vars(task).copy())
        self._apply_task(job, task)
        return task

    def _apply_task(self, job: _Job, task: TaskSpec) -> None:
        job.tasks[task.task_id] = task
        job.tasks_by_worker[task.worker_id] = task.task_id

    def _job_view(self, job: _Job) -> Dict[str, Any]:
        return {
            "job_id": job.job_id,
            "dataset_id": job.dataset_id,
            "policy": job.policy.value,
            "num_consumers": job.num_consumers,
            "finished": job.finished,
            "worker_list_version": self._worker_list_version,
            "compression": job.compression,
            "autocache": job.autocache_decision,
            "tasks": [vars(t) for t in self._active_tasks(job)],
        }

    def _active_tasks(self, job: _Job) -> List[TaskSpec]:
        return [
            t
            for t in job.tasks.values()
            if t.task_id not in job.completed_tasks
            and t.worker_id in self._workers
        ]

    def _slot_count(self, job: _Job) -> int:
        """Tasks counted against the job's worker cap/share.

        Normally the ACTIVE tasks; within the post-restore grace window
        every journaled (uncompleted) task holds its slot even though its
        worker has not re-registered yet — the owner is probably mid-
        reconnect, and handing its slot to a faster-registering worker
        would inflate the job past its journaled allocation.
        """
        if (
            self._task_grace_deadline is not None
            and time.monotonic() < self._task_grace_deadline
        ):
            return len(
                [t for t in job.tasks.values() if t.task_id not in job.completed_tasks]
            )
        self._task_grace_deadline = None
        return len(self._active_tasks(job))

    # ------------------------------------------------------------------
    # Fleet scheduling (multi-tenant worker allocation)
    # ------------------------------------------------------------------
    def _schedulable(self, job: _Job) -> bool:
        """Jobs the fleet scheduler may grow/shrink.

        Coordinated-read jobs stripe rounds over the sorted worker set and
        STATIC jobs fix their partitions up front — resizing either would
        break their placement contract, so they keep the task-on-every-
        worker behavior and pin the fleet instead.
        """
        return (
            not job.finished
            and job.num_consumers == 0
            and job.policy != ShardingPolicy.STATIC
        )

    def _initial_share(self, job: _Job) -> Optional[int]:
        """Fair-share entry allocation for a newly created job."""
        capacity = len(self._workers)
        if capacity == 0:
            return None  # no fleet yet: first rebalance sets the share
        demands = [
            JobDemand(
                job_id=j.job_id,
                weight=j.weight,
                allocated=0 if j is job else len(self._active_tasks(j)),
                max_workers=j.max_workers,
            )
            for j in self._jobs.values()
            if self._schedulable(j)
        ]
        return self._scheduler.plan(capacity, demands).shares.get(job.job_id)

    def rebalance(self) -> Optional[Dict[str, Any]]:
        """One fleet-scheduling round; returns the plan view or None when
        scheduling is disabled.

        Each schedulable job's demand is derived from its own fresh
        ``client_stall`` aggregate; weighted max-min fairness arbitrates
        the demands over the current fleet, and the dispatcher realizes
        the resulting shares by granting tasks on the least-loaded workers
        and retiring tasks from the most-loaded ones.  The returned
        ``unmet``/``surplus`` feed the two-level Autoscaler: per-job share
        adjustment happened HERE; the global pool only needs to move when
        aggregate demand and fleet capacity disagree.
        """
        with self._lock:
            if self._scheduler is None:
                return None
            capacity = len(self._workers)
            if (
                self._task_grace_deadline is not None
                and time.monotonic() < self._task_grace_deadline
            ):
                # post-restore grace: journaled task owners are still
                # re-registering — rebalancing against a half-returned
                # fleet would shuffle allocations that are about to be
                # reclaimed verbatim
                return {
                    "scheduled": True,
                    "capacity": capacity,
                    "demand": 0,
                    "unmet": 0,
                    "surplus": 0,
                    "shares": {},
                }
            sched_jobs = [j for j in self._jobs.values() if self._schedulable(j)]
            if capacity == 0:
                return {
                    "scheduled": True,
                    "capacity": 0,
                    "demand": len(sched_jobs),
                    "unmet": len(sched_jobs),
                    "surplus": 0,
                    "shares": {},
                }
            demands = []
            for job in sched_jobs:
                cs = self._aggregate_client_stall(job)
                demands.append(
                    JobDemand(
                        job_id=job.job_id,
                        weight=job.weight,
                        allocated=len(self._active_tasks(job)),
                        max_workers=job.max_workers,
                        stall_frac=None if cs is None else float(cs["stall_frac"]),
                    )
                )
            plan = self._scheduler.plan(capacity, demands)
            load = self._worker_load()  # one map, updated as tasks move
            for job in sched_jobs:
                target = plan.shares.get(job.job_id)
                if target is None:
                    continue
                job.target_share = target
                self._apply_share(job, target, load)
            # unscheduled tenants (coordinated/STATIC jobs, unfinished
            # snapshots) use the whole fleet: they pin it against scale-in
            pinned = any(
                not j.finished and not self._schedulable(j)
                for j in self._jobs.values()
            ) or any(not s.finished for s in self._snapshots.values())
            return {
                "scheduled": True,
                "capacity": capacity,
                "demand": plan.total_demand,
                "unmet": plan.unmet,
                "surplus": 0 if pinned else plan.surplus,
                "shares": dict(plan.shares),
            }

    def _worker_load(self) -> Dict[str, int]:
        load = {wid: 0 for wid in self._workers}
        for j in self._jobs.values():
            if j.finished:
                continue
            for t in self._active_tasks(j):
                load[t.worker_id] = load.get(t.worker_id, 0) + 1
        return load

    def _apply_share(
        self, job: _Job, target: int, load: Optional[Dict[str, int]] = None
    ) -> None:
        """Grow/shrink one job's task set toward ``target`` workers.

        ``load`` (per-worker active-task counts) is updated in place as
        tasks move, so one map computed per rebalance round serves every
        job's adjustment.
        """
        if load is None:
            load = self._worker_load()
        active = self._active_tasks(job)
        if len(active) > target:
            # victim order: first workers NOT holding an in-flight shard
            # for this job (cheapest to stop — nothing to re-queue), then
            # by descending total load (free the contended hosts)
            inflight: Set[str] = set()
            if job.shard_mgr is not None:
                with job.shard_mgr._lock:
                    inflight = {
                        st.assigned_to
                        for st in job.shard_mgr._states
                        if st.assigned_to and not st.completed
                    }
            victims = sorted(
                active,
                key=lambda t: (
                    t.worker_id in inflight,
                    -load.get(t.worker_id, 0),
                    t.worker_id,
                ),
            )
            for t in victims[: len(active) - target]:
                self._retire_task(job, t)
                load[t.worker_id] = load.get(t.worker_id, 1) - 1
        elif len(active) < target:
            have = set(job.tasks_by_worker)
            free = sorted(
                (w for wid, w in self._workers.items() if wid not in have),
                key=lambda w: (load.get(w.info.worker_id, 0), w.info.worker_id),
            )
            # iterate past candidates _ensure_task refuses (e.g. a worker
            # still draining this job's retired task): a blocked candidate
            # must not burn one of the grant slots
            need = target - len(active)
            for w in free:
                if need <= 0:
                    break
                if self._ensure_task(job, w.info) is not None:
                    load[w.info.worker_id] = load.get(w.info.worker_id, 0) + 1
                    need -= 1

    def _retire_task(self, job: _Job, task: TaskSpec) -> None:
        """Shrink a job by one worker (journaled, like task creation).

        The worker tears its runner down on the next heartbeat (the task
        disappears from ``valid_tasks``) and the client stops fetching
        when the dispatcher view stops listing it.  The worker's in-flight
        shards are reclaimed with worker-failure semantics — re-queued at
        the checkpointed offset with ``resume_offsets``, lost otherwise
        (the documented at-most-once stance) — but only AFTER the worker's
        runner has verifiably stopped (one heartbeat after the prune was
        delivered): the retiree is alive, and re-queuing a shard it is
        still serving would double-deliver its suffix.  A shard the
        retiree completes before the prune lands counts as completed.
        """
        self._journal.append(
            "task_retired", {"job_id": job.job_id, "task_id": task.task_id}
        )
        self._apply_task_retired(job, task.task_id)
        if job.shard_mgr is not None:
            if task.worker_id in self._workers:
                self._pending_reclaims[(job.job_id, task.worker_id)] = False
            else:
                self._reclaim_shards(job, task.worker_id)
        self._maybe_finish(job)

    def _reclaim_shards(self, job: _Job, worker_id: str) -> None:
        """Reclaim a drained/retired worker's in-flight shards for one job
        (worker-failure semantics; callers hold ``self._lock``)."""
        if job.shard_mgr is None:
            return
        for sid in job.shard_mgr.worker_failed(worker_id):
            self._journal.append(
                "shard_lost",
                {"job_id": job.job_id, "shard_id": sid, "worker_id": worker_id},
            )
        self._maybe_finish(job)

    def _step_pending_reclaims(self, worker_id: str) -> None:
        """Advance deferred reclaims on a heartbeat from ``worker_id``.

        The first heartbeat after retirement returns a ``valid_tasks``
        list without the retired task — the worker prunes the runner on
        receipt — so the SECOND heartbeat proves the runner is gone and
        its shards are safe to re-queue.
        """
        for key in [k for k in self._pending_reclaims if k[1] == worker_id]:
            if not self._pending_reclaims[key]:
                self._pending_reclaims[key] = True
                continue
            del self._pending_reclaims[key]
            job = self._jobs.get(key[0])
            if job is not None:
                self._reclaim_shards(job, worker_id)

    def _apply_task_retired(self, job: _Job, task_id: str) -> None:
        task = job.tasks.pop(task_id, None)
        if task is None:
            return
        if job.tasks_by_worker.get(task.worker_id) == task_id:
            del job.tasks_by_worker[task.worker_id]
        job.completed_tasks.discard(task_id)

    def rpc_retire_task(self, task_id: str) -> Dict[str, Any]:
        """Administrative task retirement (tests / external tooling); the
        scheduler's rebalance() uses the same journaled path internally.

        Under ``scheduling=True`` the job's target share is pinned to the
        shrunk allocation so the next heartbeat doesn't re-grant the slot.
        In a non-scheduling deployment the every-worker-has-a-task
        invariant re-grants on the next heartbeat — retirement is durable
        only for capped jobs already at ``max_workers``.
        """
        with self._lock:
            for job in self._jobs.values():
                if task_id in job.tasks:
                    self._retire_task(job, job.tasks[task_id])
                    if self._scheduler is not None and self._schedulable(job):
                        job.target_share = len(self._active_tasks(job))
                    return {"ok": True}
            return {"ok": False}

    def rpc_client_heartbeat(
        self,
        job_id: str,
        client_id: str,
        starving: bool = False,
        stall_stats: Optional[Dict[str, Any]] = None,
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown job {job_id}")
            job.clients.add(client_id)
            if stall_stats:
                job.client_stall[client_id] = {
                    "t": time.monotonic(),
                    **stall_stats,
                }
            self._maybe_finish(job)
            view = self._job_view(job)
            view["starving_ack"] = starving
            return view

    # ------------------------------------------------------------------
    # Workers
    # ------------------------------------------------------------------
    def rpc_register_worker(
        self, worker_id: str, address: str, tags: Optional[Dict[str, Any]] = None
    ) -> Dict[str, Any]:
        with self._lock:
            self._journal.append(
                "worker_registered", {"worker_id": worker_id, "address": address}
            )
            is_new = worker_id not in self._workers
            # (re)registration resets delivery state — stateless workers that
            # restart must receive their tasks again (paper §3.4)
            self._workers[worker_id] = _Worker(WorkerInfo(worker_id, address, tags or {}))
            if is_new:
                self._worker_list_version += 1
            w = self._workers[worker_id]
            tasks = self._undelivered_tasks(w)
            self._assign_snapshot_streams(worker_id)
            return {
                "tasks": tasks,
                "snapshot_streams": self._undelivered_snapshot_streams(w),
                "worker_list_version": self._worker_list_version,
            }

    def _undelivered_tasks(self, w: _Worker) -> List[Dict[str, Any]]:
        """Tasks for every active job not yet shipped to this worker."""
        out: List[Dict[str, Any]] = []
        for job in self._jobs.values():
            if job.finished:
                continue
            t = self._ensure_task(job, w.info)
            if t is None:
                tid = job.tasks_by_worker.get(w.info.worker_id)
                if tid and tid not in job.completed_tasks:
                    t = job.tasks[tid]
            if t is not None and t.task_id not in w.delivered:
                w.delivered.add(t.task_id)
                out.append(self._task_payload(t, job))
        return out

    def _task_payload(self, t: TaskSpec, job: _Job) -> Dict[str, Any]:
        ds = self._datasets[job.dataset_id]
        p = vars(t).copy()
        p["graph_bytes"] = ds.graph_bytes
        p["compression"] = job.compression
        p["resume_offsets"] = job.resume_offsets
        p["static_shards"] = None
        if job.policy == ShardingPolicy.STATIC and job.shard_mgr is not None:
            # computed ONCE over the workers present at first hand-out (the
            # paper's "up-front" semantics) and journaled for restart stability
            if job.static_assignment is None:
                assignment = job.shard_mgr.static_assignment(
                    sorted(job.tasks_by_worker)
                )
                self._journal.append(
                    "static_assignment",
                    {"job_id": job.job_id, "assignment": assignment},
                )
                job.static_assignment = assignment
            p["static_shards"] = job.static_assignment.get(t.worker_id, [])
        return p

    def rpc_worker_heartbeat(
        self,
        worker_id: str,
        buffer_occupancy: float = 0.0,
        cpu_busy: float = 0.0,
        completed_tasks: Optional[List[str]] = None,
        cache_stats: Optional[Dict[str, Dict[str, Any]]] = None,
        failed_streams: Optional[List[List[Any]]] = None,
    ) -> Dict[str, Any]:
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                # unknown worker (e.g. dispatcher restarted): ask it to re-register
                return {"reregister": True}
            w.last_heartbeat = time.monotonic()
            w.buffer_occupancy = buffer_occupancy
            w.cpu_busy = cpu_busy
            if cache_stats is not None:
                w.cache_stats = cache_stats
            self._step_pending_reclaims(worker_id)
            for tid in completed_tasks or []:
                self._complete_task(tid, journal=True)
            for sid, stream_id in failed_streams or []:
                # the worker's writer died on an exception: release the
                # stream so it can be retried (here or elsewhere) from the
                # last committed offset
                self._release_failed_stream(sid, int(stream_id), worker_id)
            new_tasks = self._undelivered_tasks(w)
            self._assign_snapshot_streams(worker_id)
            valid = [
                job.tasks_by_worker[worker_id]
                for job in self._jobs.values()
                if worker_id in job.tasks_by_worker and not job.finished
            ]
            return {
                "new_tasks": new_tasks,
                "snapshot_streams": self._undelivered_snapshot_streams(w),
                "valid_tasks": valid,
                "worker_list_version": self._worker_list_version,
                "reregister": False,
            }

    def _complete_task(self, task_id: str, journal: bool) -> None:
        for job in self._jobs.values():
            if task_id in job.tasks and task_id not in job.completed_tasks:
                if journal:
                    self._journal.append("task_completed", {"task_id": task_id})
                job.completed_tasks.add(task_id)
                self._maybe_finish(job)

    def _maybe_finish(self, job: _Job) -> None:
        if job.finished or not job.tasks:
            return
        live = [t for t in job.tasks.values() if t.worker_id in self._workers]
        all_done = all(t.task_id in job.completed_tasks for t in live) and live
        if job.policy == ShardingPolicy.DYNAMIC and job.shard_mgr is not None:
            if job.shard_mgr.done() and all_done:
                self._finish_job(job)
        elif all_done:
            self._finish_job(job)

    def _finish_job(self, job: _Job) -> None:
        self._journal.append("job_finished", {"job_id": job.job_id})
        job.finished = True

    # -- failure detection ------------------------------------------------
    def check_workers(self) -> List[str]:
        """Mark workers dead after heartbeat timeout. Returns removed ids.

        Called by the orchestrator's GC loop (or tests directly).
        """
        now = time.monotonic()
        removed = []
        with self._lock:
            for wid, w in list(self._workers.items()):
                if now - w.last_heartbeat > self._heartbeat_timeout:
                    removed.append(wid)
                    self._remove_worker(wid)
            self._sweep_orphan_shards(now)
        return removed

    def _sweep_orphan_shards(self, now: float) -> None:
        """Reclaim shards AND snapshot streams assigned (pre-restart, per
        the journal) to workers that never re-registered.  check_workers
        can't see them — they are not in self._workers — so without this
        sweep such shards stay in-flight forever and the job (or snapshot)
        never finishes."""
        if self._orphan_sweep_deadline is None or now < self._orphan_sweep_deadline:
            return
        self._orphan_sweep_deadline = None
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            orphan_owners = {
                s.assigned_to
                for s in snap.streams
                if s.assigned_to and not s.done
                and s.assigned_to not in self._workers
            }
            for wid in orphan_owners:
                self._release_worker_streams(wid)
        for job in self._jobs.values():
            mgr = job.shard_mgr
            if mgr is None or job.finished:
                continue
            orphans = {
                st.assigned_to
                for st in mgr._states
                if st.assigned_to and not st.completed
                and st.assigned_to not in self._workers
            }
            for wid in orphans:
                for sid in mgr.worker_failed(wid):
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": wid},
                    )
            if orphans:
                self._maybe_finish(job)
        # deferred retirement reclaims whose worker never re-registered
        # were just covered by the orphan sweep above
        for key in [k for k in self._pending_reclaims if k[1] not in self._workers]:
            del self._pending_reclaims[key]

    def rpc_remove_worker(self, worker_id: str) -> Dict[str, Any]:
        """Administrative removal (tests / orchestrator-initiated)."""
        with self._lock:
            self._remove_worker(worker_id)
        return {"ok": True}

    def _remove_worker(self, worker_id: str) -> None:
        if worker_id not in self._workers:
            return
        self._journal.append("worker_removed", {"worker_id": worker_id})
        del self._workers[worker_id]
        self._worker_list_version += 1
        # worker death supersedes any deferred retirement reclaim: the
        # worker_failed sweep below covers every job's in-flight shards
        for key in [k for k in self._pending_reclaims if k[1] == worker_id]:
            del self._pending_reclaims[key]
        self._release_worker_streams(worker_id)
        for job in self._jobs.values():
            if job.shard_mgr is not None:
                lost = job.shard_mgr.worker_failed(worker_id)
                for sid in lost:
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": worker_id},
                    )
            self._maybe_finish(job)

    # ------------------------------------------------------------------
    # DYNAMIC sharding hand-out (worker-facing)
    # ------------------------------------------------------------------
    def rpc_get_shard(self, job_id: str, worker_id: str) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.shard_mgr is None:
                return {"done": True}
            if worker_id not in job.tasks_by_worker:
                # the worker's task was retired (fleet scheduler) but its
                # runner has not been pruned yet — handing it a shard would
                # strand that shard in-flight forever once the runner stops
                return {"done": True}
            nxt = job.shard_mgr.next_shard(worker_id)
            if nxt is None:
                # resume_offsets: an in-flight shard on a dying worker can
                # RE-ENTER the queue — "empty now" is not "drained".  Tell
                # workers to poll again instead of retiring their task.
                if job.shard_mgr.resume_offsets and not job.shard_mgr.done():
                    return {"done": False, "wait": True}
                return {"done": True}
            sid, shard, offset = nxt
            self._journal.append(
                "shard_assigned",
                {"job_id": job_id, "shard_id": sid, "worker_id": worker_id},
            )
            return {"done": False, "shard_id": sid, "shard": shard, "offset": offset}

    def rpc_complete_shard(
        self, job_id: str, shard_id: int, worker_id: str
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_completed",
                    {"job_id": job_id, "shard_id": shard_id, "worker_id": worker_id},
                )
                job.shard_mgr.complete_shard(shard_id, worker_id)
            return {"ok": True}

    def rpc_checkpoint_offset(
        self, job_id: str, shard_id: int, worker_id: str, offset: int
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_offset",
                    {"job_id": job_id, "shard_id": shard_id, "offset": offset},
                )
                job.shard_mgr.checkpoint_offset(shard_id, worker_id, offset)
            return {"ok": True}

    # ------------------------------------------------------------------
    # Snapshots / materialization (repro.snapshot): the committer layer
    # ------------------------------------------------------------------
    def rpc_start_snapshot(
        self,
        path: str,
        dataset_id: Optional[str] = None,
        graph_bytes: Optional[bytes] = None,
        num_streams: int = 0,
        compression: Optional[str] = None,
        client_codecs: Optional[List[str]] = None,
        chunk_bytes: int = 0,
        seed_base: int = 0,
        replace_stale_s: Optional[float] = None,
    ) -> Dict[str, Any]:
        """Get-or-start materializing a dataset to ``path`` (idempotent
        per (path, pipeline fingerprint)).

        Partitions the source into ``num_streams`` streams (default: one
        per registered worker), journals the plan, and assigns streams to
        workers round-robin; workers receive their assignments via
        heartbeat and start appending committed chunks.

        A path already holding a DIFFERENT pipeline's snapshot is an error
        (manifests merge by seq — mixing pipelines would silently
        interleave their batches).  A path with an unfinished snapshot no
        dispatcher tracks (a dead deployment's partial write) is refused
        unless ``replace_stale_s`` is given and the write has been idle at
        least that long, in which case the stale directory is cleared and
        the snapshot restarts.
        """
        from ..snapshot.format import read_metadata
        from ..snapshot.reader import last_progress_unix, snapshot_finished

        with self._lock:
            path = os.path.abspath(path)
            if dataset_id is None:
                if graph_bytes is None:
                    raise ValueError("start_snapshot needs dataset_id or graph_bytes")
                dataset_id = self.rpc_get_or_register_dataset(graph_bytes)["dataset_id"]
            ds = self._datasets[dataset_id]
            if path in self._snapshots_by_path:
                snap = self._snapshots[self._snapshots_by_path[path]]
                if snap.fingerprint != ds.fingerprint:
                    raise ValueError(
                        f"snapshot path {path} already materializes pipeline "
                        f"{snap.fingerprint}, not {ds.fingerprint} — use a "
                        f"different path per pipeline"
                    )
                return dict(snap.view(), existing=True)
            meta = read_metadata(path)
            if meta is not None:  # on-disk snapshot this dispatcher doesn't track
                if meta.get("fingerprint") != ds.fingerprint:
                    raise ValueError(
                        f"snapshot path {path} holds pipeline "
                        f"{meta.get('fingerprint')}, not {ds.fingerprint}"
                    )
                if snapshot_finished(path):
                    # adopt the finished snapshot read-only: report success
                    from ..snapshot.reader import snapshot_status

                    return dict(snapshot_status(path), existing=True, path=path)
                idle = time.time() - last_progress_unix(path)
                if replace_stale_s is None or idle < replace_stale_s:
                    raise ValueError(
                        f"snapshot path {path} holds an unfinished write this "
                        f"dispatcher doesn't track (idle {idle:.0f}s); pass "
                        f"replace_stale_s to restart it or use a fresh path"
                    )
                import shutil

                shutil.rmtree(path, ignore_errors=True)
            num_streams = int(num_streams) or max(1, len(self._workers))
            streams = partition_streams(
                Graph.from_bytes(ds.graph_bytes), num_streams, self._overpartition
            )
            payload = {
                "snapshot_id": new_id("snap"),
                "path": path,
                "dataset_id": dataset_id,
                "fingerprint": ds.fingerprint,
                "codec": resolve_codec(compression, client_codecs),
                "chunk_bytes": int(chunk_bytes) or DEFAULT_CHUNK_BYTES,
                "seed_base": int(seed_base),
                "streams": streams,
            }
            self._journal.append("snapshot_started", payload, sync=True)
            snap = self._apply_snapshot_started(payload)
            # initial round-robin assignment over the current worker pool;
            # workers registering later pick up unassigned streams on
            # heartbeat (and reassignment after failures does the same)
            workers = sorted(self._workers)
            for i, stream in enumerate(snap.streams):
                if workers:
                    self._assign_stream(snap, stream, workers[i % len(workers)])
            return dict(snap.view(), existing=False)

    def _apply_snapshot_started(self, p: Dict[str, Any]) -> SnapshotState:
        snap = SnapshotState(
            snapshot_id=p["snapshot_id"],
            path=p["path"],
            dataset_id=p["dataset_id"],
            fingerprint=p["fingerprint"],
            codec=p.get("codec"),
            chunk_bytes=p["chunk_bytes"],
            seed_base=p.get("seed_base", 0),
            streams=[
                StreamState(stream_id=i, shards=shards)
                for i, shards in enumerate(p["streams"])
            ],
        )
        self._snapshots[snap.snapshot_id] = snap
        self._snapshots_by_path[snap.path] = snap.snapshot_id
        # idempotent: (re)write the immutable on-disk metadata so readers on
        # the shared FS can discover the snapshot without the dispatcher
        write_metadata(
            snap.path,
            snap.snapshot_id,
            snap.fingerprint,
            snap.codec,
            snap.chunk_bytes,
            len(snap.streams),
            snap.seed_base,
        )
        return snap

    def _assign_stream(
        self, snap: SnapshotState, stream: StreamState, worker_id: str
    ) -> None:
        self._journal.append(
            "snapshot_stream_assigned",
            {
                "snapshot_id": snap.snapshot_id,
                "stream_id": stream.stream_id,
                "worker_id": worker_id,
            },
        )
        stream.assigned_to = worker_id
        # the spec must be (re)shipped with fresh resume state
        key = (snap.snapshot_id, stream.stream_id)
        for w in self._workers.values():
            w.delivered_streams.discard(key)

    def _assign_snapshot_streams(self, worker_id: str) -> None:
        """Hand unowned streams to a live worker, keeping the load fair.

        Streams lose their owner on worker failure (or were never assigned
        because no worker was registered at start).  Each heartbeat tops the
        calling worker up to its fair share of the remaining streams.  A
        stream whose recorded owner has not (re-)registered is NOT up for
        grabs here: after a dispatcher restart the owner usually comes back
        within a heartbeat, and the orphan sweep reclaims it after the
        grace period if it doesn't (stealing a live writer's stream would
        force a pointless re-production of its whole uncommitted suffix).
        """
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            unowned = [s for s in snap.streams if not s.done and s.assigned_to is None]
            if not unowned:
                continue
            fair = -(-len(snap.undone_streams()) // max(1, len(self._workers)))
            owned = len(snap.streams_for_worker(worker_id))
            for s in unowned:
                if owned >= fair:
                    break
                self._assign_stream(snap, s, worker_id)
                owned += 1

    def _undelivered_snapshot_streams(self, w: _Worker) -> List[Dict[str, Any]]:
        out: List[Dict[str, Any]] = []
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            ds = self._datasets[snap.dataset_id]
            for s in snap.streams:
                if s.done or s.assigned_to != w.info.worker_id:
                    continue
                key = (snap.snapshot_id, s.stream_id)
                if key in w.delivered_streams:
                    continue
                w.delivered_streams.add(key)
                out.append(snap.stream_spec(s, ds.graph_bytes))
        return out

    def rpc_snapshot_commit_chunk(
        self,
        snapshot_id: str,
        stream_id: int,
        worker_id: str,
        seq: int,
        count: int,
        nbytes: int = 0,
    ) -> Dict[str, Any]:
        """Acknowledge one committed chunk (journaled with fsync BEFORE the
        ack — the ack is the writer's license to treat the chunk as durable
        committer state).  A non-owner report means the stream was
        reassigned: the (zombie) writer must stop."""
        with self._lock:
            snap = self._snapshots.get(snapshot_id)
            if snap is None or stream_id >= len(snap.streams):
                return {"ok": False, "reassigned": True}
            stream = snap.streams[stream_id]
            if stream.done or stream.assigned_to != worker_id:
                return {"ok": False, "reassigned": True}
            if seq < stream.next_seq:
                return {"ok": True, "dup": True}  # redelivered report
            if seq != stream.next_seq:
                # gap: acks for earlier chunks are still in flight (queued
                # worker-side while the dispatcher was down, draining via
                # heartbeat) — tell the writer to re-queue this one BEHIND
                # them rather than treating the stream as lost
                return {"ok": False, "retry": True}
            self._journal.append(
                "snapshot_chunk_committed",
                {
                    "snapshot_id": snapshot_id,
                    "stream_id": stream_id,
                    "seq": seq,
                    "count": count,
                    "nbytes": nbytes,
                },
                sync=True,
            )
            apply_chunk_committed(stream, seq, count, nbytes)
            return {"ok": True}

    def rpc_snapshot_stream_done(
        self, snapshot_id: str, stream_id: int, worker_id: str
    ) -> Dict[str, Any]:
        with self._lock:
            snap = self._snapshots.get(snapshot_id)
            if snap is None or stream_id >= len(snap.streams):
                return {"ok": False, "reassigned": True}
            stream = snap.streams[stream_id]
            if stream.done:
                return {"ok": True, "dup": True}
            if stream.assigned_to != worker_id:
                return {"ok": False, "reassigned": True}
            self._journal.append(
                "snapshot_stream_done",
                {"snapshot_id": snapshot_id, "stream_id": stream_id},
                sync=True,
            )
            self._apply_stream_done(snap, stream_id)
            return {"ok": True}

    def _apply_stream_done(self, snap: SnapshotState, stream_id: int) -> None:
        stream = snap.streams[stream_id]
        stream.done = True
        stream.assigned_to = None
        if snap.all_streams_done and not snap.finished:
            self._journal.append(
                "snapshot_finished", {"snapshot_id": snap.snapshot_id}, sync=True
            )
            self._finalize_snapshot(snap)

    def _finalize_snapshot(self, snap: SnapshotState) -> None:
        snap.finished = True
        # the DONE marker is what detached readers key "finished" off;
        # idempotent so a restored dispatcher can re-run it
        write_done(snap.path, snap.summary())

    def rpc_snapshot_status(
        self, snapshot_id: Optional[str] = None, path: Optional[str] = None
    ) -> Dict[str, Any]:
        with self._lock:
            if snapshot_id is None and path is not None:
                snapshot_id = self._snapshots_by_path.get(os.path.abspath(path))
            snap = self._snapshots.get(snapshot_id or "")
            if snap is None:
                return {"exists": False, "finished": False}
            return dict(snap.view(), exists=True)

    def _release_failed_stream(
        self, snapshot_id: str, stream_id: int, worker_id: str
    ) -> None:
        snap = self._snapshots.get(snapshot_id)
        if snap is None or snap.finished or stream_id >= len(snap.streams):
            return
        stream = snap.streams[stream_id]
        if stream.done or stream.assigned_to != worker_id:
            return
        self._journal.append(
            "snapshot_stream_released",
            {"snapshot_id": snapshot_id, "stream_id": stream_id},
        )
        stream.assigned_to = None
        key = (snapshot_id, stream_id)
        for w in self._workers.values():
            w.delivered_streams.discard(key)
        # reassignment happens via _assign_snapshot_streams on the next
        # heartbeat of any worker (including the one that just failed)

    def _release_worker_streams(self, worker_id: str) -> None:
        """Worker died: orphan its streams and reassign them immediately so
        materialization continues (replacements resume at the committed
        offset — the journal has every acknowledged chunk)."""
        survivors = sorted(self._workers)
        i = 0
        for snap in self._snapshots.values():
            if snap.finished:
                continue
            for s in snap.streams:
                if s.assigned_to == worker_id and not s.done:
                    self._journal.append(
                        "snapshot_stream_released",
                        {"snapshot_id": snap.snapshot_id, "stream_id": s.stream_id},
                    )
                    s.assigned_to = None
                    if survivors:
                        self._assign_stream(snap, s, survivors[i % len(survivors)])
                        i += 1

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def rpc_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "num_workers": len(self._workers),
                "worker_list_version": self._worker_list_version,
                "num_jobs": len(self._jobs),
                "jobs": {
                    j.job_id: {
                        "name": j.job_name,
                        "policy": j.policy.value,
                        "finished": j.finished,
                        "tasks": len(j.tasks),
                        "active_tasks": len(self._active_tasks(j)),
                        "completed_tasks": len(j.completed_tasks),
                        "weight": j.weight,
                        "target_share": j.target_share,
                        "clients": len(j.clients),
                        "shards": j.shard_mgr.stats() if j.shard_mgr else None,
                        # feed-side consumer latency (repro.feed reports);
                        # None until a feeder has reported recently
                        "client_stall": self._aggregate_client_stall(j),
                    }
                    for j in self._jobs.values()
                },
                "workers": {
                    wid: {
                        "address": w.info.address,
                        "buffer_occupancy": w.buffer_occupancy,
                        "cpu_busy": w.cpu_busy,
                        "cache_stats": w.cache_stats,
                    }
                    for wid, w in self._workers.items()
                },
                # sharing efficiency per pipeline fingerprint, aggregated
                # from worker heartbeats (feeds the autocache hot signal)
                "sharing": {
                    key: self._aggregate_cache_stats(key)
                    for key in sorted(
                        {k for w in self._workers.values() for k in w.cache_stats}
                    )
                },
                "snapshots": {
                    s.snapshot_id: s.view() for s in self._snapshots.values()
                },
            }

    def rpc_list_workers(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "workers": [vars(w.info) for w in self._workers.values()],
                "version": self._worker_list_version,
            }

    # ------------------------------------------------------------------
    # Journal restore (paper §3.4: replay on restart)
    # ------------------------------------------------------------------
    def _restore(self, path: str) -> None:
        events = list(Journal.replay(path))
        if not events:
            return
        with self._lock:
            for seq, etype, p in events:
                self._journal.set_seq(seq)
                if etype == "snapshot":
                    self._restore_snapshot(p)
                elif etype == "dataset_registered":
                    self._apply_dataset(p["dataset_id"], p["graph_bytes"], p["fingerprint"])
                elif etype == "job_created":
                    self._apply_job(p)
                elif etype == "job_finished":
                    if p["job_id"] in self._jobs:
                        self._jobs[p["job_id"]].finished = True
                elif etype == "task_created":
                    job = self._jobs.get(p["job_id"])
                    if job is not None:
                        task = TaskSpec(**p)
                        self._apply_task(job, task)
                        job.seq = max(job.seq, task.worker_seed)
                elif etype == "task_retired":
                    job = self._jobs.get(p["job_id"])
                    if job is not None:
                        self._apply_task_retired(job, p["task_id"])
                elif etype == "static_assignment":
                    job = self._jobs.get(p["job_id"])
                    if job is not None:
                        job.static_assignment = p["assignment"]
                elif etype == "task_completed":
                    self._complete_task(p["task_id"], journal=False)
                elif etype == "shard_assigned":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        # keep the assignment: the worker is (presumably) still
                        # alive and processing; heartbeat timeout reclaims it
                        mgr = job.shard_mgr
                        with mgr._lock:
                            for st in mgr._states:
                                if st.shard_id == p["shard_id"]:
                                    st.assigned_to = p["worker_id"]
                            try:
                                mgr._pending.remove(p["shard_id"])
                            except ValueError:
                                pass
                elif etype == "shard_completed":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        job.shard_mgr.complete_shard(p["shard_id"], p["worker_id"])
                elif etype == "shard_lost":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        for st in job.shard_mgr._states:
                            if st.shard_id == p["shard_id"] and not st.completed:
                                st.lost = True
                                st.assigned_to = None
                elif etype == "shard_offset":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        for st in job.shard_mgr._states:
                            if st.shard_id == p["shard_id"]:
                                st.offset = max(st.offset, p["offset"])
                elif etype == "snapshot_started":
                    self._apply_snapshot_started(p)
                elif etype == "snapshot_stream_assigned":
                    snap = self._snapshots.get(p["snapshot_id"])
                    if snap is not None:
                        # keep the assignment: a live writer continues
                        # seamlessly; a dead one is reclaimed by the orphan
                        # sweep / check_workers like in-flight shards
                        snap.streams[p["stream_id"]].assigned_to = p["worker_id"]
                elif etype == "snapshot_stream_released":
                    snap = self._snapshots.get(p["snapshot_id"])
                    if snap is not None:
                        snap.streams[p["stream_id"]].assigned_to = None
                elif etype == "snapshot_chunk_committed":
                    snap = self._snapshots.get(p["snapshot_id"])
                    if snap is not None:
                        apply_chunk_committed(
                            snap.streams[p["stream_id"]],
                            p["seq"],
                            p["count"],
                            p.get("nbytes", 0),
                        )
                elif etype == "snapshot_stream_done":
                    snap = self._snapshots.get(p["snapshot_id"])
                    if snap is not None:
                        stream = snap.streams[p["stream_id"]]
                        stream.done = True
                        stream.assigned_to = None
                elif etype == "snapshot_finished":
                    snap = self._snapshots.get(p["snapshot_id"])
                    if snap is not None:
                        # re-runs write_done: idempotent, covers a crash
                        # between the journal append and the DONE marker
                        self._finalize_snapshot(snap)
                # worker_registered/worker_removed: workers are transient; they
                # re-register via heartbeat after a dispatcher restart.  Tasks
                # and in-flight shard assignments are preserved verbatim: live
                # workers continue seamlessly.  Workers that DON'T come back
                # are invisible to check_workers (not in self._workers), so
                # arm the orphan sweep: one heartbeat-timeout of grace, then
                # their in-flight shards are reclaimed (lost / re-queued).
            # crash window between the last stream_done and snapshot_finished:
            # finish the finalization the dead dispatcher never got to
            for snap in self._snapshots.values():
                if snap.all_streams_done and not snap.finished:
                    self._journal.append(
                        "snapshot_finished", {"snapshot_id": snap.snapshot_id}, sync=True
                    )
                    self._finalize_snapshot(snap)
            # fleet scheduling: allocations survive the restart — the
            # replayed grant/retire history IS the allocation, so seed each
            # job's share from it (re-registering workers reclaim exactly
            # their journaled tasks; rebalance() adjusts from there)
            if self._scheduler is not None:
                for job in self._jobs.values():
                    if self._schedulable(job) and job.tasks:
                        live = [
                            t
                            for t in job.tasks.values()
                            if t.task_id not in job.completed_tasks
                        ]
                        if live:
                            job.target_share = len(live)
            if any(
                st.assigned_to and not st.completed
                for job in self._jobs.values()
                if job.shard_mgr is not None
                for st in job.shard_mgr._states
            ) or any(
                s.assigned_to and not s.done
                for snap in self._snapshots.values()
                if not snap.finished
                for s in snap.streams
            ):
                self._orphan_sweep_deadline = (
                    time.monotonic() + self._heartbeat_timeout
                )
            if any(job.tasks and not job.finished for job in self._jobs.values()):
                self._task_grace_deadline = (
                    time.monotonic() + self._heartbeat_timeout
                )
            # shards assigned to a worker holding NO task for the job are a
            # retirement whose deferred reclaim died with the dispatcher:
            # re-arm it (the worker's heartbeats drive it; the orphan sweep
            # covers workers that never come back)
            for job in self._jobs.values():
                if job.shard_mgr is None or job.finished:
                    continue
                with job.shard_mgr._lock:
                    owners = {
                        st.assigned_to
                        for st in job.shard_mgr._states
                        if st.assigned_to and not st.completed
                    }
                for wid in owners:
                    if wid not in job.tasks_by_worker:
                        self._pending_reclaims[(job.job_id, wid)] = False

    def _restore_snapshot(self, p: Dict[str, Any]) -> None:
        for ds in p.get("datasets", []):
            self._apply_dataset(ds["dataset_id"], ds["graph_bytes"], ds["fingerprint"])
        for jp in p.get("jobs", []):
            job = self._apply_job(jp["payload"])
            job.finished = jp["finished"]
            if jp.get("shard_mgr") and job.shard_mgr is not None:
                graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
                job.shard_mgr = ShardManager.from_payload(graph, jp["shard_mgr"])
        for sp in p.get("snapshots", []):
            snap = SnapshotState.from_payload(sp)
            self._snapshots[snap.snapshot_id] = snap
            self._snapshots_by_path[snap.path] = snap.snapshot_id

    def snapshot(self) -> None:
        with self._lock:
            payload = {
                "datasets": [vars(d) for d in self._datasets.values()],
                "jobs": [
                    {
                        "payload": {
                            "job_id": j.job_id,
                            "job_name": j.job_name,
                            "dataset_id": j.dataset_id,
                            "policy": j.policy.value,
                            "num_consumers": j.num_consumers,
                            "sharing": j.sharing,
                            "compression": j.compression,
                            "max_workers": j.max_workers,
                            "weight": j.weight,
                            "resume_offsets": j.resume_offsets,
                            "autocache_decision": j.autocache_decision,
                            "target_share": j.target_share,
                        },
                        "finished": j.finished,
                        "shard_mgr": j.shard_mgr.to_payload() if j.shard_mgr else None,
                    }
                    for j in self._jobs.values()
                ],
                "snapshots": [s.to_payload() for s in self._snapshots.values()],
            }
            self._journal.snapshot(payload)

    def close(self) -> None:
        self._journal.close()
