"""The tf.data-service dispatcher (paper §3.1, §3.3, §3.4).

Control plane only — never touches data.  Manages:
  * registered datasets (pipeline graphs, keyed by content fingerprint),
  * jobs (clients with the same ``job_name`` join the same job),
  * the worker pool (registration, heartbeats, failure detection),
  * per-job shard hand-out for the DYNAMIC policy (ShardManager),
  * a write-ahead journal so a restarted dispatcher recovers its state.

Threading model: a single lock guards dispatcher state (control-plane calls
are small and rare relative to data-plane traffic, which goes directly from
clients to workers — the dispatcher is deliberately off the data path).
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set

from ..data.graph import Graph
from .codecs import resolve_codec
from .journal import Journal
from .protocol import (
    FetchStatus,
    JobView,
    ShardingPolicy,
    TaskSpec,
    WorkerInfo,
    new_id,
)
from .sharding import ShardManager


@dataclass
class _Dataset:
    dataset_id: str
    graph_bytes: bytes
    fingerprint: str


@dataclass
class _Job:
    job_id: str
    job_name: str
    dataset_id: str
    policy: ShardingPolicy
    num_consumers: int = 0
    sharing: bool = False
    compression: Optional[str] = None
    max_workers: int = 0  # 0 = use all registered workers
    resume_offsets: bool = False
    tasks: Dict[str, TaskSpec] = field(default_factory=dict)  # by task_id
    tasks_by_worker: Dict[str, str] = field(default_factory=dict)
    completed_tasks: Set[str] = field(default_factory=set)
    shard_mgr: Optional[ShardManager] = None
    finished: bool = False
    clients: Set[str] = field(default_factory=set)
    seq: int = 0  # task seeds
    static_assignment: Optional[Dict[str, List[Dict[str, Any]]]] = None


@dataclass
class _Worker:
    info: WorkerInfo
    last_heartbeat: float = field(default_factory=time.monotonic)
    buffer_occupancy: float = 0.0
    cpu_busy: float = 0.0
    delivered: Set[str] = field(default_factory=set)  # task ids shipped


class Dispatcher:
    def __init__(
        self,
        journal_path: Optional[str] = None,
        heartbeat_timeout: float = 5.0,
        overpartition: int = 4,
    ):
        self._lock = threading.RLock()
        self._datasets: Dict[str, _Dataset] = {}
        self._datasets_by_fp: Dict[str, str] = {}
        self._jobs: Dict[str, _Job] = {}
        self._jobs_by_name: Dict[str, str] = {}
        self._workers: Dict[str, _Worker] = {}
        self._worker_list_version = 0
        self._heartbeat_timeout = heartbeat_timeout
        self._overpartition = overpartition
        # set after a journal restore that found shards assigned to workers
        # not (yet) re-registered: those workers get one heartbeat-timeout of
        # grace to come back before their in-flight shards are reclaimed
        self._orphan_sweep_deadline: Optional[float] = None
        self._journal = Journal(journal_path)
        if journal_path:
            self._restore(journal_path)

    # ------------------------------------------------------------------
    # RPC entry point
    # ------------------------------------------------------------------
    def handle(self, method: str, payload: Dict[str, Any]) -> Dict[str, Any]:
        fn = getattr(self, f"rpc_{method}", None)
        if fn is None:
            raise ValueError(f"dispatcher: unknown method {method}")
        return fn(**payload)

    # ------------------------------------------------------------------
    # Datasets & jobs (client-facing)
    # ------------------------------------------------------------------
    def rpc_get_or_register_dataset(self, graph_bytes: bytes) -> Dict[str, Any]:
        """Register the RAW client graph; optimize once, dispatcher-side.

        The content fingerprint is taken over the bytes the client sent —
        BEFORE optimization — because optimizer passes synthesize fresh
        fused closures whose serialization is not content-stable.  Two jobs
        submitting identical pipelines must land on the same dataset_id, or
        ephemeral data sharing (§3.5) silently degrades to one cache per
        job.  Workers receive the optimized graph.
        """
        g = Graph.from_bytes(graph_bytes)
        fp = g.fingerprint()
        with self._lock:
            if fp in self._datasets_by_fp:
                return {"dataset_id": self._datasets_by_fp[fp], "fingerprint": fp}
            from ..data.optimizer import optimize_graph

            opt_bytes = optimize_graph(g).to_bytes()
            ds_id = new_id("ds")
            self._journal.append(
                "dataset_registered",
                {"dataset_id": ds_id, "graph_bytes": opt_bytes, "fingerprint": fp},
            )
            self._apply_dataset(ds_id, opt_bytes, fp)
            return {"dataset_id": ds_id, "fingerprint": fp}

    def _apply_dataset(self, ds_id: str, graph_bytes: bytes, fp: str) -> None:
        self._datasets[ds_id] = _Dataset(ds_id, graph_bytes, fp)
        self._datasets_by_fp[fp] = ds_id

    def rpc_get_or_create_job(
        self,
        dataset_id: str,
        job_name: Optional[str] = None,
        policy: str = "off",
        num_consumers: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        max_workers: int = 0,
        resume_offsets: bool = False,
        client_id: Optional[str] = None,
        client_codecs: Optional[List[str]] = None,
    ) -> Dict[str, Any]:
        with self._lock:
            if job_name and job_name in self._jobs_by_name:
                job = self._jobs[self._jobs_by_name[job_name]]
                if client_id:
                    job.clients.add(client_id)
                return self._job_view(job)
            payload = dict(
                job_id=new_id("job"),
                job_name=job_name or "",
                dataset_id=dataset_id,
                policy=str(ShardingPolicy.parse(policy).value),
                num_consumers=num_consumers,
                sharing=sharing,
                # codec negotiation (restricted to what the requesting
                # client can decode): the journaled payload carries the
                # RESOLVED codec so workers joining after a dispatcher
                # restart compress with the same algorithm
                compression=resolve_codec(compression, client_codecs),
                max_workers=max_workers,
                resume_offsets=resume_offsets,
                # journaled so a restored dispatcher partitions the source
                # into the SAME shards (ids must stay aligned with the log)
                shard_hint=max(1, len(self._workers)) * self._overpartition,
            )
            self._journal.append("job_created", payload)
            job = self._apply_job(payload)
            if client_id:
                job.clients.add(client_id)
            return self._job_view(job)

    def _apply_job(self, p: Dict[str, Any]) -> _Job:
        job = _Job(
            job_id=p["job_id"],
            job_name=p["job_name"],
            dataset_id=p["dataset_id"],
            policy=ShardingPolicy(p["policy"]),
            num_consumers=p["num_consumers"],
            sharing=p["sharing"],
            compression=p.get("compression"),
            max_workers=p.get("max_workers", 0),
            resume_offsets=p.get("resume_offsets", False),
        )
        if job.policy in (ShardingPolicy.DYNAMIC, ShardingPolicy.STATIC):
            graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
            hint = p.get("shard_hint") or max(1, len(self._workers)) * self._overpartition
            job.shard_mgr = ShardManager(
                graph,
                job.policy,
                num_workers_hint=hint,
                overpartition=1,
                resume_offsets=job.resume_offsets,
            )
        self._jobs[job.job_id] = job
        if job.job_name:
            self._jobs_by_name[job.job_name] = job.job_id
        # every registered worker gets a task for the new job (scale-out)
        for w in self._workers.values():
            self._ensure_task(job, w.info)
        return job

    def _ensure_task(self, job: _Job, w: WorkerInfo) -> Optional[TaskSpec]:
        if job.finished or w.worker_id in job.tasks_by_worker:
            return None
        if job.max_workers and len(job.tasks) >= job.max_workers:
            return None
        ds = self._datasets[job.dataset_id]
        job.seq += 1
        task = TaskSpec(
            task_id=new_id("task"),
            job_id=job.job_id,
            dataset_id=job.dataset_id,
            worker_id=w.worker_id,
            worker_address=w.address,
            policy=job.policy.value,
            num_consumers=job.num_consumers,
            round_robin=job.num_consumers > 0,
            shared=job.sharing,
            cache_key=ds.fingerprint if job.sharing else None,
            worker_seed=job.seq,
        )
        # journal task creation: task ids must be STABLE across dispatcher
        # restarts so live workers/clients keep their handles (§3.4)
        self._journal.append("task_created", vars(task).copy())
        self._apply_task(job, task)
        return task

    def _apply_task(self, job: _Job, task: TaskSpec) -> None:
        job.tasks[task.task_id] = task
        job.tasks_by_worker[task.worker_id] = task.task_id

    def _job_view(self, job: _Job) -> Dict[str, Any]:
        return {
            "job_id": job.job_id,
            "dataset_id": job.dataset_id,
            "policy": job.policy.value,
            "num_consumers": job.num_consumers,
            "finished": job.finished,
            "worker_list_version": self._worker_list_version,
            "compression": job.compression,
            "tasks": [vars(t) for t in self._active_tasks(job)],
        }

    def _active_tasks(self, job: _Job) -> List[TaskSpec]:
        return [
            t
            for t in job.tasks.values()
            if t.task_id not in job.completed_tasks
            and t.worker_id in self._workers
        ]

    def rpc_client_heartbeat(
        self, job_id: str, client_id: str, starving: bool = False
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None:
                raise KeyError(f"unknown job {job_id}")
            job.clients.add(client_id)
            self._maybe_finish(job)
            view = self._job_view(job)
            view["starving_ack"] = starving
            return view

    # ------------------------------------------------------------------
    # Workers
    # ------------------------------------------------------------------
    def rpc_register_worker(
        self, worker_id: str, address: str, tags: Optional[Dict[str, Any]] = None
    ) -> Dict[str, Any]:
        with self._lock:
            self._journal.append(
                "worker_registered", {"worker_id": worker_id, "address": address}
            )
            is_new = worker_id not in self._workers
            # (re)registration resets delivery state — stateless workers that
            # restart must receive their tasks again (paper §3.4)
            self._workers[worker_id] = _Worker(WorkerInfo(worker_id, address, tags or {}))
            if is_new:
                self._worker_list_version += 1
            w = self._workers[worker_id]
            tasks = self._undelivered_tasks(w)
            return {"tasks": tasks, "worker_list_version": self._worker_list_version}

    def _undelivered_tasks(self, w: _Worker) -> List[Dict[str, Any]]:
        """Tasks for every active job not yet shipped to this worker."""
        out: List[Dict[str, Any]] = []
        for job in self._jobs.values():
            if job.finished:
                continue
            t = self._ensure_task(job, w.info)
            if t is None:
                tid = job.tasks_by_worker.get(w.info.worker_id)
                if tid and tid not in job.completed_tasks:
                    t = job.tasks[tid]
            if t is not None and t.task_id not in w.delivered:
                w.delivered.add(t.task_id)
                out.append(self._task_payload(t, job))
        return out

    def _task_payload(self, t: TaskSpec, job: _Job) -> Dict[str, Any]:
        ds = self._datasets[job.dataset_id]
        p = vars(t).copy()
        p["graph_bytes"] = ds.graph_bytes
        p["compression"] = job.compression
        p["resume_offsets"] = job.resume_offsets
        p["static_shards"] = None
        if job.policy == ShardingPolicy.STATIC and job.shard_mgr is not None:
            # computed ONCE over the workers present at first hand-out (the
            # paper's "up-front" semantics) and journaled for restart stability
            if job.static_assignment is None:
                assignment = job.shard_mgr.static_assignment(
                    sorted(job.tasks_by_worker)
                )
                self._journal.append(
                    "static_assignment",
                    {"job_id": job.job_id, "assignment": assignment},
                )
                job.static_assignment = assignment
            p["static_shards"] = job.static_assignment.get(t.worker_id, [])
        return p

    def rpc_worker_heartbeat(
        self,
        worker_id: str,
        buffer_occupancy: float = 0.0,
        cpu_busy: float = 0.0,
        completed_tasks: Optional[List[str]] = None,
    ) -> Dict[str, Any]:
        with self._lock:
            w = self._workers.get(worker_id)
            if w is None:
                # unknown worker (e.g. dispatcher restarted): ask it to re-register
                return {"reregister": True}
            w.last_heartbeat = time.monotonic()
            w.buffer_occupancy = buffer_occupancy
            w.cpu_busy = cpu_busy
            for tid in completed_tasks or []:
                self._complete_task(tid, journal=True)
            new_tasks = self._undelivered_tasks(w)
            valid = [
                job.tasks_by_worker[worker_id]
                for job in self._jobs.values()
                if worker_id in job.tasks_by_worker and not job.finished
            ]
            return {
                "new_tasks": new_tasks,
                "valid_tasks": valid,
                "worker_list_version": self._worker_list_version,
                "reregister": False,
            }

    def _complete_task(self, task_id: str, journal: bool) -> None:
        for job in self._jobs.values():
            if task_id in job.tasks and task_id not in job.completed_tasks:
                if journal:
                    self._journal.append("task_completed", {"task_id": task_id})
                job.completed_tasks.add(task_id)
                self._maybe_finish(job)

    def _maybe_finish(self, job: _Job) -> None:
        if job.finished or not job.tasks:
            return
        live = [t for t in job.tasks.values() if t.worker_id in self._workers]
        all_done = all(t.task_id in job.completed_tasks for t in live) and live
        if job.policy == ShardingPolicy.DYNAMIC and job.shard_mgr is not None:
            if job.shard_mgr.done() and all_done:
                self._finish_job(job)
        elif all_done:
            self._finish_job(job)

    def _finish_job(self, job: _Job) -> None:
        self._journal.append("job_finished", {"job_id": job.job_id})
        job.finished = True

    # -- failure detection ------------------------------------------------
    def check_workers(self) -> List[str]:
        """Mark workers dead after heartbeat timeout. Returns removed ids.

        Called by the orchestrator's GC loop (or tests directly).
        """
        now = time.monotonic()
        removed = []
        with self._lock:
            for wid, w in list(self._workers.items()):
                if now - w.last_heartbeat > self._heartbeat_timeout:
                    removed.append(wid)
                    self._remove_worker(wid)
            self._sweep_orphan_shards(now)
        return removed

    def _sweep_orphan_shards(self, now: float) -> None:
        """Reclaim shards assigned (pre-restart, per the journal) to workers
        that never re-registered.  check_workers can't see them — they are
        not in self._workers — so without this sweep such shards stay
        in-flight forever and the job never finishes."""
        if self._orphan_sweep_deadline is None or now < self._orphan_sweep_deadline:
            return
        self._orphan_sweep_deadline = None
        for job in self._jobs.values():
            mgr = job.shard_mgr
            if mgr is None or job.finished:
                continue
            orphans = {
                st.assigned_to
                for st in mgr._states
                if st.assigned_to and not st.completed
                and st.assigned_to not in self._workers
            }
            for wid in orphans:
                for sid in mgr.worker_failed(wid):
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": wid},
                    )
            if orphans:
                self._maybe_finish(job)

    def rpc_remove_worker(self, worker_id: str) -> Dict[str, Any]:
        """Administrative removal (tests / orchestrator-initiated)."""
        with self._lock:
            self._remove_worker(worker_id)
        return {"ok": True}

    def _remove_worker(self, worker_id: str) -> None:
        if worker_id not in self._workers:
            return
        self._journal.append("worker_removed", {"worker_id": worker_id})
        del self._workers[worker_id]
        self._worker_list_version += 1
        for job in self._jobs.values():
            if job.shard_mgr is not None:
                lost = job.shard_mgr.worker_failed(worker_id)
                for sid in lost:
                    self._journal.append(
                        "shard_lost",
                        {"job_id": job.job_id, "shard_id": sid, "worker_id": worker_id},
                    )
            self._maybe_finish(job)

    # ------------------------------------------------------------------
    # DYNAMIC sharding hand-out (worker-facing)
    # ------------------------------------------------------------------
    def rpc_get_shard(self, job_id: str, worker_id: str) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is None or job.shard_mgr is None:
                return {"done": True}
            nxt = job.shard_mgr.next_shard(worker_id)
            if nxt is None:
                # resume_offsets: an in-flight shard on a dying worker can
                # RE-ENTER the queue — "empty now" is not "drained".  Tell
                # workers to poll again instead of retiring their task.
                if job.shard_mgr.resume_offsets and not job.shard_mgr.done():
                    return {"done": False, "wait": True}
                return {"done": True}
            sid, shard, offset = nxt
            self._journal.append(
                "shard_assigned",
                {"job_id": job_id, "shard_id": sid, "worker_id": worker_id},
            )
            return {"done": False, "shard_id": sid, "shard": shard, "offset": offset}

    def rpc_complete_shard(
        self, job_id: str, shard_id: int, worker_id: str
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_completed",
                    {"job_id": job_id, "shard_id": shard_id, "worker_id": worker_id},
                )
                job.shard_mgr.complete_shard(shard_id, worker_id)
            return {"ok": True}

    def rpc_checkpoint_offset(
        self, job_id: str, shard_id: int, worker_id: str, offset: int
    ) -> Dict[str, Any]:
        with self._lock:
            job = self._jobs.get(job_id)
            if job is not None and job.shard_mgr is not None:
                self._journal.append(
                    "shard_offset",
                    {"job_id": job_id, "shard_id": shard_id, "offset": offset},
                )
                job.shard_mgr.checkpoint_offset(shard_id, worker_id, offset)
            return {"ok": True}

    # ------------------------------------------------------------------
    # Introspection
    # ------------------------------------------------------------------
    def rpc_stats(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "num_workers": len(self._workers),
                "worker_list_version": self._worker_list_version,
                "num_jobs": len(self._jobs),
                "jobs": {
                    j.job_id: {
                        "name": j.job_name,
                        "policy": j.policy.value,
                        "finished": j.finished,
                        "tasks": len(j.tasks),
                        "completed_tasks": len(j.completed_tasks),
                        "clients": len(j.clients),
                        "shards": j.shard_mgr.stats() if j.shard_mgr else None,
                    }
                    for j in self._jobs.values()
                },
                "workers": {
                    wid: {
                        "address": w.info.address,
                        "buffer_occupancy": w.buffer_occupancy,
                        "cpu_busy": w.cpu_busy,
                    }
                    for wid, w in self._workers.items()
                },
            }

    def rpc_list_workers(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "workers": [vars(w.info) for w in self._workers.values()],
                "version": self._worker_list_version,
            }

    # ------------------------------------------------------------------
    # Journal restore (paper §3.4: replay on restart)
    # ------------------------------------------------------------------
    def _restore(self, path: str) -> None:
        events = list(Journal.replay(path))
        if not events:
            return
        with self._lock:
            for seq, etype, p in events:
                self._journal.set_seq(seq)
                if etype == "snapshot":
                    self._restore_snapshot(p)
                elif etype == "dataset_registered":
                    self._apply_dataset(p["dataset_id"], p["graph_bytes"], p["fingerprint"])
                elif etype == "job_created":
                    self._apply_job(p)
                elif etype == "job_finished":
                    if p["job_id"] in self._jobs:
                        self._jobs[p["job_id"]].finished = True
                elif etype == "task_created":
                    job = self._jobs.get(p["job_id"])
                    if job is not None:
                        task = TaskSpec(**p)
                        self._apply_task(job, task)
                        job.seq = max(job.seq, task.worker_seed)
                elif etype == "static_assignment":
                    job = self._jobs.get(p["job_id"])
                    if job is not None:
                        job.static_assignment = p["assignment"]
                elif etype == "task_completed":
                    self._complete_task(p["task_id"], journal=False)
                elif etype == "shard_assigned":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        # keep the assignment: the worker is (presumably) still
                        # alive and processing; heartbeat timeout reclaims it
                        mgr = job.shard_mgr
                        with mgr._lock:
                            for st in mgr._states:
                                if st.shard_id == p["shard_id"]:
                                    st.assigned_to = p["worker_id"]
                            try:
                                mgr._pending.remove(p["shard_id"])
                            except ValueError:
                                pass
                elif etype == "shard_completed":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        job.shard_mgr.complete_shard(p["shard_id"], p["worker_id"])
                elif etype == "shard_lost":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        for st in job.shard_mgr._states:
                            if st.shard_id == p["shard_id"] and not st.completed:
                                st.lost = True
                                st.assigned_to = None
                elif etype == "shard_offset":
                    job = self._jobs.get(p["job_id"])
                    if job and job.shard_mgr:
                        for st in job.shard_mgr._states:
                            if st.shard_id == p["shard_id"]:
                                st.offset = max(st.offset, p["offset"])
                # worker_registered/worker_removed: workers are transient; they
                # re-register via heartbeat after a dispatcher restart.  Tasks
                # and in-flight shard assignments are preserved verbatim: live
                # workers continue seamlessly.  Workers that DON'T come back
                # are invisible to check_workers (not in self._workers), so
                # arm the orphan sweep: one heartbeat-timeout of grace, then
                # their in-flight shards are reclaimed (lost / re-queued).
            if any(
                st.assigned_to and not st.completed
                for job in self._jobs.values()
                if job.shard_mgr is not None
                for st in job.shard_mgr._states
            ):
                self._orphan_sweep_deadline = (
                    time.monotonic() + self._heartbeat_timeout
                )

    def _restore_snapshot(self, p: Dict[str, Any]) -> None:
        for ds in p.get("datasets", []):
            self._apply_dataset(ds["dataset_id"], ds["graph_bytes"], ds["fingerprint"])
        for jp in p.get("jobs", []):
            job = self._apply_job(jp["payload"])
            job.finished = jp["finished"]
            if jp.get("shard_mgr") and job.shard_mgr is not None:
                graph = Graph.from_bytes(self._datasets[job.dataset_id].graph_bytes)
                job.shard_mgr = ShardManager.from_payload(graph, jp["shard_mgr"])

    def snapshot(self) -> None:
        with self._lock:
            payload = {
                "datasets": [vars(d) for d in self._datasets.values()],
                "jobs": [
                    {
                        "payload": {
                            "job_id": j.job_id,
                            "job_name": j.job_name,
                            "dataset_id": j.dataset_id,
                            "policy": j.policy.value,
                            "num_consumers": j.num_consumers,
                            "sharing": j.sharing,
                            "compression": j.compression,
                            "max_workers": j.max_workers,
                            "resume_offsets": j.resume_offsets,
                        },
                        "finished": j.finished,
                        "shard_mgr": j.shard_mgr.to_payload() if j.shard_mgr else None,
                    }
                    for j in self._jobs.values()
                ],
            }
            self._journal.snapshot(payload)

    def close(self) -> None:
        self._journal.close()
