"""Encoder-decoder transformer (whisper-large-v3 backbone).

Per the assignment, the conv/mel frontend is a STUB: the model consumes
precomputed frame embeddings ``enc_embeds`` of shape (B, encoder_seq, d_model)
(``input_specs()`` supplies the ShapeDtypeStruct).  Encoder layers are
bidirectional; decoder layers are causal self-attention + cross-attention
over the encoder output.  Decode uses a self-attn KV ring plus precomputed
cross-attention K/V (computed once per sequence at prefill).
"""
from __future__ import annotations

import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.context import shard_activations
from .config import ModelConfig
from . import layers as L


def _init_enc_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2 = jax.random.split(key)
    return {
        "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "attn": L.init_attention(k1, cfg),
        "mlp": L.init_mlp(k2, cfg),
    }


def _init_dec_block(key, cfg: ModelConfig) -> Dict[str, Any]:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "ln_x": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "attn": L.init_attention(k1, cfg),
        "xattn": L.init_attention(k2, cfg),
        "mlp": L.init_mlp(k3, cfg),
    }


def _sinusoid(seq: int, d: int) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32)[:, None]
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-math.log(10000.0) / d))
    pe = jnp.zeros((seq, d), jnp.float32)
    pe = pe.at[:, 0::2].set(jnp.sin(pos * div))
    pe = pe.at[:, 1::2].set(jnp.cos(pos * div))
    return pe


def _sinusoid_at(pos: jnp.ndarray, d: int) -> jnp.ndarray:
    """Positional embedding row for a dynamic position scalar."""
    div = jnp.exp(jnp.arange(0, d, 2, jnp.float32) * (-math.log(10000.0) / d))
    angle = pos.astype(jnp.float32) * div
    pe = jnp.zeros((d,), jnp.float32)
    pe = pe.at[0::2].set(jnp.sin(angle))
    pe = pe.at[1::2].set(jnp.cos(angle))
    return pe


class EncDecModel:
    def __init__(self, cfg: ModelConfig):
        assert cfg.family == "encdec"
        self.cfg = cfg

    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        ks = jax.random.split(key, 4)
        enc = [
            _init_enc_block(jax.random.fold_in(ks[0], i), cfg)
            for i in range(cfg.encoder_layers)
        ]
        dec = [
            _init_dec_block(jax.random.fold_in(ks[1], i), cfg)
            for i in range(cfg.num_layers)
        ]
        stack = lambda blocks: jax.tree.map(lambda *xs: jnp.stack(xs), *blocks)
        return {
            "embed": L._init(ks[2], (cfg.vocab_size, cfg.d_model), 0.02, L.pdt(cfg)),
            "enc": stack(enc),
            "dec": stack(dec),
            "enc_norm": jnp.ones((cfg.d_model,), L.pdt(cfg)),
            "final_norm": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        }

    # -- encoder -----------------------------------------------------------
    def encode(self, params: Dict[str, Any], enc_embeds: jnp.ndarray) -> jnp.ndarray:
        cfg = self.cfg
        B, S, d = enc_embeds.shape
        x = enc_embeds.astype(L.cdt(cfg)) + _sinusoid(S, d).astype(L.cdt(cfg))[None]
        x = shard_activations(x, "bsd")
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(carry, p):
            h = carry
            a = L.attention(
                p["attn"], L.rms_norm(h, p["ln1"]), cfg, positions,
                causal=False, use_rope=False,
            )
            h = h + a
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]), cfg.mlp_act)
            return shard_activations(h, "bsd"), None

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, params["enc"])
        return L.rms_norm(x, params["enc_norm"])

    # -- decoder (teacher-forced training / prefill) -------------------------
    def forward(
        self,
        params: Dict[str, Any],
        batch: Dict[str, Any],
        last_token_only: bool = False,
    ) -> jnp.ndarray:
        cfg = self.cfg
        enc_out = self.encode(params, batch["enc_embeds"])
        tokens = batch["tokens"]
        B, S = tokens.shape
        x = params["embed"].astype(L.cdt(cfg))[tokens]
        x = x + _sinusoid(S, cfg.d_model).astype(x.dtype)[None]
        x = shard_activations(x, "bsd")
        positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

        def body(carry, p):
            h = carry
            h = h + L.attention(
                p["attn"], L.rms_norm(h, p["ln1"]), cfg, positions,
                causal=True, use_rope=False,
            )
            h = h + L.attention(
                p["xattn"], L.rms_norm(h, p["ln_x"]), cfg, positions,
                causal=False, kv_x=enc_out, use_rope=False,
            )
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]), cfg.mlp_act)
            return shard_activations(h, "bsd"), None

        if cfg.remat == "block":
            body = jax.checkpoint(body)
        x, _ = lax.scan(body, x, params["dec"])
        x = L.rms_norm(x, params["final_norm"])
        if last_token_only:
            x = x[:, -1:, :]
        logits = x @ params["embed"].T.astype(x.dtype)  # whisper ties embeddings
        return logits.astype(jnp.float32) if cfg.logits_fp32 else logits

    # -- decode -------------------------------------------------------------
    def init_cache(
        self, params: Dict[str, Any], batch_size: int, max_seq: int,
        enc_embeds: Optional[jnp.ndarray] = None,
    ) -> Dict[str, Any]:
        """Self-attn KV ring + precomputed cross-attn K/V from the encoder."""
        cfg = self.cfg
        dt = L.cdt(cfg)
        Ld = cfg.num_layers
        if enc_embeds is None:
            enc_out = jnp.zeros((batch_size, cfg.encoder_seq, cfg.d_model), dt)
        else:
            enc_out = self.encode(params, enc_embeds)

        def xkv(p):  # (Ld, ...) stacked xattn K/V
            k = jnp.einsum("bsd,ldk->lbsk", enc_out, p["xattn"]["wk"].astype(dt))
            v = jnp.einsum("bsd,ldk->lbsk", enc_out, p["xattn"]["wv"].astype(dt))
            S = enc_out.shape[1]
            k = k.reshape(Ld, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
            v = v.reshape(Ld, batch_size, S, cfg.num_kv_heads, cfg.head_dim)
            return k, v

        xk, xv = xkv(params["dec"])
        return {
            "pos": jnp.zeros((), jnp.int32),
            "k": jnp.zeros(
                (Ld, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dt
            ),
            "v": jnp.zeros(
                (Ld, batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dt
            ),
            "xk": xk,
            "xv": xv,
        }

    def decode_step(
        self, params: Dict[str, Any], cache: Dict[str, Any], tokens: jnp.ndarray
    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        cfg = self.cfg
        pos = cache["pos"]
        B = tokens.shape[0]
        x = params["embed"].astype(L.cdt(cfg))[tokens][:, None, :]
        x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)[None, None, :]

        def body(carry, inp):
            h = carry
            p, kc, vc, xk, xv = inp
            a, c_new = L.attention_decode(
                p["attn"], L.rms_norm(h, p["ln1"]), {"k": kc, "v": vc}, pos, cfg
            )
            h = h + a
            h = h + self._cross_decode(p["xattn"], L.rms_norm(h, p["ln_x"]), xk, xv)
            h = h + L.mlp(p["mlp"], L.rms_norm(h, p["ln2"]), cfg.mlp_act)
            return h, (c_new["k"], c_new["v"])

        x, (k_new, v_new) = lax.scan(
            body, x, (params["dec"], cache["k"], cache["v"], cache["xk"], cache["xv"])
        )
        x = L.rms_norm(x, params["final_norm"])
        logits = (x @ params["embed"].T.astype(x.dtype))[:, 0]
        new_cache = dict(cache)
        new_cache.update({"pos": pos + 1, "k": k_new, "v": v_new})
        return logits.astype(jnp.float32), new_cache

    def _cross_decode(self, p, x_t, xk, xv):
        cfg = self.cfg
        B = x_t.shape[0]
        q = (x_t @ p["wq"].astype(x_t.dtype)).reshape(
            B, cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads, cfg.head_dim
        )
        scale = 1.0 / math.sqrt(cfg.head_dim)
        qf = (q.astype(jnp.float32) * scale).astype(xk.dtype)
        s = jnp.einsum(
            "bhgd,bkhd->bhgk", qf, xk, preferred_element_type=jnp.float32
        )
        pvals = jax.nn.softmax(s, axis=-1)
        out = jnp.einsum(
            "bhgk,bkhd->bhgd", pvals.astype(xv.dtype), xv,
            preferred_element_type=jnp.float32,
        ).astype(x_t.dtype)
        return out.reshape(B, 1, cfg.q_dim) @ p["wo"].astype(x_t.dtype)
