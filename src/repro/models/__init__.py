"""repro.models — 10-architecture model zoo (dense GQA / MoE / SSM / hybrid /
enc-dec / VLM backbones) in pure JAX, scan-over-layers, mesh-agnostic."""
from typing import Union

from .config import ModelConfig, ShapeConfig, SHAPES
from .encdec import EncDecModel
from .lm import LanguageModel

Model = Union[LanguageModel, EncDecModel]


def build_model(cfg: ModelConfig) -> Model:
    if cfg.family == "encdec":
        return EncDecModel(cfg)
    return LanguageModel(cfg)


__all__ = [
    "EncDecModel",
    "LanguageModel",
    "Model",
    "ModelConfig",
    "SHAPES",
    "ShapeConfig",
    "build_model",
]
