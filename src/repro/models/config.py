"""Unified model configuration covering all assigned architecture families.

One ``ModelConfig`` describes dense GQA transformers, MoE, SSM (mamba2/SSD),
hybrid (jamba), encoder-decoder (whisper) and VLM-backbone (qwen2-vl) models.
``src/repro/configs/<id>.py`` instantiate the exact assigned configs; smoke
tests use ``scaled_down()`` reductions of the same family.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 => d_model // num_heads

    # attention details
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope: bool = False  # qwen2-vl multimodal RoPE (sectioned rotary)
    attn_window: int = 0  # 0 = full; >0 = sliding-window attention
    tie_embeddings: bool = False
    attn_logit_softcap: float = 0.0

    # MLP
    mlp_act: str = "swiglu"  # swiglu | gelu

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1  # MoE FFN every k-th layer (others dense)
    first_dense_layers: int = 0  # leading dense layers (kimi-k2 style)
    capacity_factor: float = 1.25
    # GShard-style 2D dispatch: tokens split into `moe_groups` groups
    # (aligned with the data-parallel shards), capacity per group.  0/1 =
    # single global group.  Groups keep the dispatch scatter local to each
    # dp shard — see EXPERIMENTS.md §Perf kimi iterations.
    moe_groups: int = 0

    # SSM (mamba2 / SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_groups: int = 1
    ssm_chunk: int = 128
    # hybrid interleave: one attention layer every `attn_period` layers,
    # at offset `attn_offset` (jamba: period 8, offset 7 => 1:7 ratio)
    attn_period: int = 0
    attn_offset: int = 0

    # encoder-decoder (whisper): `num_layers` is the decoder depth
    encoder_layers: int = 0
    encoder_seq: int = 0  # fixed encoder length (1500 mel frames for whisper)

    # modality frontend stubs ([audio]/[vlm]: precomputed embeddings)
    frontend: str = "none"  # none | audio_stub | vision_stub

    # launch-time sharding plan hints, consumed by launch/dryrun via
    # repro.dist: FSDP extended over the DCN pod axis and bf16 optimizer
    # moments are what let the 405B/1T configs fit a 256-chip pod.
    fsdp_over_pod: bool = False
    opt_state_dtype: str = "float32"

    # numerics / runtime
    dtype: str = "bfloat16"
    param_dtype: str = "float32"
    attn_impl: str = "xla"  # xla | pallas | pallas_interpret
    attn_chunk: int = 512  # KV-chunk for the xla flash-equivalent
    remat: str = "block"  # none | block  (remat each layer block)
    logits_fp32: bool = True

    def __post_init__(self) -> None:
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // max(1, self.num_heads))

    # -- derived -----------------------------------------------------------
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.ssm_d_inner // self.ssm_head_dim

    def is_attn_layer(self, i: int) -> bool:
        """Mixer type for layer i (hybrid interleave; paper arch: jamba)."""
        if self.family == "ssm":
            return False
        if self.family == "hybrid" and self.attn_period > 0:
            return i % self.attn_period == self.attn_offset
        return True

    def is_moe_layer(self, i: int) -> bool:
        if self.num_experts == 0 or i < self.first_dense_layers:
            return False
        return (i % max(1, self.moe_every)) == (max(1, self.moe_every) - 1)

    # -- parameter count (for 6ND model-flops accounting) -------------------
    def param_counts(self) -> Dict[str, float]:
        d, ff, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        per_attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.qk_norm:
            per_attn += 2 * self.head_dim
        n_mlp_mats = 3 if self.mlp_act == "swiglu" else 2
        per_dense_ffn = n_mlp_mats * d * ff
        per_moe_ffn = self.num_experts * n_mlp_mats * d * ff + d * self.num_experts
        per_active_moe_ffn = self.experts_per_token * n_mlp_mats * d * ff
        di, N, H = self.ssm_d_inner, self.ssm_state, self.ssm_heads
        per_ssm = (
            d * (2 * di + 2 * self.ssm_groups * N + H)  # in_proj
            + di * d  # out_proj
            + 3 * H  # A, D, dt_bias
            + 4 * (di + 2 * self.ssm_groups * N)  # conv1d
        )
        total = emb
        active = emb
        layers = self.num_layers + self.encoder_layers
        for i in range(self.num_layers):
            mixer = per_attn if self.is_attn_layer(i) else per_ssm
            ffn = per_moe_ffn if self.is_moe_layer(i) else per_dense_ffn
            ffn_active = per_active_moe_ffn if self.is_moe_layer(i) else per_dense_ffn
            norms = 2 * d
            total += mixer + ffn + norms
            active += mixer + ffn_active + norms
        for _ in range(self.encoder_layers):  # enc-dec: encoder always dense attn
            total += per_attn + per_dense_ffn + 2 * d
            active += per_attn + per_dense_ffn + 2 * d
        if self.encoder_layers:  # decoder cross-attention
            total += self.num_layers * per_attn
            active += self.num_layers * per_attn
        return {"total": float(total), "active": float(active)}

    # -- reductions for smoke tests -----------------------------------------
    def scaled_down(self) -> "ModelConfig":
        """A tiny config of the same family for CPU smoke tests."""
        changes: Dict[str, Any] = dict(
            num_layers=min(self.num_layers, 4 if self.family != "hybrid" else 8),
            d_model=128,
            num_heads=4,
            num_kv_heads=min(self.num_kv_heads, 2) or 2,
            head_dim=32,
            d_ff=256,
            vocab_size=512,
            encoder_layers=min(self.encoder_layers, 2),
            encoder_seq=min(self.encoder_seq, 32) if self.encoder_seq else 0,
            param_dtype="float32",
            dtype="float32",
            remat="none",
            attn_chunk=64,
            ssm_chunk=16,
        )
        if self.num_experts:
            changes["num_experts"] = min(self.num_experts, 8)
            changes["experts_per_token"] = min(self.experts_per_token, 2)
        if self.ssm_state:
            changes["ssm_state"] = 16
            changes["ssm_head_dim"] = 32
        if self.family == "hybrid":
            changes["attn_period"] = min(self.attn_period, 4) or 4
            changes["attn_offset"] = (changes["attn_period"] - 1)
        if self.first_dense_layers:
            changes["first_dense_layers"] = 1
        return dataclasses.replace(self, **changes)

    def replace(self, **kw: Any) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES: Dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}
