"""Decoder-only language model covering dense / MoE / SSM / hybrid / VLM
families, built from repro.models.layers.

Layers are organized into *groups* — (sub-pattern, repeats) — so homogeneous
stacks compile as a single ``lax.scan`` over stacked parameters (compact HLO,
mandatory at 126 layers) while heterogeneous interleaves (jamba's 1:7
mamba:attn with MoE-every-2; kimi's leading dense layer) scan over periods
with the period body unrolled.

Forward signature is batch-dict based:
  * dense/moe/ssm/hybrid: {"tokens": (B,S) i32}
  * vlm ([vlm] stub):     {"embeds": (B,S,d), "positions": (B,S,3)}
(labels handled by the train-step, not the model).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.context import shard_activations
from .config import ModelConfig
from . import layers as L

LayerSpec = Tuple[str, str]  # (mixer: attn|ssm, ffn: dense|moe)


# ---------------------------------------------------------------------------
# Layer grouping
# ---------------------------------------------------------------------------
@dataclass(frozen=True)
class LayerGroup:
    subpattern: Tuple[LayerSpec, ...]
    repeats: int


def layer_pattern(cfg: ModelConfig) -> List[LayerSpec]:
    def ffn_kind(i: int) -> str:
        if cfg.is_moe_layer(i):
            return "moe"
        return "dense" if cfg.d_ff > 0 else "none"  # mamba2 blocks: mixer only

    return [
        ("attn" if cfg.is_attn_layer(i) else "ssm", ffn_kind(i))
        for i in range(cfg.num_layers)
    ]


def compute_groups(cfg: ModelConfig) -> List[LayerGroup]:
    pattern = layer_pattern(cfg)
    groups: List[LayerGroup] = []
    i = 0
    if cfg.first_dense_layers:
        groups.append(
            LayerGroup(tuple(pattern[: cfg.first_dense_layers]), repeats=1)
        )
        i = cfg.first_dense_layers
    body = pattern[i:]
    if not body:
        return groups
    period = 1
    if cfg.family == "hybrid" and cfg.attn_period:
        period = cfg.attn_period
    elif cfg.num_experts and cfg.moe_every > 1:
        period = cfg.moe_every
    # verify periodicity (construction guarantees it; assert for safety)
    assert len(body) % period == 0, (len(body), period)
    sub = tuple(body[:period])
    for r in range(len(body) // period):
        assert tuple(body[r * period : (r + 1) * period]) == sub
    groups.append(LayerGroup(sub, repeats=len(body) // period))
    return groups


# ---------------------------------------------------------------------------
# Block apply (one layer)
# ---------------------------------------------------------------------------
def block_apply(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict[str, Any],
    x: jnp.ndarray,
    positions: jnp.ndarray,
) -> jnp.ndarray:
    mixer, ffn = spec
    B, S, d = x.shape
    h = L.rms_norm(x, p["ln1"])
    if mixer == "attn":
        h = L.attention(p["attn"], h, cfg, positions, causal=True)
    else:
        h = L.mamba2_mixer(p["ssm"], h, cfg)
    x = shard_activations(x + h, "bsd")
    if ffn == "none":
        return x
    h2 = L.rms_norm(x, p["ln2"])
    if ffn == "moe":
        h2 = L.moe_ffn(p["moe"], h2.reshape(B * S, d), cfg).reshape(B, S, d)
    else:
        h2 = L.mlp(p["mlp"], h2, cfg.mlp_act)
    return shard_activations(x + h2, "bsd")


def block_decode(
    cfg: ModelConfig,
    spec: LayerSpec,
    p: Dict[str, Any],
    c: Dict[str, Any],
    x_t: jnp.ndarray,
    pos: jnp.ndarray,
) -> Tuple[jnp.ndarray, Dict[str, Any]]:
    mixer, ffn = spec
    B = x_t.shape[0]
    h = L.rms_norm(x_t, p["ln1"])
    if mixer == "attn":
        h, c_new = L.attention_decode(p["attn"], h, c, pos, cfg)
    else:
        h, c_new = L.mamba2_decode(p["ssm"], h, c, cfg)
    x_t = x_t + h
    if ffn == "none":
        return x_t, c_new
    h2 = L.rms_norm(x_t, p["ln2"])
    if ffn == "moe":
        # serving is dropless: capacity-dropping a decode token silently
        # corrupts its output (training tolerates drops, inference must not)
        h2 = L.moe_ffn(p["moe"], h2.reshape(B, -1), cfg, dropless=True).reshape(B, 1, -1)
    else:
        h2 = L.mlp(p["mlp"], h2, cfg.mlp_act)
    return x_t + h2, c_new


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------
def _init_block(key, cfg: ModelConfig, spec: LayerSpec) -> Dict[str, Any]:
    kmix, kffn = jax.random.split(key)
    p: Dict[str, Any] = {
        "ln1": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        "ln2": jnp.ones((cfg.d_model,), L.pdt(cfg)),
    }
    mixer, ffn = spec
    if mixer == "attn":
        p["attn"] = L.init_attention(kmix, cfg)
    else:
        p["ssm"] = L.init_mamba2(kmix, cfg)
    if ffn == "moe":
        p["moe"] = L.init_moe(kffn, cfg)
    elif ffn == "dense":
        p["mlp"] = L.init_mlp(kffn, cfg)
    else:  # "none": mamba2 block has no separate FFN
        del p["ln2"]
    return p


def _stack(trees: List[Any]) -> Any:
    return jax.tree.map(lambda *xs: jnp.stack(xs), *trees)


class LanguageModel:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.groups = compute_groups(cfg)

    # -- params ---------------------------------------------------------
    def init(self, key: jax.Array) -> Dict[str, Any]:
        cfg = self.cfg
        keys = jax.random.split(key, 3 + len(self.groups))
        params: Dict[str, Any] = {
            "embed": L._init(
                keys[0], (cfg.vocab_size, cfg.d_model), 0.02, L.pdt(cfg)
            ),
            "final_norm": jnp.ones((cfg.d_model,), L.pdt(cfg)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L._init(
                keys[1], (cfg.d_model, cfg.vocab_size), 0.02, L.pdt(cfg)
            )
        for gi, g in enumerate(self.groups):
            gkey = keys[3 + gi]
            reps = []
            for r in range(g.repeats):
                rkey = jax.random.fold_in(gkey, r)
                sub = [
                    _init_block(jax.random.fold_in(rkey, j), cfg, spec)
                    for j, spec in enumerate(g.subpattern)
                ]
                reps.append(sub)
            params[f"group{gi}"] = (
                _stack(reps) if g.repeats > 1 else reps[0]
            )
        return params

    # -- forward (train / prefill) -----------------------------------------
    def forward(
        self,
        params: Dict[str, Any],
        batch: Dict[str, Any],
        last_token_only: bool = False,
    ) -> jnp.ndarray:
        cfg = self.cfg
        if cfg.family == "vlm" and "embeds" in batch:
            x = batch["embeds"].astype(L.cdt(cfg))
            B, S, _ = x.shape
            positions = batch.get("positions")
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        else:
            tokens = batch["tokens"]
            B, S = tokens.shape
            x = params["embed"].astype(L.cdt(cfg))[tokens]
            positions = batch.get("positions")
            if positions is None:
                positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))
        x = shard_activations(x, "bsd")

        for gi, g in enumerate(self.groups):
            gp = params[f"group{gi}"]
            if g.repeats == 1:
                for j, spec in enumerate(g.subpattern):
                    x = block_apply(cfg, spec, gp[j], x, positions)
            else:
                def body(carry, rep_params, _g=g):
                    h = carry
                    for j, spec in enumerate(_g.subpattern):
                        h = block_apply(cfg, spec, rep_params[j], h, positions)
                    return h, None

                if cfg.remat == "block":
                    body = jax.checkpoint(body)
                x, _ = lax.scan(body, x, gp)
        x = L.rms_norm(x, params["final_norm"])
        if last_token_only:  # prefill: only the last position feeds sampling
            x = x[:, -1:, :]
        head = (
            params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        )
        logits = x @ head.astype(x.dtype)
        if cfg.logits_fp32:
            logits = logits.astype(jnp.float32)
        return logits

    # -- decode -------------------------------------------------------------
    def init_cache(
        self, batch_size: int, max_seq: int, dtype: Optional[Any] = None
    ) -> Dict[str, Any]:
        cfg = self.cfg
        dt = dtype or L.cdt(cfg)

        def one(spec: LayerSpec) -> Dict[str, Any]:
            if spec[0] == "attn":
                return {
                    "k": jnp.zeros(
                        (batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dt
                    ),
                    "v": jnp.zeros(
                        (batch_size, max_seq, cfg.num_kv_heads, cfg.head_dim), dt
                    ),
                }
            conv_ch = cfg.ssm_d_inner + 2 * cfg.ssm_groups * cfg.ssm_state
            return {
                "h": jnp.zeros(
                    (batch_size, cfg.ssm_heads, cfg.ssm_state, cfg.ssm_head_dim),
                    jnp.float32,
                ),
                "conv": jnp.zeros((batch_size, 3, conv_ch), dt),
            }

        cache: Dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
        for gi, g in enumerate(self.groups):
            if g.repeats == 1:
                cache[f"group{gi}"] = [one(spec) for spec in g.subpattern]
            else:
                cache[f"group{gi}"] = jax.tree.map(
                    lambda x: jnp.broadcast_to(x, (g.repeats,) + x.shape).copy()
                    if hasattr(x, "shape")
                    else x,
                    [one(spec) for spec in g.subpattern],
                )
        return cache

    def decode_step(
        self,
        params: Dict[str, Any],
        cache: Dict[str, Any],
        tokens: jnp.ndarray,  # (B,) int32 — the newest token per sequence
    ) -> Tuple[jnp.ndarray, Dict[str, Any]]:
        cfg = self.cfg
        pos = cache["pos"]
        x = params["embed"].astype(L.cdt(cfg))[tokens][:, None, :]  # (B,1,d)
        new_cache: Dict[str, Any] = {"pos": pos + 1}
        for gi, g in enumerate(self.groups):
            gp, gc = params[f"group{gi}"], cache[f"group{gi}"]
            if g.repeats == 1:
                new_list = []
                for j, spec in enumerate(g.subpattern):
                    x, c_new = block_decode(cfg, spec, gp[j], gc[j], x, pos)
                    new_list.append(c_new)
                new_cache[f"group{gi}"] = new_list
            else:
                def body(carry, pc, _g=g):
                    h = carry
                    rep_params, rep_cache = pc
                    outs = []
                    for j, spec in enumerate(_g.subpattern):
                        h, c_new = block_decode(
                            cfg, spec, rep_params[j], rep_cache[j], h, pos
                        )
                        outs.append(c_new)
                    return h, outs

                x, updated = lax.scan(body, x, (gp, gc))
                new_cache[f"group{gi}"] = updated
        x = L.rms_norm(x, params["final_norm"])
        head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
        logits = (x @ head.astype(x.dtype))[:, 0]
        return logits.astype(jnp.float32), new_cache
