"""Pure-JAX model layers shared by all architecture families.

Conventions:
  * params are (nested) dicts of jnp arrays; apply fns are pure.
  * compute dtype = cfg.dtype (bf16 on TPU); accumulations in f32.
  * attention's XLA path is a flash-equivalent chunked implementation
    (lax.scan over KV chunks with an online-softmax carry) — same math as
    kernels/flash_attention, memory-bounded for 32k+ contexts.  The Pallas
    path (cfg.attn_impl = "pallas*") swaps in the TPU kernel.
  * MoE uses gshard-style token-choice top-k with capacity dispatch
    (cumsum position-in-expert + scatter), expert-parallel over the model
    mesh axis.
  * mamba2 uses the SSD chunked formulation (matmul-rich => MXU-friendly).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..dist.context import shard_activations
from .config import ModelConfig

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float16": jnp.float16}


def cdt(cfg: ModelConfig):
    return DTYPES[cfg.dtype]


def pdt(cfg: ModelConfig):
    return DTYPES[cfg.param_dtype]


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, w: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * lax.rsqrt(var + eps)) * w.astype(jnp.float32)).astype(dt)


# ---------------------------------------------------------------------------
# Rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray,  # (B, S, H, D)
    positions: jnp.ndarray,  # (B, S) int32  or (B, S, 3) for M-RoPE
    theta: float,
    mrope: bool = False,
) -> jnp.ndarray:
    D = x.shape[-1]
    freqs = rope_freqs(D, theta)  # (D/2,)
    if mrope and positions.ndim == 3:
        # M-RoPE (qwen2-vl): split rotary channels into 3 sections driven by
        # (temporal, height, width) position streams.
        sec = D // 2 // 3
        sizes = [sec, sec, D // 2 - 2 * sec]
        angle_parts = []
        off = 0
        for i, sz in enumerate(sizes):
            f = freqs[off : off + sz]
            angle_parts.append(
                positions[..., i].astype(jnp.float32)[:, :, None] * f[None, None, :]
            )
            off += sz
        angles = jnp.concatenate(angle_parts, axis=-1)  # (B, S, D/2)
    else:
        angles = positions.astype(jnp.float32)[:, :, None] * freqs[None, None, :]
    cos = jnp.cos(angles)[:, :, None, :]  # (B, S, 1, D/2)
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — flash-equivalent chunked XLA implementation
# ---------------------------------------------------------------------------
def _attn_chunked(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    q_offset: jnp.ndarray,  # scalar: absolute position of q[0] (causal masking)
    causal: bool,
    window: int,
    chunk: int,
    softcap: float = 0.0,
) -> jnp.ndarray:
    """Online-softmax attention, scanning KV in chunks of ``chunk``.

    Identical math to flash attention; O(Sq * chunk) live memory for scores.
    GQA: q heads grouped over kv heads.
    """
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    # keep matmul inputs in the compute dtype (bf16 on MXU), accumulate f32
    qf = ((q.astype(jnp.float32) * scale).astype(q.dtype)).reshape(
        B, Sq, Hkv, G, D
    )

    nchunks = -(-Sk // chunk)
    pad = nchunks * chunk - Sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kc = k.reshape(B, nchunks, chunk, Hkv, D)
    vc = v.reshape(B, nchunks, chunk, Hkv, D)

    q_pos = q_offset + jnp.arange(Sq)  # (Sq,)

    def body(carry, inp):
        m, l, acc = carry  # (B,Sq,Hkv,G) , (B,Sq,Hkv,G), (B,Sq,Hkv,G,D)
        kci, vci, cidx = inp
        kv_pos = cidx * chunk + jnp.arange(chunk)  # (chunk,)
        s = jnp.einsum(
            "bqhgd,bkhd->bqhgk", qf, kci, preferred_element_type=jnp.float32
        )
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)
        mask = kv_pos[None, :] < Sk - pad + jnp.zeros((Sq, 1), jnp.int32)  # valid
        if causal:
            mask &= kv_pos[None, :] <= q_pos[:, None]
        if window > 0:
            mask &= kv_pos[None, :] > q_pos[:, None] - window
        s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
        m_new = jnp.maximum(m, s.max(axis=-1))
        # guard -inf rows (fully masked chunk): exp(-inf - -inf) -> use safe m
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask[None, :, None, None, :], p, 0.0)
        alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        acc_new = acc * alpha[..., None] + jnp.einsum(
            "bqhgk,bkhd->bqhgd", p.astype(vci.dtype), vci,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, Sq, Hkv, G), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, Sq, Hkv, G), jnp.float32)
    a0 = jnp.zeros((B, Sq, Hkv, G, D), jnp.float32)
    (m, l, acc), _ = lax.scan(
        body,
        (m0, l0, a0),
        (kc.swapaxes(0, 1), vc.swapaxes(0, 1), jnp.arange(nchunks)),
    )
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)


def attention(
    params: Dict[str, jnp.ndarray],
    x: jnp.ndarray,  # (B, S, d)
    cfg: ModelConfig,
    positions: jnp.ndarray,
    causal: bool = True,
    kv_x: Optional[jnp.ndarray] = None,  # cross-attention source
    use_rope: bool = True,
) -> jnp.ndarray:
    B, S, d = x.shape
    src = x if kv_x is None else kv_x
    q = (x @ params["wq"].astype(x.dtype)).reshape(B, S, cfg.num_heads, cfg.head_dim)
    k = (src @ params["wk"].astype(x.dtype)).reshape(
        B, src.shape[1], cfg.num_kv_heads, cfg.head_dim
    )
    v = (src @ params["wv"].astype(x.dtype)).reshape(
        B, src.shape[1], cfg.num_kv_heads, cfg.head_dim
    )
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    if use_rope and kv_x is None:
        q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope)
        k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope)
    if cfg.attn_impl.startswith("pallas"):
        from ..kernels.flash_attention.ops import flash_attention as _fa

        out = _fa(
            q, k, v,
            causal=causal and kv_x is None,
            window=cfg.attn_window,
            interpret=cfg.attn_impl == "pallas_interpret",
        )
    else:
        out = _attn_chunked(
            q, k, v,
            q_offset=jnp.asarray(0, jnp.int32),
            causal=causal and kv_x is None,
            window=cfg.attn_window,
            chunk=min(cfg.attn_chunk, src.shape[1]),
            softcap=cfg.attn_logit_softcap,
        )
    return out.reshape(B, S, cfg.q_dim) @ params["wo"].astype(x.dtype)


def attention_decode(
    params: Dict[str, jnp.ndarray],
    x_t: jnp.ndarray,  # (B, 1, d)
    cache: Dict[str, jnp.ndarray],  # {"k","v"}: (B, Smax, Hkv, D)
    pos: jnp.ndarray,  # scalar int32: current length
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """One-token decode against a KV cache (in-place update at ``pos``)."""
    B = x_t.shape[0]
    q = (x_t @ params["wq"].astype(x_t.dtype)).reshape(B, 1, cfg.num_heads, cfg.head_dim)
    k = (x_t @ params["wk"].astype(x_t.dtype)).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    v = (x_t @ params["wv"].astype(x_t.dtype)).reshape(B, 1, cfg.num_kv_heads, cfg.head_dim)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"])
        k = rms_norm(k, params["k_norm"])
    posb = jnp.broadcast_to(pos[None], (B, 1))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))

    Hkv, G = cfg.num_kv_heads, cfg.num_heads // cfg.num_kv_heads
    scale = 1.0 / math.sqrt(cfg.head_dim)
    # dots run on the cache's native dtype (bf16 on MXU) with f32 accumulate —
    # converting the cache to f32 would materialize + transpose the whole
    # cache every token (measured 17 GB/token/device on whisper decode_32k).
    qf = ((q.astype(jnp.float32) * scale).astype(k_cache.dtype)).reshape(
        B, Hkv, G, cfg.head_dim
    )
    s = jnp.einsum(
        "bhgd,bkhd->bhgk", qf, k_cache, preferred_element_type=jnp.float32
    )
    kv_pos = jnp.arange(k_cache.shape[1])
    mask = kv_pos <= pos  # (Smax,)
    if cfg.attn_window > 0:
        mask &= kv_pos > pos - cfg.attn_window
    s = jnp.where(mask[None, None, None, :], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum(
        "bhgk,bkhd->bhgd", p.astype(v_cache.dtype), v_cache,
        preferred_element_type=jnp.float32,
    ).astype(x_t.dtype)
    out = out.reshape(B, 1, cfg.q_dim) @ params["wo"].astype(x_t.dtype)
    return out, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# FFNs
# ---------------------------------------------------------------------------
def mlp(params: Dict[str, jnp.ndarray], x: jnp.ndarray, act: str) -> jnp.ndarray:
    if act == "swiglu":
        h = jax.nn.silu(x @ params["w1"].astype(x.dtype)) * (
            x @ params["w3"].astype(x.dtype)
        )
    else:
        h = jax.nn.gelu(x @ params["w1"].astype(x.dtype))
    return h @ params["w2"].astype(x.dtype)


def moe_ffn(
    params: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig,
    dropless: bool = False,
) -> jnp.ndarray:
    """Token-choice top-k MoE, GShard-style 2D grouped-capacity dispatch.

    x: (T, d) flattened tokens (caller reshapes).  Tokens are split into
    ``cfg.moe_groups`` groups (G aligned with the data-parallel shards) and
    each group gets its own capacity C — the dispatch scatter then stays
    LOCAL to a dp shard and the buffer shards as (G→data, E→model).  A
    single global group (G=1) makes the scatter span shards: SPMD either
    replicates the buffer per model shard (16x redundant expert FLOPs) or
    all-reduces full-buffer updates — both measured, both bad
    (EXPERIMENTS.md §Perf, kimi-k2 prefill iterations 2-4).

    ``dropless=True`` sets capacity C = T so no (token, choice) is ever
    dropped — the serving path uses this (decode batches are small, and
    dropping tokens at inference silently corrupts outputs).
    """
    T, d = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    G = max(1, cfg.moe_groups if T % max(1, cfg.moe_groups) == 0 else 1)
    t = T // G  # tokens per group
    if dropless:
        C = t
    else:
        C = max(1, int(math.ceil(t * k / E * cfg.capacity_factor)))
        C = min(C, t)

    xg = shard_activations(x.reshape(G, t, d), "gtd")
    logits = (xg @ params["router"].astype(x.dtype)).astype(jnp.float32)  # (G,t,E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_ids = lax.top_k(probs, k)  # (G, t, k)
    gate = (gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)).astype(x.dtype)

    # slot index within the (group, expert) queue: exclusive cumsum over the
    # group's flattened token-major (t·k) choice list
    onehot = jax.nn.one_hot(expert_ids, E, dtype=jnp.int32)  # (G, t, k, E)
    flat = onehot.reshape(G, t * k, E)
    pos = jnp.cumsum(flat, axis=1) - flat
    pos = (pos * flat).sum(-1).reshape(G, t, k)  # (G, t, k)
    keep = pos < C  # capacity-dropped tokens fall back to residual only

    # dispatch: per-group scatter into (G, E, C, d) buffers — index arrays
    # carry the group id so the batched scatter never crosses groups
    safe_pos = jnp.where(keep, pos, C - 1)
    gid = jnp.broadcast_to(jnp.arange(G)[:, None, None], (G, t, k))
    buf = jnp.zeros((G, E, C, d), x.dtype)
    tok = jnp.broadcast_to(xg[:, :, None, :], (G, t, k, d))
    buf = buf.at[gid, expert_ids, safe_pos].add(
        jnp.where(keep[..., None], tok, 0), mode="drop"
    )
    buf = shard_activations(buf, "gecd")

    # expert FFN on (G, E, C, d)
    if cfg.mlp_act == "swiglu":
        h = jax.nn.silu(
            jnp.einsum("gecd,edf->gecf", buf, params["w1"].astype(x.dtype))
        ) * jnp.einsum("gecd,edf->gecf", buf, params["w3"].astype(x.dtype))
    else:
        h = jax.nn.gelu(
            jnp.einsum("gecd,edf->gecf", buf, params["w1"].astype(x.dtype))
        )
    out_buf = shard_activations(
        jnp.einsum("gecf,efd->gecd", h, params["w2"].astype(x.dtype)), "gecd"
    )

    # combine: gather each token's expert outputs, weight by (renormalized) gate
    gathered = out_buf[gid, expert_ids, safe_pos]  # (G, t, k, d)
    out = (gathered * (gate * keep)[..., None]).sum(axis=2)  # (G, t, d)
    return out.reshape(T, d)


# ---------------------------------------------------------------------------
# mamba2 (SSD) — chunked matmul formulation
# ---------------------------------------------------------------------------
def _segsum(x: jnp.ndarray) -> jnp.ndarray:
    """Lower-triangular pairwise segment sums: out[..., i, j] = sum_{j<t<=i} x[t]."""
    Q = x.shape[-1]
    cs = jnp.cumsum(x, axis=-1)
    seg = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((Q, Q), bool), 0)
    return jnp.where(mask, seg, -jnp.inf)


def _depthwise_causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """x: (B, L, Ch), w: (K, Ch) depthwise causal conv."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    for i in range(K):  # K=4: unrolled, fuses into a few adds
        out = out + xp[:, i : i + x.shape[1], :] * w[i][None, None, :]
    return out + b[None, None, :]


def mamba2_mixer(
    params: Dict[str, jnp.ndarray], x: jnp.ndarray, cfg: ModelConfig
) -> jnp.ndarray:
    """SSD forward over a full sequence (training/prefill).

    x: (B, L, d).  Chunked: intra-chunk attention-like matmuls + inter-chunk
    state recurrence (lax.scan over chunks).
    """
    B, L, d = x.shape
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.ssm_d_inner
    Q = min(cfg.ssm_chunk, L)
    nc = -(-L // Q)
    pad = nc * Q - L

    zxbcdt = x @ params["in_proj"].astype(x.dtype)  # (B,L, 2di+2GN+H)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)
    xbc = jax.nn.silu(
        _depthwise_causal_conv(xbc, params["conv_w"], params["conv_b"])
    )
    xs, Bc, Cc = jnp.split(xbc, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    a = -jnp.exp(params["A_log"].astype(jnp.float32))  # (H,)

    if pad:
        xs = jnp.pad(xs, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
    Lp = nc * Q

    xh = xs.reshape(B, nc, Q, H, P).astype(jnp.float32)
    Bh = Bc.reshape(B, nc, Q, G, N).astype(jnp.float32)
    Ch = Cc.reshape(B, nc, Q, G, N).astype(jnp.float32)
    dth = dt.reshape(B, nc, Q, H)
    rep = H // G
    Bh = jnp.repeat(Bh, rep, axis=3)  # (B,nc,Q,H,N)
    Ch = jnp.repeat(Ch, rep, axis=3)

    da = dth * a[None, None, None, :]  # (B,nc,Q,H) log-decay per step
    da_cum = jnp.cumsum(da, axis=2)  # inclusive
    # intra-chunk (diagonal blocks): Y_d[i] = sum_{j<=i} C_i.B_j exp(sum da) dt_j x_j
    Lmat = jnp.exp(_segsum(da.transpose(0, 1, 3, 2)))  # (B,nc,H,Q,Q)
    CB = jnp.einsum("bcqhn,bckhn->bchqk", Ch, Bh, preferred_element_type=jnp.float32)
    Y_diag = jnp.einsum(
        "bchqk,bckh,bckhp->bcqhp", CB * Lmat, dth, xh,
        preferred_element_type=jnp.float32,
    )
    # chunk-final states: S_c = sum_j exp(da_cum[-1]-da_cum[j]) dt_j B_j x_j^T
    decay_states = jnp.exp(da_cum[:, :, -1:, :] - da_cum)  # (B,nc,Q,H)
    S = jnp.einsum(
        "bcqhn,bcqh,bcqh,bcqhp->bchnp", Bh, decay_states, dth, xh,
        preferred_element_type=jnp.float32,
    )
    # inter-chunk recurrence
    chunk_decay = jnp.exp(da_cum[:, :, -1, :])  # (B,nc,H)

    def scan_body(h, inp):
        S_c, dec = inp  # (B,H,N,P), (B,H)
        h_new = h * dec[..., None, None] + S_c
        return h_new, h  # emit state *entering* the chunk

    h0 = jnp.zeros((B, H, N, P), jnp.float32)
    _, h_prev = lax.scan(
        scan_body, h0, (S.swapaxes(0, 1), chunk_decay.swapaxes(0, 1))
    )
    h_prev = h_prev.swapaxes(0, 1)  # (B,nc,H,N,P): state at chunk start
    state_decay = jnp.exp(da_cum)  # (B,nc,Q,H)
    Y_off = jnp.einsum(
        "bcqhn,bchnp,bcqh->bcqhp", Ch, h_prev, state_decay,
        preferred_element_type=jnp.float32,
    )
    Y = (Y_diag + Y_off).reshape(B, Lp, H, P)[:, :L]
    Y = Y + xs.reshape(B, Lp, H, P)[:, :L] * params["D"].astype(jnp.float32)[None, None, :, None]
    Y = Y.reshape(B, L, di).astype(x.dtype)
    # gated RMSNorm (mamba2 block output norm)
    Y = rms_norm(Y * jax.nn.silu(z), params["norm_w"])
    return Y @ params["out_proj"].astype(x.dtype)


def mamba2_decode(
    params: Dict[str, jnp.ndarray],
    x_t: jnp.ndarray,  # (B, 1, d)
    state: Dict[str, jnp.ndarray],  # {"h": (B,H,N,P), "conv": (B,K-1,Ch)}
    cfg: ModelConfig,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    """Single-token SSD recurrence: h <- exp(dt a) h + dt B x ; y = C h + D x."""
    B = x_t.shape[0]
    H, P, N, G = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state, cfg.ssm_groups
    di = cfg.ssm_d_inner
    zxbcdt = (x_t @ params["in_proj"].astype(x_t.dtype))[:, 0]  # (B, ...)
    z, xs, Bc, Cc, dt = jnp.split(
        zxbcdt, [di, 2 * di, 2 * di + G * N, 2 * di + 2 * G * N], axis=-1
    )
    xbc = jnp.concatenate([xs, Bc, Cc], axis=-1)  # (B, Ch)
    conv = state["conv"]  # (B, K-1, Ch) last inputs
    K = params["conv_w"].shape[0]
    full = jnp.concatenate([conv, xbc[:, None, :]], axis=1)  # (B, K, Ch)
    conv_out = (full * params["conv_w"][None]).sum(1) + params["conv_b"]
    xbc = jax.nn.silu(conv_out)
    xs, Bc, Cc = jnp.split(xbc, [di, di + G * N], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))  # (B,H)
    a = -jnp.exp(params["A_log"].astype(jnp.float32))
    xh = xs.reshape(B, H, P).astype(jnp.float32)
    Bh = jnp.repeat(Bc.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    Ch_ = jnp.repeat(Cc.reshape(B, G, N), H // G, axis=1).astype(jnp.float32)
    decay = jnp.exp(dt * a[None, :])  # (B,H)
    h = state["h"] * decay[..., None, None] + jnp.einsum(
        "bhn,bh,bhp->bhnp", Bh, dt, xh
    )
    y = jnp.einsum("bhn,bhnp->bhp", Ch_, h) + xh * params["D"].astype(jnp.float32)[None, :, None]
    y = y.reshape(B, 1, di).astype(x_t.dtype)
    y = rms_norm(y * jax.nn.silu(z[:, None, :]), params["norm_w"])
    out = y @ params["out_proj"].astype(x_t.dtype)
    new_state = {"h": h, "conv": full[:, 1:]}
    return out, new_state


# ---------------------------------------------------------------------------
# Parameter initialization
# ---------------------------------------------------------------------------
def _init(key, shape, scale, dtype):
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attention(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    s = 1.0 / math.sqrt(d)
    p = {
        "wq": _init(ks[0], (d, qd), s, pdt(cfg)),
        "wk": _init(ks[1], (d, kvd), s, pdt(cfg)),
        "wv": _init(ks[2], (d, kvd), s, pdt(cfg)),
        "wo": _init(ks[3], (qd, d), 1.0 / math.sqrt(qd), pdt(cfg)),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((cfg.head_dim,), pdt(cfg))
        p["k_norm"] = jnp.ones((cfg.head_dim,), pdt(cfg))
    return p


def init_mlp(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 3)
    d, ff = cfg.d_model, cfg.d_ff
    p = {
        "w1": _init(ks[0], (d, ff), 1.0 / math.sqrt(d), pdt(cfg)),
        "w2": _init(ks[1], (ff, d), 1.0 / math.sqrt(ff), pdt(cfg)),
    }
    if cfg.mlp_act == "swiglu":
        p["w3"] = _init(ks[2], (d, ff), 1.0 / math.sqrt(d), pdt(cfg))
    return p


def init_moe(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 4)
    d, ff, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    p = {
        "router": _init(ks[0], (d, E), 1.0 / math.sqrt(d), pdt(cfg)),
        "w1": _init(ks[1], (E, d, ff), 1.0 / math.sqrt(d), pdt(cfg)),
        "w2": _init(ks[2], (E, ff, d), 1.0 / math.sqrt(ff), pdt(cfg)),
    }
    if cfg.mlp_act == "swiglu":
        p["w3"] = _init(ks[3], (E, d, ff), 1.0 / math.sqrt(d), pdt(cfg))
    return p


def init_mamba2(key, cfg: ModelConfig) -> Dict[str, jnp.ndarray]:
    ks = jax.random.split(key, 4)
    d, di, N, G, H = cfg.d_model, cfg.ssm_d_inner, cfg.ssm_state, cfg.ssm_groups, cfg.ssm_heads
    conv_ch = di + 2 * G * N
    return {
        "in_proj": _init(ks[0], (d, 2 * di + 2 * G * N + H), 1.0 / math.sqrt(d), pdt(cfg)),
        "conv_w": _init(ks[1], (4, conv_ch), 0.5, pdt(cfg)),
        "conv_b": jnp.zeros((conv_ch,), pdt(cfg)),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(pdt(cfg)),
        "D": jnp.ones((H,), pdt(cfg)),
        "dt_bias": jnp.zeros((H,), pdt(cfg)),
        "norm_w": jnp.ones((di,), pdt(cfg)),
        "out_proj": _init(ks[2], (di, d), 1.0 / math.sqrt(di), pdt(cfg)),
    }
