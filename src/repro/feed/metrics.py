"""Feed-side stall accounting.

The paper's diagnosis ("input-bound fraction", §2) is measured at the
CLIENT; this module measures one hop later, where it actually hurts: how
long the accelerator sat idle because the next batch was not already on
device.  The feeder splits every consumed step into three exclusive
buckets —

  fetch     time its transfer thread spent blocked on the host iterator
            (the data service could not keep up),
  transfer  time spent in ``jax.device_put`` / global-array assembly
            (host→device bandwidth),
  compute   time the consumer spent between ``next()`` calls (the train
            step itself),

— plus the headline number, ``idle_s``: wall time the consumer blocked in
``next()`` waiting for a device-resident batch.  ``idle_s`` is what the
double buffer exists to drive to zero; its per-step value and the
fetch/transfer split are also what the feeder reports upstream as the
autoscaler's client-latency signal (Cachew-style: scale the worker pool on
what the *consumer* observes, not on worker-local buffer occupancy).
"""
from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import Any, Dict, Optional

from ..obs.registry import MetricsRegistry


@dataclass
class FeedMetrics:
    """Cumulative counters for one ``DeviceFeeder`` session.

    Updated from two threads (transfer thread: ``fetch_s``/``transfer_s``/
    ``batches_fetched``/``bytes_to_device``; consumer thread: the rest), so
    mutation goes through the ``add_*`` helpers which hold ``_lock``.

    The dataclass fields stay the source of truth — ``StallWindow`` and the
    feeder tests read them directly under ``_lock`` — but every write is
    mirrored into ``registry`` (``feed_*`` families) so the feeder shows up
    in metrics dumps alongside the client/worker registries.
    """

    steps: int = 0  # batches handed to the consumer
    batches_fetched: int = 0  # batches pulled from the service
    idle_s: float = 0.0  # consumer blocked in next(): accelerator idle
    fetch_s: float = 0.0  # transfer thread blocked on the host iterator
    transfer_s: float = 0.0  # host->device placement time
    compute_s: float = 0.0  # consumer time between next() calls
    bytes_to_device: int = 0
    queue_depth_ema: float = 0.0  # device-queue fill observed at next()
    registry: Optional[MetricsRegistry] = field(default=None, repr=False, compare=False)
    _lock: threading.Lock = field(default_factory=threading.Lock, repr=False)

    def __post_init__(self) -> None:
        if self.registry is None:
            self.registry = MetricsRegistry()
        self._series = {
            "steps": self.registry.counter("feed_steps", "batches handed to the consumer"),
            "batches_fetched": self.registry.counter(
                "feed_batches_fetched", "batches pulled from the data service"
            ),
            "idle_s": self.registry.counter(
                "feed_idle_time", "consumer wall time blocked in next()"
            ),
            "fetch_s": self.registry.counter(
                "feed_fetch_time", "transfer thread blocked on the host iterator"
            ),
            "transfer_s": self.registry.counter(
                "feed_transfer_time", "host->device placement time"
            ),
            "compute_s": self.registry.counter(
                "feed_compute_time", "consumer time between next() calls"
            ),
            "bytes_to_device": self.registry.counter(
                "feed_bytes_to_device", "bytes placed on device"
            ),
            "queue_depth_ema": self.registry.gauge(
                "feed_queue_depth", "device-queue fill EMA observed at next()"
            ),
        }

    # -- writers (thread-safe) -------------------------------------------
    def add_fetch(self, seconds: float) -> None:
        with self._lock:
            self.fetch_s += seconds
            self.batches_fetched += 1
        self._series["fetch_s"].add(seconds)
        self._series["batches_fetched"].inc()

    def add_transfer(self, seconds: float, nbytes: int) -> None:
        with self._lock:
            self.transfer_s += seconds
            self.bytes_to_device += nbytes
        self._series["transfer_s"].add(seconds)
        self._series["bytes_to_device"].add(nbytes)

    def add_step(self, idle: float, compute: Optional[float], depth_frac: float) -> None:
        with self._lock:
            self.steps += 1
            self.idle_s += idle
            if compute is not None:
                self.compute_s += compute
            self.queue_depth_ema = 0.8 * self.queue_depth_ema + 0.2 * depth_frac
            depth_ema = self.queue_depth_ema
        self._series["steps"].inc()
        self._series["idle_s"].add(idle)
        if compute is not None:
            self._series["compute_s"].add(compute)
        self._series["queue_depth_ema"].set(depth_ema)

    # -- derived ----------------------------------------------------------
    @property
    def idle_s_per_step(self) -> float:
        return self.idle_s / self.steps if self.steps else 0.0

    @property
    def stall_fraction(self) -> float:
        """Fraction of the consumer's wall time spent waiting for data —
        the feed-side twin of the paper's input-bound fraction."""
        wall = self.idle_s + self.compute_s
        return self.idle_s / wall if wall > 0 else 0.0

    def breakdown(self) -> Dict[str, float]:
        """fetch / transfer / compute shares of total accounted time."""
        total = self.fetch_s + self.transfer_s + self.compute_s
        if total <= 0:
            return {"fetch": 0.0, "transfer": 0.0, "compute": 0.0}
        return {
            "fetch": self.fetch_s / total,
            "transfer": self.transfer_s / total,
            "compute": self.compute_s / total,
        }

    def summary(self) -> Dict[str, Any]:
        with self._lock:
            out = {
                "steps": self.steps,
                "batches_fetched": self.batches_fetched,
                "idle_s": self.idle_s,
                "idle_s_per_step": self.idle_s_per_step,
                "stall_frac": self.stall_fraction,
                "fetch_s": self.fetch_s,
                "transfer_s": self.transfer_s,
                "compute_s": self.compute_s,
                "bytes_to_device": self.bytes_to_device,
                "queue_depth_ema": self.queue_depth_ema,
            }
        out["breakdown"] = self.breakdown()
        return out


class StallWindow:
    """Rolling delta over ``FeedMetrics`` for periodic upstream reports.

    The autoscaler wants the *recent* stall fraction, not the session
    cumulative (a long healthy run would mask a fresh stall, and a slow
    warmup would read as a permanent one).  ``report()`` returns the stats
    for the window since the previous call, or ``None`` when no step
    completed in the window.
    """

    def __init__(self, metrics: FeedMetrics):
        self._m = metrics
        self._steps = 0
        self._idle = 0.0
        self._compute = 0.0
        self._fetch = 0.0
        self._transfer = 0.0

    def report(self) -> Optional[Dict[str, float]]:
        m = self._m
        with m._lock:
            d_steps = m.steps - self._steps
            if d_steps <= 0:
                return None
            d_idle = m.idle_s - self._idle
            d_compute = m.compute_s - self._compute
            d_fetch = m.fetch_s - self._fetch
            d_transfer = m.transfer_s - self._transfer
            depth = m.queue_depth_ema
            self._steps, self._idle = m.steps, m.idle_s
            self._compute, self._fetch = m.compute_s, m.fetch_s
            self._transfer = m.transfer_s
        wall = d_idle + d_compute
        return {
            "stall_frac": d_idle / wall if wall > 0 else 0.0,
            "idle_s_per_step": d_idle / d_steps,
            "fetch_s_per_step": d_fetch / d_steps,
            "transfer_s_per_step": d_transfer / d_steps,
            "queue_depth": depth,
            "steps": float(d_steps),
        }
