"""DeviceFeeder: the bridge between the data service and the jax mesh.

The service half of this repo ends at a host iterator (``DataServiceClient``
yields numpy batches); the model half starts at device-resident sharded
``jax.Array``s.  The seed training loops crossed that gap synchronously —
``next(it)`` then ``jnp.asarray`` on the step's critical path — which is
precisely the data-stall pattern software pipelining exists to hide
(tf.data's ``prefetch``-to-device, Murray et al. §3; Gong et al. measure
the host→device hop as a dominant end-to-end cost).  The feeder closes it:

1. **Per-host consumer registration.**  Each host of a multi-host jax
   deployment registers as a distinct consumer of ONE service job.  In
   ``static`` mode the feeder reuses the coordinated-reads consumer
   indexing (``num_consumers = num_hosts``, ``consumer_index = host``,
   ``core/protocol.py`` §3.6): every round, host h receives slot h of the
   round's window, so hosts consume disjoint, aligned per-host shards of
   the global batch without any cross-host coordination of their own.  In
   ``dynamic`` mode each host is an independent client of a DYNAMIC job —
   disjoint FCFS shards, no round alignment (fine for pure data
   parallelism over an OFF/DYNAMIC pipeline).

2. **Background fetch + transfer with a double-buffered device queue.**
   A transfer thread pulls host batches and immediately places them with
   ``jax.device_put`` onto the batch ``NamedSharding``s derived from
   ``repro.dist.sharding_rules`` (each host uploads only its addressable
   shards; multi-process meshes assemble global ``jax.Array``s via
   ``make_array_from_process_local_data`` — never a host gather).  Placed
   batches wait in a depth-``depth`` queue (default 2: classic double
   buffering), so fetch and host→device copy of batch N+1 overlap the
   train step on batch N.

3. **Feed-side stall metrics.**  ``FeedMetrics`` splits wall time into
   accelerator-idle / fetch / transfer / compute; a rolling window of the
   same numbers is pushed through the client's dispatcher heartbeat
   (``DataServiceClient.report_feed_stall``), where it becomes the
   autoscaler's Cachew-style client-latency scaling signal.
"""
from __future__ import annotations

import queue
import threading
import time
from typing import Any, Iterator, Optional

from .metrics import FeedMetrics, StallWindow
from .sharded import host_layout, infer_batch_shardings, leaf_nbytes, put_batch, resolve_shardings


class _FeedError:
    """Queued in place of a batch to surface a transfer-thread failure."""

    def __init__(self, error: BaseException):
        self.error = error


class DeviceFeeder:
    """Double-buffered device prefetch over a service-backed dataset.

    Parameters
    ----------
    dataset:
        A ``DistributedDataset`` (from ``Dataset.distribute(...)``), or a
        plain ``repro.data.Dataset`` together with ``service=``.
    service:
        Service handle / dispatcher address; only needed when ``dataset``
        is a raw ``Dataset``.
    mesh, plan:
        When given, per-leaf batch ``NamedSharding``s are derived once from
        the first batch via ``dist.sharding_rules.batch_sharding`` — the
        identical rule the train step's ``in_shardings`` use.
    shardings:
        Explicit override: a single ``Sharding`` for every leaf or a
        pytree matching the batch.  Wins over ``mesh``/``plan``.
    depth:
        Device-queue capacity (2 = double buffering).
    sharding_mode:
        ``"static"`` — per-host static sharding via coordinated-reads
        consumer indexing (forces ``processing_mode="off"``: round-robin
        windows are materialized whole on each worker).
        ``"dynamic"`` — each host is an independent client (DYNAMIC/OFF
        pipelines).  ``"auto"`` (default) — static iff ``num_hosts > 1``.
    host_index, num_hosts:
        Override the jax process layout (defaults: ``jax.process_index()``
        / ``jax.process_count()``).  Tests use these to emulate multiple
        hosts inside one process.
    report_interval_s:
        How often the rolling stall window is pushed to the service client
        for the autoscaler (0 disables reporting).
    """

    _END = object()

    def __init__(
        self,
        dataset: Any,
        *,
        service: Any = None,
        mesh: Any = None,
        plan: Any = None,
        shardings: Any = None,
        depth: int = 2,
        sharding_mode: str = "auto",
        host_index: Optional[int] = None,
        num_hosts: Optional[int] = None,
        report_interval_s: float = 1.0,
        **client_kw: Any,
    ):
        if sharding_mode not in ("auto", "static", "dynamic"):
            raise ValueError(f"unknown sharding_mode {sharding_mode!r}")
        if hasattr(dataset, "session"):  # DistributedDataset
            if client_kw:
                raise TypeError(
                    "client kwargs belong on Dataset.distribute(...) when "
                    "passing an already-distributed dataset"
                )
            self._dds = dataset
        else:  # raw Dataset: distribute it here
            if service is None:
                raise TypeError("service= is required for a raw Dataset")
            client_kw.setdefault("processing_mode", "dynamic")
            self._dds = dataset.distribute(service=service, **client_kw)

        default_index, default_count = host_layout()
        self._host_index = default_index if host_index is None else int(host_index)
        self._num_hosts = default_count if num_hosts is None else int(num_hosts)
        if sharding_mode == "auto":
            sharding_mode = "static" if self._num_hosts > 1 else "dynamic"
        self.sharding_mode = sharding_mode

        self._mesh, self._plan = mesh, plan
        self._explicit_shardings = shardings
        self._shardings: Any = None
        self._shardings_ready = False

        self.metrics = FeedMetrics()
        self._window = StallWindow(self.metrics)
        self._report_interval = report_interval_s
        self._last_report = time.perf_counter()

        self._queue: "queue.Queue[Any]" = queue.Queue(maxsize=max(1, depth))
        self._depth = max(1, depth)
        self._closed = threading.Event()
        self._last_return: Optional[float] = None
        self._client = self._make_session()
        self._thread = threading.Thread(
            target=self._run, name="device-feeder", daemon=True
        )
        self._thread.start()

    # ------------------------------------------------------------------
    # Session / registration
    # ------------------------------------------------------------------
    def _make_session(self) -> Any:
        """Register this host's consumer session per the sharding mode.

        The feeder opts into ``zero_copy=True``: with a co-located worker
        the shm ring's borrowed views feed ``jax.device_put`` directly —
        host batch bytes are copied exactly once, shm slot → device.  The
        lease contract (views valid until the next ``next(it)``) holds
        because ``_run`` places each batch on device before fetching the
        next one.  Remote workers are unaffected (tcp path decodes owned
        arrays).
        """
        overrides: dict = {"zero_copy": True}
        if self.sharding_mode == "static":
            # Coordinated-reads consumer indexing (§3.6): round r, slot
            # host_index — per-host static sharding of every round's window.
            overrides.update(
                processing_mode="off",
                num_consumers=self._num_hosts,
                consumer_index=self._host_index,
            )
        return self._dds.session(**overrides)

    # ------------------------------------------------------------------
    # Transfer thread
    # ------------------------------------------------------------------
    def _run(self) -> None:
        # The service client owns the job's trace context; the feeder's
        # spans (fetch / device_put) parent onto the same root so one
        # Perfetto track shows client->dispatcher->worker->feeder.
        tracer = getattr(self._client, "tracer", None)
        root = getattr(self._client, "trace_root", None)
        try:
            it = iter(self._client)
            while not self._closed.is_set():
                t0 = time.perf_counter()
                try:
                    batch = next(it)
                except StopIteration:
                    break
                dt = time.perf_counter() - t0
                self.metrics.add_fetch(dt)
                sampled = (
                    tracer is not None
                    and root is not None
                    and tracer.should_sample()
                )
                if sampled:
                    tracer.record(
                        "feed.fetch", root.child(), time.time() - dt, dt,
                        parent_id=root.span_id,
                    )
                t0 = time.perf_counter()
                placed = self._to_device(batch)
                dt = time.perf_counter() - t0
                nbytes = leaf_nbytes(batch)
                self.metrics.add_transfer(dt, nbytes)
                if sampled:
                    tracer.record(
                        "feed.device_put", root.child(), time.time() - dt, dt,
                        parent_id=root.span_id, nbytes=nbytes,
                    )
                if not self._put(placed):
                    return  # closed while the queue was full
                self._maybe_report()
        except Exception as e:  # surface to the consumer, don't die silently
            self._put(_FeedError(e))
        finally:
            self._put(self._END)
            self._report()

    def _to_device(self, batch: Any) -> Any:
        if not self._shardings_ready:
            if self._explicit_shardings is not None:
                self._shardings = resolve_shardings(batch, self._explicit_shardings)
            elif self._mesh is not None and self._plan is not None:
                self._shardings = infer_batch_shardings(batch, self._mesh, self._plan)
            self._shardings_ready = True
        return put_batch(batch, self._shardings)

    def _put(self, item: Any) -> bool:
        while not self._closed.is_set():
            try:
                self._queue.put(item, timeout=0.1)
                return True
            except queue.Full:
                continue
        return False

    # ------------------------------------------------------------------
    # Stall reporting (autoscaler client-latency signal)
    # ------------------------------------------------------------------
    def _maybe_report(self) -> None:
        if self._report_interval <= 0:
            return
        now = time.perf_counter()
        if now - self._last_report >= self._report_interval:
            self._last_report = now
            self._report()

    def _report(self) -> None:
        stats = self._window.report()
        if stats is None:
            return
        report = getattr(self._client, "report_feed_stall", None)
        if report is not None:
            report(stats)

    # ------------------------------------------------------------------
    # Consumption
    # ------------------------------------------------------------------
    def next(self, timeout: Optional[float] = None) -> Any:
        """Block until the next device-resident batch is ready.

        The blocked time IS the accelerator-idle metric: with the double
        buffer keeping up it is ~0; when it grows, the feed (service fetch
        or host→device transfer) is the bottleneck, and the reported stall
        window tells the autoscaler which.
        """
        t0 = time.perf_counter()
        compute = None if self._last_return is None else t0 - self._last_return
        deadline = None if timeout is None else t0 + timeout
        while True:
            if self._closed.is_set():
                raise StopIteration("feeder closed")
            try:
                item = self._queue.get(timeout=0.1)
                break
            except queue.Empty:
                if deadline is not None and time.perf_counter() > deadline:
                    raise TimeoutError(
                        f"no batch after {timeout:.1f}s (service stalled?)"
                    )
        now = time.perf_counter()
        if item is self._END:
            self._queue.put(self._END)  # idempotent end for later calls
            raise StopIteration
        if isinstance(item, _FeedError):
            raise RuntimeError("device feed failed") from item.error
        self.metrics.add_step(
            idle=now - t0,
            compute=compute,
            depth_frac=self._queue.qsize() / self._depth,
        )
        self._last_return = time.perf_counter()
        return item

    def __iter__(self) -> Iterator[Any]:
        while True:
            try:
                yield self.next()
            except StopIteration:
                return

    def __next__(self) -> Any:
        return self.next()

    # ------------------------------------------------------------------
    # Lifecycle
    # ------------------------------------------------------------------
    def close(self) -> None:
        """Stop the transfer thread and the service session.  Idempotent;
        safe mid-epoch — in-flight batches are dropped, the service job
        keeps running for other consumers."""
        if self._closed.is_set():
            return
        self._closed.set()
        self._client.close()
        self._thread.join(timeout=5.0)
        # unblock any consumer stuck in next()
        try:
            self._queue.put_nowait(self._END)
        except queue.Full:
            pass

    def __enter__(self) -> "DeviceFeeder":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()
