"""repro.feed — the accelerator-feed subsystem.

Bridges the data service (host-side numpy batches from
``DataServiceClient``) to the jax mesh (device-resident sharded
``jax.Array``s): per-host consumer registration, a background
fetch+transfer thread with a double-buffered device queue, and feed-side
stall metrics that double as the autoscaler's client-latency signal.

  * ``feeder``  — ``DeviceFeeder``, the user-facing pipeline stage.
  * ``metrics`` — ``FeedMetrics`` (idle / fetch / transfer / compute
                  accounting) and the rolling ``StallWindow`` reporter.
  * ``sharded`` — host→device placement: per-leaf batch ``NamedSharding``
                  derivation and addressable-shard-only uploads.
"""
from .feeder import DeviceFeeder
from .metrics import FeedMetrics, StallWindow
from .sharded import host_layout, infer_batch_shardings, put_batch

__all__ = [
    "DeviceFeeder",
    "FeedMetrics",
    "StallWindow",
    "host_layout",
    "infer_batch_shardings",
    "put_batch",
]
