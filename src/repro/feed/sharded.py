"""Host→device placement for service batches.

One host of a jax deployment only owns its *addressable* devices; a global
``jax.Array`` sharded over a multi-host mesh is assembled by every host
uploading exactly its local shards — there is never a host-side gather.
This module turns a service batch (a pytree of numpy arrays) into device
arrays under that contract:

* per-leaf ``NamedSharding``s come either from the caller or are derived
  once from a (mesh, ShardingPlan) pair via
  ``dist.sharding_rules.batch_sharding`` — the same rule the train step is
  jitted with, so the feeder's upload layout matches ``in_shardings`` and
  ``jax.jit`` never re-lays-out the batch;
* single-process meshes use ``jax.device_put(leaf, sharding)`` (XLA splits
  the host array across local devices);
* multi-process meshes use ``jax.make_array_from_process_local_data``:
  each host passes only ITS slice of the global batch (its per-host
  consumer slot, see ``feeder.DeviceFeeder``) and jax assembles the global
  array from the per-process shards.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import numpy as np


def host_layout() -> Tuple[int, int]:
    """(host_index, num_hosts) of this process in the jax deployment."""
    return jax.process_index(), jax.process_count()


def leaf_nbytes(tree: Any) -> int:
    return sum(
        int(getattr(leaf, "nbytes", 0)) for leaf in jax.tree_util.tree_leaves(tree)
    )


def infer_batch_shardings(batch: Any, mesh: Any, plan: Any) -> Any:
    """Per-leaf NamedShardings for a concrete batch: leading (batch) dim
    over the plan's data axes, everything else replicated — exactly what
    the jitted train step declares via ``sharding_rules.batch_sharding``.

    Derived from the batch's own shapes, so indivisible leading dims
    degrade to replication instead of failing the upload (same
    divisibility-gating contract as the parameter rules).
    """
    from ..dist.sharding_rules import batch_sharding

    return batch_sharding(mesh, plan, batch)


def resolve_shardings(batch: Any, shardings: Any) -> Any:
    """Normalize a shardings argument against a batch's tree structure.

    ``shardings`` may be a single ``Sharding`` (applied to every leaf) or a
    pytree matching the batch.  Returns a per-leaf tree, or ``None``.
    """
    if shardings is None:
        return None
    if isinstance(shardings, jax.sharding.Sharding):
        return jax.tree_util.tree_map(lambda _: shardings, batch)
    return shardings


def put_batch(batch: Any, shardings: Optional[Any]) -> Any:
    """Place one host batch onto devices.

    With no shardings: plain ``device_put`` to the default device (the
    single-accelerator case — still moves the copy off the training loop's
    critical path because the feeder calls this from its transfer thread).

    With shardings on a single-process mesh: ``device_put(leaf, s)``.

    With shardings on a multi-process mesh: the leaf this host holds is its
    LOCAL portion of the global batch; ``make_array_from_process_local_data``
    uploads the local shards and wires them into one global ``jax.Array``.
    """
    if shardings is None:
        return jax.tree_util.tree_map(jax.device_put, batch)
    multi_process = jax.process_count() > 1

    def one(leaf: Any, s: Any) -> Any:
        if s is None:
            return jax.device_put(leaf)
        if multi_process and isinstance(s, jax.sharding.NamedSharding):
            return jax.make_array_from_process_local_data(s, np.asarray(leaf))
        return jax.device_put(leaf, s)

    return jax.tree_util.tree_map(one, batch, shardings)
