"""Pass 1 — lock discipline (L001 unlocked write, L002 order cycle, L003
blocking call under lock).

Ground truth is inferred, not declared: for every class group (a class plus
its mixins/bases analyzed as one unit) that owns a ``threading.Lock /
RLock / Condition`` attribute, the set of attributes mutated under ``with
self.<lock>:`` defines the guarded set.  A later write to a guarded
attribute with no lock held is an L001.

Two refinements keep the false-positive rate workable:

* **Lock-held helpers.** The codebase's convention is a docstring marker —
  ``Caller holds ``self._lock``.`` — on internal helpers invoked from
  locked scopes.  The pass honors the marker, and additionally runs a
  fixed point: a method whose every intra-group call site is itself inside
  a locked scope (or inside another lock-held method) inherits the held
  set.  ``__init__`` is exempt (construction is single-threaded).
* **Condition waits.** ``self._cond.wait()`` while holding ``self._cond``
  releases the lock by contract and is not a blocking call under lock.
"""
from __future__ import annotations

import re
from typing import Dict, FrozenSet, List, Optional, Set, Tuple

from .findings import Finding
from .model import ClassInfo, FunctionInfo, Project

_HOLDS_RE = re.compile(r"callers?\s+(?:must\s+)?hold", re.IGNORECASE)

# Callee-name predicates for "this call can block" (L003).
_BLOCKING_EXACT = {
    "time.sleep", "os.fsync", "os.replace", "shutil.rmtree",
    "socket.create_connection", "open",
}
_BLOCKING_SUFFIX = (".sendall", ".recv", ".accept", ".connect", ".fsync")


def _held_locks(with_items: Tuple[str, ...], group_locks: Set[str]) -> FrozenSet[str]:
    held = set()
    for item in with_items:
        parts = item.split(".")
        if len(parts) == 2 and parts[0] == "self" and parts[1] in group_locks:
            held.add(parts[1])
    return frozenset(held)


def _annotated_locks(func: FunctionInfo, group_locks: Set[str]) -> FrozenSet[str]:
    doc = func.docstring
    if not doc or not _HOLDS_RE.search(doc):
        return frozenset()
    mentioned = {a for a in group_locks if f"self.{a}" in doc}
    if mentioned:
        return frozenset(mentioned)
    if len(group_locks) == 1:
        return frozenset(group_locks)
    return frozenset()


class GroupAnalysis:
    """Resolved lock facts for one class group."""

    def __init__(self, project: Project, group: List[ClassInfo]):
        self.project = project
        self.group = group
        self.locks: Set[str] = set()
        for c in group:
            self.locks.update(c.lock_attrs)
        self.lock_owner: Dict[str, str] = {}
        for c in sorted(group, key=lambda c: (c.module, c.line)):
            for a in c.lock_attrs:
                self.lock_owner.setdefault(a, c.name)
        # method name -> FunctionInfo list (mixins could collide; keep all)
        self.methods: Dict[str, List[FunctionInfo]] = {}
        self.functions: List[FunctionInfo] = []
        for c in group:
            for key, f in c.functions.items():
                self.functions.append(f)
                if not f.is_nested:
                    self.methods.setdefault(f.name, []).append(f)
        self.assumed = self._fixed_point()

    def _fixed_point(self) -> Dict[str, FrozenSet[str]]:
        """assumed[qualname] = locks a method may assume its caller holds."""
        # Intra-group call sites per callee method name.
        callsites: Dict[str, List] = {name: [] for name in self.methods}
        for f in self.functions:
            for c in f.calls:
                parts = c.name.split(".")
                if len(parts) == 2 and parts[0] == "self" and parts[1] in self.methods:
                    callsites[parts[1]].append(c)
        assumed: Dict[str, FrozenSet[str]] = {}
        annotated: Dict[str, FrozenSet[str]] = {}
        for name, funcs in self.methods.items():
            ann = frozenset().union(*(_annotated_locks(f, self.locks) for f in funcs))
            annotated[name] = ann
            if ann:
                assumed[name] = ann
            elif callsites[name]:
                assumed[name] = frozenset(self.locks)  # optimistic top; shrink below
            else:
                assumed[name] = frozenset()
        changed = True
        while changed:
            changed = False
            for name in self.methods:
                if annotated[name] or not callsites[name]:
                    continue
                meet: Optional[FrozenSet[str]] = None
                for site in callsites[name]:
                    caller = site.func
                    caller_assumed = (
                        assumed.get(caller.name, frozenset())
                        if caller.class_name and not caller.is_nested
                        else frozenset()
                    )
                    eff = _held_locks(site.with_items, self.locks) | caller_assumed
                    meet = eff if meet is None else (meet & eff)
                meet = meet or frozenset()
                if meet != assumed[name]:
                    assumed[name] = meet
                    changed = True
        return assumed

    def effective(self, func: FunctionInfo, with_items: Tuple[str, ...]) -> FrozenSet[str]:
        held = _held_locks(with_items, self.locks)
        if func.class_name and not func.is_nested:
            held |= self.assumed.get(func.name, frozenset())
        return held


def _check_unlocked_writes(ga: GroupAnalysis, findings: List[Finding]) -> None:
    if not ga.locks:
        return
    guarded: Dict[str, Set[str]] = {}
    for f in ga.functions:
        if f.name == "__init__":
            continue
        for w in f.writes:
            if w.root != "self" or w.attr.split(".")[0] in ga.locks:
                continue
            locks = ga.effective(f, w.with_items)
            if locks:
                guarded.setdefault(w.attr, set()).update(locks)
    for f in ga.functions:
        if f.name == "__init__":
            continue
        for w in f.writes:
            if w.root != "self" or w.attr not in guarded:
                continue
            locks = ga.effective(f, w.with_items)
            if locks & guarded[w.attr]:
                continue
            lock = sorted(guarded[w.attr])[0]
            owner = ga.lock_owner.get(lock, f.class_name or "?")
            findings.append(
                Finding(
                    file=f.module, line=w.line, code="L001",
                    message=(
                        f"unlocked write to '{w.attr}' "
                        f"(guarded by '{owner}.{lock}' elsewhere)"
                    ),
                )
            )


def _is_blocking(name: str, const_kwargs, with_items: Tuple[str, ...]) -> Optional[str]:
    if name in _BLOCKING_EXACT or name.endswith(_BLOCKING_SUFFIX):
        return name
    last = name.rsplit(".", 1)[-1]
    if last == "call" and "." in name:
        return name  # RPC stub call (Stub.call / conn.call)
    if last == "wait" and "." in name:
        receiver = name.rsplit(".", 1)[0]
        if receiver not in with_items:
            return name  # Event.wait etc.; cond.wait on a HELD cond releases it
        return None
    if last in ("append", "append_replica") and "journal" in name.lower():
        if const_kwargs.get("sync") is True:
            return f"{name}(sync=True)"  # fsync'd WAL append
    return None


def _check_blocking_under_lock(ga: GroupAnalysis, findings: List[Finding]) -> None:
    if not ga.locks:
        return
    for f in ga.functions:
        if f.name == "__init__":
            continue
        for c in f.calls:
            locks = ga.effective(f, c.with_items)
            if not locks:
                continue
            blocked = _is_blocking(c.name, c.const_kwargs, c.with_items)
            if blocked is None:
                continue
            lock = sorted(locks)[0]
            owner = ga.lock_owner.get(lock, f.class_name or "?")
            findings.append(
                Finding(
                    file=f.module, line=c.line, code="L003",
                    message=(
                        f"blocking call '{blocked}' while holding "
                        f"'{owner}.{lock}'"
                    ),
                )
            )


# -- L002: lock-order cycles -------------------------------------------------
def _resolve_lock_node(
    project: Project, ga: GroupAnalysis, func: FunctionInfo, item: str
) -> Optional[str]:
    """Map a with-item expression to a ``Class.lockattr`` node, or None."""
    parts = item.split(".")
    # one alias hop: ``mgr._lock`` with ``mgr = job.shard_mgr``
    if parts[0] != "self" and parts[0] in func.local_aliases:
        parts = func.local_aliases[parts[0]].split(".") + parts[1:]
    if len(parts) == 2 and parts[0] == "self" and parts[1] in ga.locks:
        return f"{ga.lock_owner[parts[1]]}.{parts[1]}"
    if len(parts) >= 2:
        lock_attr, holder_attr = parts[-1], parts[-2]
        for cls_name in sorted(project.attr_classes.get(holder_attr, ())):
            for c in project.all_classes():
                if c.name == cls_name and lock_attr in c.lock_attrs:
                    return f"{c.name}.{lock_attr}"
    return None


def _callee_lock_nodes(
    project: Project, call_name: str, func: FunctionInfo
) -> List[str]:
    """``self.<attr>.<meth>()`` -> lock nodes that callee is known to take."""
    parts = call_name.split(".")
    if parts[0] != "self" and parts[0] in func.local_aliases:
        parts = func.local_aliases[parts[0]].split(".") + parts[1:]
    if len(parts) != 3 or parts[0] != "self":
        return []
    holder_attr, meth = parts[1], parts[2]
    nodes: List[str] = []
    for cls_name in sorted(project.attr_classes.get(holder_attr, ())):
        for c in project.all_classes():
            if c.name != cls_name or meth not in c.functions:
                continue
            callee = c.functions[meth]
            for acq in callee.acquires:
                p = acq.item.split(".")
                if len(p) == 2 and p[0] == "self" and p[1] in c.lock_attrs:
                    nodes.append(f"{c.name}.{p[1]}")
    return nodes


def _check_lock_order(
    project: Project, analyses: List[GroupAnalysis], findings: List[Finding]
) -> None:
    edges: Dict[Tuple[str, str], Tuple[str, int]] = {}  # edge -> exemplar site

    def add_edge(a: str, b: str, module: str, line: int) -> None:
        if a != b:
            edges.setdefault((a, b), (module, line))

    for ga in analyses:
        for f in ga.functions:
            for acq in f.acquires:
                target = _resolve_lock_node(project, ga, f, acq.item)
                if target is None:
                    continue
                held_nodes = [
                    n for it in acq.held_before
                    if (n := _resolve_lock_node(project, ga, f, it))
                ]
                assumed = (
                    ga.assumed.get(f.name, frozenset())
                    if f.class_name and not f.is_nested else frozenset()
                )
                held_nodes += [f"{ga.lock_owner[a]}.{a}" for a in assumed]
                for h in held_nodes:
                    add_edge(h, target, f.module, acq.line)
            for c in f.calls:
                locks = ga.effective(f, c.with_items)
                if not locks:
                    continue
                for target in _callee_lock_nodes(project, c.name, f):
                    for a in locks:
                        add_edge(f"{ga.lock_owner[a]}.{a}", target, f.module, c.line)

    # cycle detection (iterative DFS, deterministic order)
    adj: Dict[str, List[str]] = {}
    for (a, b) in edges:
        adj.setdefault(a, []).append(b)
    for k in adj:
        adj[k].sort()
    WHITE, GRAY, BLACK = 0, 1, 2
    color: Dict[str, int] = {}
    stack_path: List[str] = []
    reported: Set[Tuple[str, ...]] = set()

    def dfs(node: str) -> None:
        color[node] = GRAY
        stack_path.append(node)
        for nxt in adj.get(node, ()):
            if color.get(nxt, WHITE) == WHITE:
                dfs(nxt)
            elif color.get(nxt) == GRAY:
                i = stack_path.index(nxt)
                cycle = tuple(stack_path[i:]) + (nxt,)
                canon = tuple(sorted(cycle[:-1]))
                if canon not in reported:
                    reported.add(canon)
                    module, line = edges[(node, nxt)]
                    findings.append(
                        Finding(
                            file=module, line=line, code="L002",
                            message="lock-order cycle: " + " -> ".join(cycle),
                        )
                    )
        stack_path.pop()
        color[node] = BLACK

    for node in sorted(adj):
        if color.get(node, WHITE) == WHITE:
            dfs(node)


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    analyses = [
        GroupAnalysis(project, group)
        for group in project.class_groups()
        if any(c.lock_attrs for c in group)
    ]
    for ga in analyses:
        _check_unlocked_writes(ga, findings)
        _check_blocking_under_lock(ga, findings)
    _check_lock_order(project, analyses, findings)
    return findings
