"""repro.analysis — repo-specific static analysis for the control plane.

Six AST passes over ``src/repro/`` (see the sibling modules for the rule
details):

1. ``locks``        — lock discipline: unlocked writes to guarded
                      attributes, lock-order cycles, blocking calls under
                      a lock (L001/L002/L003).
2. ``journal_pass`` — journal/replay conformance: every
                      ``_journal.append("etype")`` needs an ``apply_event``
                      branch and vice versa; journaled state must not be
                      mutated off the replay/append path (J001/J002/J003).
3. ``rpc_pass``     — RPC surface conformance: ``rpc_*`` handlers need a
                      ``protocol.py`` doc entry, a client stub call site,
                      and dict payloads (R001/R002/R003).
4. ``dist_pass``    — distributed blocking over the inter-process call
                      graph: RPC under a local lock, synchronous RPC
                      cycles across process roles, retry-critical RPCs
                      with no timeout/backoff (D001/D002/D003).
5. ``replay_pass``  — replay determinism: no clock reads, unseeded
                      randomness, set-iteration order, or unstable types
                      on the journal replay/append paths
                      (P001/P002/P003/P004).
6. ``thread_pass``  — thread lifecycle: threads neither daemon nor
                      joined, spawns inside rpc handlers without an owner
                      (T001/T002).

Passes 4-6 share the inter-process call-graph layer in ``model.py``
(:class:`~.model.RpcGraph`): stub ``.call("m")`` sites resolved to
``rpc_m`` handlers across ``core/client.py``, ``core/worker.py``,
``core/dispatcher/*``, ``core/service.py`` and ``core/replica.py``, each
end tagged with its process role.

Run it as ``python -m repro.analysis --strict`` (the CI gate): exit 1 on
any finding that is neither in ``analysis/baseline.txt`` nor suppressed
inline with ``# analysis: allow(CODE)`` — and on any *stale* baseline
entry (a line no current finding matches).  The dynamic chaos harness
(``tests/chaos.py``) samples the same invariants at runtime; this package
pins them at review time.
"""
from __future__ import annotations

import time as _time
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

from . import dist_pass, journal_pass, locks, replay_pass, rpc_pass, thread_pass
from .findings import (
    Finding,
    SuppressionIndex,
    load_baseline,
    split_new,
    stale_entries,
    write_baseline,
)
from .model import Project, build_project

__all__ = [
    "Finding",
    "analyze",
    "build_project",
    "default_root",
    "default_baseline",
    "run_analysis",
]

PASSES = (
    ("locks", locks.run),
    ("journal", journal_pass.run),
    ("rpc", rpc_pass.run),
    ("dist", dist_pass.run),
    ("replay", replay_pass.run),
    ("thread", thread_pass.run),
)


def default_root() -> Path:
    """The tree the analyzer self-hosts on: ``src/repro`` (this package's parent)."""
    return Path(__file__).resolve().parents[1]


def default_baseline() -> Path:
    return Path(__file__).resolve().parent / "baseline.txt"


def run_analysis(
    root: Path, timings: Optional[Dict[str, float]] = None
) -> List[Finding]:
    """All passes over ``root``; findings sorted by (file, line, code).

    With ``timings``, per-pass wall seconds are recorded into it under the
    pass name (plus ``"parse"`` for the shared model build) — the lint
    driver prints them so a slow pass is visible before it erodes the
    <10s CI budget.
    """
    t0 = _time.perf_counter()
    project = build_project(root)
    if timings is not None:
        timings["parse"] = _time.perf_counter() - t0
    findings: List[Finding] = []
    for name, p in PASSES:
        t0 = _time.perf_counter()
        findings.extend(p(project))
        if timings is not None:
            timings[name] = _time.perf_counter() - t0
    return sorted(set(findings), key=lambda f: (f.file, f.line, f.code, f.message))


def analyze(
    root: Optional[Path] = None, baseline_path: Optional[Path] = None
) -> Tuple[List[Finding], List[Finding]]:
    """Returns (new, accepted) findings after baseline + inline suppressions."""
    root = (root or default_root()).resolve()
    findings = run_analysis(root)
    files = sorted(root.rglob("*.py"))
    suppressions = SuppressionIndex.scan(root, files)
    baseline: Set[str] = load_baseline(baseline_path or default_baseline())
    return split_new(findings, baseline, suppressions)


def stale_baseline(
    root: Optional[Path] = None, baseline_path: Optional[Path] = None
) -> List[str]:
    """Baseline entries matching no current finding (see ``stale_entries``)."""
    root = (root or default_root()).resolve()
    findings = run_analysis(root)
    baseline = load_baseline(baseline_path or default_baseline())
    return stale_entries(baseline, findings)
