"""repro.analysis — repo-specific static analysis for the control plane.

Three AST passes over ``src/repro/`` (see the sibling modules for the rule
details):

1. ``locks``        — lock discipline: unlocked writes to guarded
                      attributes, lock-order cycles, blocking calls under
                      a lock (L001/L002/L003).
2. ``journal_pass`` — journal/replay conformance: every
                      ``_journal.append("etype")`` needs an ``apply_event``
                      branch and vice versa; journaled state must not be
                      mutated off the replay/append path (J001/J002/J003).
3. ``rpc_pass``     — RPC surface conformance: ``rpc_*`` handlers need a
                      ``protocol.py`` doc entry, a client stub call site,
                      and dict payloads (R001/R002/R003).

Run it as ``python -m repro.analysis --strict`` (the CI gate): exit 1 on
any finding that is neither in ``analysis/baseline.txt`` nor suppressed
inline with ``# analysis: allow(CODE)``.  The dynamic chaos harness
(``tests/chaos.py``) samples the same invariants at runtime; this package
pins them at review time.
"""
from __future__ import annotations

from pathlib import Path
from typing import List, Optional, Set, Tuple

from . import journal_pass, locks, rpc_pass
from .findings import (
    Finding,
    SuppressionIndex,
    load_baseline,
    split_new,
    write_baseline,
)
from .model import Project, build_project

__all__ = [
    "Finding",
    "analyze",
    "build_project",
    "default_root",
    "default_baseline",
    "run_analysis",
]

PASSES = (locks.run, journal_pass.run, rpc_pass.run)


def default_root() -> Path:
    """The tree the analyzer self-hosts on: ``src/repro`` (this package's parent)."""
    return Path(__file__).resolve().parents[1]


def default_baseline() -> Path:
    return Path(__file__).resolve().parent / "baseline.txt"


def run_analysis(root: Path) -> List[Finding]:
    """All passes over ``root``; findings sorted by (file, line, code)."""
    project = build_project(root)
    findings: List[Finding] = []
    for p in PASSES:
        findings.extend(p(project))
    return sorted(set(findings), key=lambda f: (f.file, f.line, f.code, f.message))


def analyze(
    root: Optional[Path] = None, baseline_path: Optional[Path] = None
) -> Tuple[List[Finding], List[Finding]]:
    """Returns (new, accepted) findings after baseline + inline suppressions."""
    root = (root or default_root()).resolve()
    findings = run_analysis(root)
    files = sorted(root.rglob("*.py"))
    suppressions = SuppressionIndex.scan(root, files)
    baseline: Set[str] = load_baseline(baseline_path or default_baseline())
    return split_new(findings, baseline, suppressions)
