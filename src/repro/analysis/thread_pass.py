"""Pass 6 — thread lifecycle (T001, T002).

The static counterpart of the test suite's ``threads_leaked`` conftest
fixture: background threads must either be ``daemon=True`` (the process
may exit under them) or be joined on some shutdown path — anything else
outlives its owner and leaks.

* **T001** — a ``threading.Thread(...)`` that is neither constructed with
  a literal ``daemon=True`` nor ``.join()``-ed anywhere reachable: stored
  on ``self``, the join may live in any method of the class group (the
  ``close``/``stop`` convention); a local thread must be joined in the
  same function.
* **T002** — a thread spawned inside an ``rpc_*`` handler (directly, or
  one ``self.*`` hop below one) without a registered owner: the thread is
  stored nowhere on ``self``, so no shutdown path can ever find it.
  Handlers run on transport server threads; a spawn per request with no
  registry is an unbounded leak under request load.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set

from .findings import Finding
from .model import ClassInfo, FunctionInfo, Project, ThreadCtor


def _group_call_names(group: List[ClassInfo]) -> Set[str]:
    names: Set[str] = set()
    for c in group:
        for f in c.functions.values():
            for site in f.calls:
                names.add(site.name)
    return names


def _rpc_reachable_methods(group: List[ClassInfo]) -> Set[str]:
    """Method names that are rpc_* handlers or called directly by one."""
    out: Set[str] = set()
    for c in group:
        for f in c.functions.values():
            if f.is_nested or not f.name.startswith("rpc_"):
                continue
            out.add(f.name)
            for site in f.calls:
                parts = site.name.split(".")
                if len(parts) == 2 and parts[0] == "self":
                    out.add(parts[1])
    return out


def _joined(ctor: ThreadCtor, func: FunctionInfo, group_calls: Set[str]) -> bool:
    if ctor.target is None:
        return False
    if ctor.target.startswith("self."):
        return f"{ctor.target}.join" in group_calls
    # local thread: joined in the same function
    return any(c.name == f"{ctor.target}.join" for c in func.calls)


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for group in project.class_groups():
        group_calls = _group_call_names(group)
        rpc_methods = _rpc_reachable_methods(group)
        for c in group:
            for f in c.functions.values():
                for ctor in f.thread_ctors:
                    _check_ctor(f, ctor, group_calls, rpc_methods, findings)
                # unassigned inline spawns: Thread(...).start() — the
                # ctor never hit an Assign, so synthesize an anonymous one
                ctor_lines = {t.line for t in f.thread_ctors}
                for site in f.calls:
                    if (
                        site.name.rsplit(".", 1)[-1] == "Thread"
                        and site.line not in ctor_lines
                    ):
                        anon = ThreadCtor(
                            target=None, line=site.line,
                            daemon=site.const_kwargs.get("daemon"), func=f,
                        )
                        _check_ctor(f, anon, group_calls, rpc_methods, findings)
    # module-level functions (no class group) get the same local checks
    for mod in project.modules.values():
        for f in mod.functions.values():
            for ctor in f.thread_ctors:
                _check_ctor(f, ctor, set(), set(), findings)
            ctor_lines = {t.line for t in f.thread_ctors}
            for site in f.calls:
                if (
                    site.name.rsplit(".", 1)[-1] == "Thread"
                    and site.line not in ctor_lines
                ):
                    anon = ThreadCtor(
                        target=None, line=site.line,
                        daemon=site.const_kwargs.get("daemon"), func=f,
                    )
                    _check_ctor(f, anon, set(), set(), findings)
    return findings


def _check_ctor(
    f: FunctionInfo,
    ctor: ThreadCtor,
    group_calls: Set[str],
    rpc_methods: Set[str],
    findings: List[Finding],
) -> None:
    label = ctor.target or "<anonymous>"
    if ctor.daemon is not True and not _joined(ctor, f, group_calls):
        findings.append(
            Finding(
                file=f.module, line=ctor.line, code="T001",
                message=(
                    f"thread '{label}' in '{f.name}' is neither daemon=True "
                    "nor joined on any shutdown path (leaks past its owner)"
                ),
            )
        )
    if f.name in rpc_methods and not (
        ctor.target and ctor.target.startswith("self.")
    ):
        findings.append(
            Finding(
                file=f.module, line=ctor.line, code="T002",
                message=(
                    f"thread '{label}' spawned in rpc handler path "
                    f"'{f.name}' with no registered owner (unbounded leak "
                    "under request load)"
                ),
            )
        )
