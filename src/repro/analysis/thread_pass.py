"""Pass 6 — thread & process lifecycle (T001–T004).

The static counterpart of the test suite's ``threads_leaked`` conftest
fixture: background threads must either be ``daemon=True`` (the process
may exit under them) or be joined on some shutdown path — anything else
outlives its owner and leaks.  The same discipline extends to the
process-pool data plane: ``multiprocessing.Process`` children and
``SharedMemory`` segments survive their creator, so the leak is a whole
process (or a ``/dev/shm`` file that persists past interpreter exit)
rather than a thread.

* **T001** — a ``threading.Thread(...)`` that is neither constructed with
  a literal ``daemon=True`` nor ``.join()``-ed anywhere reachable: stored
  on ``self``, the join may live in any method of the class group (the
  ``close``/``stop`` convention); a local thread must be joined in the
  same function.
* **T002** — a thread spawned inside an ``rpc_*`` handler (directly, or
  one ``self.*`` hop below one) without a registered owner: the thread is
  stored nowhere on ``self``, so no shutdown path can ever find it.
  Handlers run on transport server threads; a spawn per request with no
  registry is an unbounded leak under request load.
* **T003** — the T001 analogue for ``multiprocessing.Process``: a child
  that is neither ``daemon=True`` nor joined on any reachable shutdown
  path.  A leaked non-daemon child blocks ``multiprocessing``'s atexit
  join forever — the parent process simply never exits.
* **T004** — a ``SharedMemory(..., create=True)`` with no ``unlink`` on
  any reachable path.  Unlike mappings, the *name* persists in
  ``/dev/shm`` past process exit; creating segments without a matching
  unlink path leaks host memory across runs.  Stored on ``self``, the
  unlink may live anywhere in the class group; a local handle commonly
  escapes the creating function (returned, wrapped in an owner object),
  so any ``*.unlink`` call in the group counts.
"""
from __future__ import annotations

from typing import List, Set

from .findings import Finding
from .model import ClassInfo, FunctionInfo, Project, ThreadCtor

# Call-site last segments that synthesize an anonymous ctor when the
# construction never hit an Assign (``Thread(...).start()``).
_INLINE_KINDS = {"Thread": "thread", "Process": "process"}


def _group_call_names(group: List[ClassInfo]) -> Set[str]:
    names: Set[str] = set()
    for c in group:
        for f in c.functions.values():
            for site in f.calls:
                names.add(site.name)
    return names


def _rpc_reachable_methods(group: List[ClassInfo]) -> Set[str]:
    """Method names that are rpc_* handlers or called directly by one."""
    out: Set[str] = set()
    for c in group:
        for f in c.functions.values():
            if f.is_nested or not f.name.startswith("rpc_"):
                continue
            out.add(f.name)
            for site in f.calls:
                parts = site.name.split(".")
                if len(parts) == 2 and parts[0] == "self":
                    out.add(parts[1])
    return out


def _joined(ctor: ThreadCtor, func: FunctionInfo, group_calls: Set[str]) -> bool:
    if ctor.target is None:
        return False
    if ctor.target.startswith("self."):
        return f"{ctor.target}.join" in group_calls
    # local thread/process: joined in the same function
    return any(c.name == f"{ctor.target}.join" for c in func.calls)


def _unlinked(ctor: ThreadCtor, func: FunctionInfo, group_calls: Set[str]) -> bool:
    if ctor.target and ctor.target.startswith("self."):
        return f"{ctor.target}.unlink" in group_calls
    if ctor.target and any(
        c.name == f"{ctor.target}.unlink" for c in func.calls
    ):
        return True
    # a local handle usually escapes its creating function (returned or
    # wrapped in the owning object): any unlink in the class group counts
    return any(n.rsplit(".", 1)[-1] == "unlink" for n in group_calls)


def _inline_spawns(f: FunctionInfo) -> List[ThreadCtor]:
    """Unassigned inline spawns: ``Thread(...).start()`` / ``Process(...)``
    — the ctor never hit an Assign, so synthesize an anonymous one."""
    ctor_lines = {t.line for t in f.thread_ctors}
    out: List[ThreadCtor] = []
    for site in f.calls:
        kind = _INLINE_KINDS.get(site.name.rsplit(".", 1)[-1])
        if kind is not None and site.line not in ctor_lines:
            out.append(
                ThreadCtor(
                    target=None, line=site.line,
                    daemon=site.const_kwargs.get("daemon"), func=f, kind=kind,
                )
            )
    return out


def _inline_shm(f: FunctionInfo) -> List[ThreadCtor]:
    ctor_lines = {t.line for t in f.shm_ctors}
    out: List[ThreadCtor] = []
    for site in f.calls:
        if (
            site.name.rsplit(".", 1)[-1] == "SharedMemory"
            and site.const_kwargs.get("create") is True
            and site.line not in ctor_lines
        ):
            out.append(
                ThreadCtor(target=None, line=site.line, daemon=None, func=f,
                           kind="shm")
            )
    return out


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    for group in project.class_groups():
        group_calls = _group_call_names(group)
        rpc_methods = _rpc_reachable_methods(group)
        for c in group:
            for f in c.functions.values():
                for ctor in f.thread_ctors + _inline_spawns(f):
                    _check_ctor(f, ctor, group_calls, rpc_methods, findings)
                for ctor in f.shm_ctors + _inline_shm(f):
                    _check_shm(f, ctor, group_calls, findings)
    # module-level functions (no class group) get the same local checks
    for mod in project.modules.values():
        for f in mod.functions.values():
            for ctor in f.thread_ctors + _inline_spawns(f):
                _check_ctor(f, ctor, set(), set(), findings)
            for ctor in f.shm_ctors + _inline_shm(f):
                _check_shm(f, ctor, set(), findings)
    return findings


def _check_ctor(
    f: FunctionInfo,
    ctor: ThreadCtor,
    group_calls: Set[str],
    rpc_methods: Set[str],
    findings: List[Finding],
) -> None:
    label = ctor.target or "<anonymous>"
    if ctor.daemon is not True and not _joined(ctor, f, group_calls):
        if ctor.kind == "process":
            findings.append(
                Finding(
                    file=f.module, line=ctor.line, code="T003",
                    message=(
                        f"child process '{label}' in '{f.name}' is neither "
                        "daemon=True nor joined on any shutdown path (a "
                        "non-daemon child blocks parent exit forever)"
                    ),
                )
            )
        else:
            findings.append(
                Finding(
                    file=f.module, line=ctor.line, code="T001",
                    message=(
                        f"thread '{label}' in '{f.name}' is neither daemon=True "
                        "nor joined on any shutdown path (leaks past its owner)"
                    ),
                )
            )
    if f.name in rpc_methods and not (
        ctor.target and ctor.target.startswith("self.")
    ):
        noun = "process" if ctor.kind == "process" else "thread"
        findings.append(
            Finding(
                file=f.module, line=ctor.line, code="T002",
                message=(
                    f"{noun} '{label}' spawned in rpc handler path "
                    f"'{f.name}' with no registered owner (unbounded leak "
                    "under request load)"
                ),
            )
        )


def _check_shm(
    f: FunctionInfo,
    ctor: ThreadCtor,
    group_calls: Set[str],
    findings: List[Finding],
) -> None:
    if _unlinked(ctor, f, group_calls):
        return
    label = ctor.target or "<anonymous>"
    findings.append(
        Finding(
            file=f.module, line=ctor.line, code="T004",
            message=(
                f"shared-memory segment '{label}' created (create=True) in "
                f"'{f.name}' with no unlink on any shutdown path (the "
                "/dev/shm name outlives the process)"
            ),
        )
    )
