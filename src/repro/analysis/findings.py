"""Finding model, inline suppressions, and the checked-in baseline.

A finding is ``file:line CODE message``.  Two escape hatches keep the CI
gate (`python -m repro.analysis --strict`) quiet on *accepted* findings
while still failing on new ones:

* **Inline suppression** — ``# analysis: allow(CODE)`` on the flagged line
  or the line directly above it.  Use for intentional, load-bearing
  exceptions and put the justification in the same comment.
* **Baseline** — ``analysis/baseline.txt`` holds accepted findings as
  ``<relpath> <CODE> <message>`` (line numbers omitted so the baseline
  survives unrelated edits).  ``--write-baseline`` / ``--update-baseline``
  regenerates it.  A baseline line no NEW finding matches anymore is
  *stale* — ``--strict`` fails on it too, so accepted-finding drift can't
  accumulate silently (:func:`stale_entries`).

Codes:

=====  ====================================================================
L001   write to a lock-guarded attribute without holding the lock
L002   lock-order cycle across classes (deadlock risk)
L003   blocking call (I/O, sleep, RPC, fsync) while holding a lock
J001   journal append of an event type with no apply_event branch
J002   apply_event branch for an event type that is never appended
J003   mutation of journaled dispatcher state outside the replay/append path
R001   rpc_* handler not documented in protocol.py
R002   rpc_* handler with no client stub call site
R003   rpc_* handler returning a non-dict / non-serializable payload
D001   blocking RPC to another process while holding a local lock
D002   synchronous RPC cycle across process roles
D003   retry-critical RPC (replication tail / heartbeat / shard fetch)
       with no timeout and no transport.Backoff policy
P001   wall-clock / perf_counter read on the journal replay path
P002   unseeded randomness (uuid4, os.urandom, random.*) on the replay path
P003   set-iteration order or thread identity feeding a journaled payload
P004   non-JSON-stable type (set) inside a journal append payload
T001   thread neither daemon=True nor joined on a shutdown path
T002   thread spawned inside an rpc_* handler without a registered owner
=====  ====================================================================
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Set, Tuple

ALL_CODES = (
    "L001", "L002", "L003",
    "J001", "J002", "J003",
    "R001", "R002", "R003",
    "D001", "D002", "D003",
    "P001", "P002", "P003", "P004",
    "T001", "T002",
)

_ALLOW_RE = re.compile(r"analysis:\s*allow\(([A-Z0-9,\s]+)\)")


@dataclass(frozen=True)
class Finding:
    file: str  # path relative to the analysis root, POSIX separators
    line: int
    code: str
    message: str

    def render(self) -> str:
        return f"{self.file}:{self.line} {self.code} {self.message}"

    def baseline_key(self) -> str:
        # Line numbers are deliberately absent: the baseline must survive
        # unrelated edits shifting code around.
        return f"{self.file} {self.code} {self.message}"


@dataclass
class SuppressionIndex:
    """Per-file map of line -> codes allowed on that line."""

    by_file: Dict[str, Dict[int, Set[str]]] = field(default_factory=dict)

    @staticmethod
    def scan(root: Path, files: List[Path]) -> "SuppressionIndex":
        idx = SuppressionIndex()
        for path in files:
            rel = path.relative_to(root).as_posix()
            lines: Dict[int, Set[str]] = {}
            try:
                text = path.read_text()
            except OSError:
                continue
            for i, src_line in enumerate(text.splitlines(), start=1):
                m = _ALLOW_RE.search(src_line)
                if not m:
                    continue
                codes = {c.strip() for c in m.group(1).split(",") if c.strip()}
                # The comment covers its own line and the line below it
                # (so a suppression can sit above a multi-line statement).
                lines.setdefault(i, set()).update(codes)
                lines.setdefault(i + 1, set()).update(codes)
            if lines:
                idx.by_file[rel] = lines
        return idx

    def allows(self, f: Finding) -> bool:
        return f.code in self.by_file.get(f.file, {}).get(f.line, set())


def load_baseline(path: Path) -> Set[str]:
    """Baseline file: one ``baseline_key`` per line; ``#`` comments allowed."""
    if not path.exists():
        return set()
    keys: Set[str] = set()
    for line in path.read_text().splitlines():
        line = line.strip()
        if not line or line.startswith("#"):
            continue
        keys.add(line)
    return keys


def write_baseline(path: Path, findings: List[Finding]) -> None:
    keys = sorted({f.baseline_key() for f in findings})
    header = (
        "# repro.analysis baseline — accepted findings, one per line as\n"
        "# '<relpath> <CODE> <message>' (no line numbers; see findings.py).\n"
        "# Regenerate with: python -m repro.analysis --update-baseline\n"
        "# Shrink it when you fix an entry; --strict fails on NEW findings\n"
        "# and on STALE entries (lines matching no current finding).\n"
    )
    path.write_text(header + "\n".join(keys) + ("\n" if keys else ""))


def stale_entries(baseline: Set[str], findings: List[Finding]) -> List[str]:
    """Baseline lines that no current finding matches (sorted).

    A stale entry means the accepted finding was fixed (or its message
    drifted) without shrinking the baseline; ``--strict`` fails on it so
    the accepted set always mirrors reality.
    """
    live = {f.baseline_key() for f in findings}
    return sorted(baseline - live)


def split_new(
    findings: List[Finding], baseline: Set[str], suppressions: SuppressionIndex
) -> Tuple[List[Finding], List[Finding]]:
    """Partition into (new, accepted) against baseline + inline allows."""
    new: List[Finding] = []
    accepted: List[Finding] = []
    for f in findings:
        if suppressions.allows(f) or f.baseline_key() in baseline:
            accepted.append(f)
        else:
            new.append(f)
    return new, accepted
