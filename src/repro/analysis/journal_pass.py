"""Pass 2 — journal/replay conformance (J001, J002, J003).

The dispatcher's WAL contract is append-before-apply: every state change is
journaled as ``self._journal.append("<etype>", payload)`` and must be
reproducible by ``apply_event`` replaying that record (restart and
hot-standby tail both go through it).  The chaos harness samples this
equivalence dynamically; this pass pins it statically:

* **J001** — an appended event type with no matching branch in any
  ``apply*_event`` function: replay silently drops the event.
* **J002** — an ``apply*_event`` branch for an event type that is never
  appended: dead replay code, usually a rename that forgot the write path.
  (The ``"snapshot"`` record is exempt: it is produced by journal
  compaction — ``Journal.snapshot()`` — not by ``append``.)
* **J003** — a mutation of journaled dispatcher state (an attribute the
  replay path writes) from a function that is neither reachable from
  ``apply*_event`` nor itself journaling (no ``_journal.append`` in it or
  in a direct callee): such a write exists only on the primary and is lost
  on replay.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from .findings import Finding
from .model import ClassInfo, FunctionInfo, Project

APPLY_NAMES_HINT = "apply"  # functions named apply*_event* are replay entry points
# Event types that legitimately appear in replay without an append call site.
REPLAY_ONLY_ETYPES = {"snapshot"}


def _is_apply_func(name: str) -> bool:
    return name.startswith("apply") and "event" in name


def _journal_append_sites(func: FunctionInfo) -> List:
    return [
        c for c in func.calls
        if c.name.rsplit(".", 1)[-1] == "append" and "journal" in c.name.lower()
    ]


def _collect_branch_etypes(project: Project, func: FunctionInfo) -> Dict[str, int]:
    """Parse the apply function's source for ``etype == "x"`` branches."""
    path = project.root / func.module
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return {}
    target: ast.AST = None
    for node in ast.walk(tree):
        if (
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and node.name == func.name
            and node.lineno == func.line
        ):
            target = node
            break
    if target is None:
        return {}
    etypes: Dict[str, int] = {}
    for node in ast.walk(target):
        if not isinstance(node, ast.Compare) or len(node.ops) != 1:
            continue
        op, comp = node.ops[0], node.comparators[0]
        if isinstance(op, ast.Eq) and isinstance(comp, ast.Constant) and isinstance(
            comp.value, str
        ):
            etypes.setdefault(comp.value, node.lineno)
        elif isinstance(op, ast.In) and isinstance(comp, (ast.Tuple, ast.Set, ast.List)):
            for el in comp.elts:
                if isinstance(el, ast.Constant) and isinstance(el.value, str):
                    etypes.setdefault(el.value, node.lineno)
    return etypes


def _dispatcher_group(project: Project) -> List[ClassInfo]:
    """The class group containing the apply*_event replay entry points."""
    for group in project.class_groups():
        for c in group:
            for f in c.functions.values():
                if _is_apply_func(f.name):
                    return group
    return []


def _replay_closure(group: List[ClassInfo]) -> Set[str]:
    """Method names reachable from the apply entry points via self.* calls."""
    methods: Dict[str, List[FunctionInfo]] = {}
    for c in group:
        for f in c.functions.values():
            if not f.is_nested:
                methods.setdefault(f.name, []).append(f)
    frontier = [n for n in methods if _is_apply_func(n)]
    seen: Set[str] = set(frontier)
    while frontier:
        name = frontier.pop()
        for f in methods[name]:
            for call in f.calls:
                parts = call.name.split(".")
                if len(parts) == 2 and parts[0] == "self" and parts[1] in methods:
                    if parts[1] not in seen:
                        seen.add(parts[1])
                        frontier.append(parts[1])
    return seen


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    group = _dispatcher_group(project)
    if not group:
        return findings
    funcs: List[FunctionInfo] = [
        f for c in group for f in c.functions.values()
    ]

    # -- appended vs applied ------------------------------------------------
    appended: Dict[str, List[Tuple[str, int]]] = {}
    for f in funcs:
        for site in _journal_append_sites(f):
            if site.str_arg0 is not None:
                appended.setdefault(site.str_arg0, []).append((f.module, site.line))
    applied: Dict[str, Tuple[str, int]] = {}
    for f in funcs:
        if not _is_apply_func(f.name):
            continue
        for etype, line in _collect_branch_etypes(project, f).items():
            applied.setdefault(etype, (f.module, line))

    for etype, sites in sorted(appended.items()):
        if etype not in applied:
            module, line = sites[0]
            findings.append(
                Finding(
                    file=module, line=line, code="J001",
                    message=(
                        f"journal append of '{etype}' has no apply_event "
                        "branch (replay drops it)"
                    ),
                )
            )
    for etype, (module, line) in sorted(applied.items()):
        if etype not in appended and etype not in REPLAY_ONLY_ETYPES:
            findings.append(
                Finding(
                    file=module, line=line, code="J002",
                    message=(
                        f"apply_event branch for '{etype}' but nothing "
                        "appends it (dead replay path)"
                    ),
                )
            )

    # -- J003: journaled-state writes off the replay/append path ------------
    closure = _replay_closure(group)
    journaled_attrs: Set[str] = set()
    lock_attrs = {a for c in group for a in c.lock_attrs}
    for f in funcs:
        if f.name in closure and not f.is_nested:
            for w in f.writes:
                if w.root == "self" and w.attr.split(".")[0] not in lock_attrs:
                    journaled_attrs.add(w.attr)
    appenders: Set[str] = {
        f.name for f in funcs if _journal_append_sites(f) and not f.is_nested
    }
    method_names = {f.name for f in funcs if not f.is_nested}
    for f in funcs:
        if f.is_nested or f.name in closure or f.name in appenders:
            continue
        if f.name == "__init__" or f.name.startswith("close"):
            continue
        # One hop of grace: a function that calls an appender is on the
        # append path (the append dominates the mutation by convention).
        calls_appender = any(
            c.name.split(".")[1] in appenders
            for c in f.calls
            if c.name.startswith("self.") and len(c.name.split(".")) == 2
            and c.name.split(".")[1] in method_names
        )
        if calls_appender:
            continue
        for w in f.writes:
            if w.root == "self" and w.attr in journaled_attrs:
                findings.append(
                    Finding(
                        file=f.module, line=w.line, code="J003",
                        message=(
                            f"write to journaled state '{w.attr}' outside "
                            "the replay/append path (lost on replay)"
                        ),
                    )
                )
    return findings
