"""Pass 4 — distributed blocking (D001, D002, D003).

The single-process lock rules (``locks.py``) stop at the process edge; this
pass follows the RPC through it using the inter-process call graph
(:class:`~.model.RpcGraph`): every stub ``.call("m", ...)`` site is
resolved to the ``rpc_m`` handler(s) and both ends carry a process role.

* **D001** — a blocking RPC issued *while holding a local lock*: the
  distributed generalization of L003.  A dispatcher handler that RPCs a
  worker under ``self._lock`` serializes the whole control plane behind
  one remote process's latency — and if the callee (transitively) calls
  back, it deadlocks the fleet rather than one thread.
* **D002** — a synchronous RPC cycle across process roles reachable from a
  single handler (dispatcher→worker→dispatcher): each hop holds a server
  thread, so the cycle deadlocks once the pools are exhausted — and under
  any lock it deadlocks immediately.
* **D003** — an RPC on a *retry-critical path* — the replication tail
  (``journal_fetch``), heartbeats, dynamic shard fetch (``get_shard``) —
  issued in a loop with neither an explicit stub ``timeout=`` nor a
  ``transport.Backoff`` policy.  These loops are exactly the paths that
  must stay live through a hung peer: failover latency is bounded by the
  RPC deadline, not the transport's (30s) default.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding
from .locks import GroupAnalysis
from .model import (
    CallSite,
    FunctionInfo,
    Project,
    RpcGraph,
    is_stub_call,
    process_role,
)

# Method-name predicate for D003's retry-critical RPC surface.
_RETRY_CRITICAL_EXACT = {"journal_fetch", "get_shard"}
_RETRY_CRITICAL_FRAGMENT = "heartbeat"


def _retry_critical(method: str) -> bool:
    return method in _RETRY_CRITICAL_EXACT or _RETRY_CRITICAL_FRAGMENT in method


def _check_rpc_under_lock(
    project: Project, graph: RpcGraph, findings: List[Finding]
) -> None:
    for group in project.class_groups():
        if not any(c.lock_attrs for c in group):
            continue
        ga = GroupAnalysis(project, group)
        for f in ga.functions:
            if f.name == "__init__":
                continue
            for site in f.calls:
                method = is_stub_call(site)
                if method is None or not graph.handlers_for(method):
                    continue
                held = ga.effective(f, site.with_items)
                if not held:
                    continue
                lock = sorted(held)[0]
                owner = ga.lock_owner.get(lock, f.class_name or "?")
                roles = ", ".join(
                    sorted({process_role(h.module) or "?"
                            for h in graph.handlers_for(method)})
                )
                findings.append(
                    Finding(
                        file=f.module, line=site.line, code="D001",
                        message=(
                            f"RPC '{method}' to {roles} process while "
                            f"holding '{owner}.{lock}' (wedges the fleet "
                            "on a slow/hung peer)"
                        ),
                    )
                )


def _check_rpc_cycles(graph: RpcGraph, findings: List[Finding]) -> None:
    """Cycles in the combined call graph containing >=1 cross-process edge.

    The search starts from rpc_* handlers only: a cycle that no handler
    can reach cannot be entered by a remote caller.
    """
    adj = graph.call_graph()
    by_id: Dict[int, FunctionInfo] = {}
    for fs in graph.handlers.values():
        for f in fs:
            by_id[id(f)] = f

    reported: Set[frozenset] = set()
    GRAY, BLACK = 1, 2
    color: Dict[int, int] = {}

    def describe(f: FunctionInfo) -> str:
        role = process_role(f.module) or "?"
        name = f.qualname if f.class_name else f.name
        return f"{role}:{name}"

    def dfs(f: FunctionInfo, path: List[Tuple[FunctionInfo, Optional[object]]]):
        color[id(f)] = GRAY
        for callee, edge in adj.get(id(f), ()):  # edge: RpcEdge or None
            state = color.get(id(callee))
            if state == GRAY:
                # back edge: extract the cycle from the path
                idx = next(
                    (i for i, (g, _) in enumerate(path) if g is callee), None
                )
                if idx is None:
                    continue
                # edges are stored with the node they point INTO; the
                # closing (callee, edge) tuple carries the back edge
                cycle = path[idx:] + [(callee, edge)]
                cross = [e for _, e in cycle[1:] if e is not None]
                if not cross:
                    continue  # plain recursion, not a distributed cycle
                canon = frozenset(id(g) for g, _ in cycle)
                if canon in reported:
                    continue
                reported.add(canon)
                first = cross[0]
                chain = " -> ".join(describe(g) for g, _ in cycle)
                findings.append(
                    Finding(
                        file=first.caller.module, line=first.site.line,
                        code="D002",
                        message=(
                            f"synchronous RPC cycle across processes: {chain}"
                        ),
                    )
                )
            elif state != BLACK:
                dfs(callee, path + [(callee, edge)])
        color[id(f)] = BLACK

    for hid in sorted(by_id, key=lambda i: (by_id[i].module, by_id[i].line)):
        if color.get(hid) is None:
            dfs(by_id[hid], [(by_id[hid], None)])


def _has_backoff_policy(f: FunctionInfo) -> bool:
    """The function drives a transport.Backoff (ctor or .next_delay())."""
    for c in f.calls:
        last = c.name.rsplit(".", 1)[-1]
        if last in ("Backoff", "next_delay"):
            return True
    return False


def _stub_has_timeout(project: Project, f: FunctionInfo, site: CallSite) -> bool:
    """The receiver of ``<recv>.call(...)`` was built as Stub(..., timeout=)."""
    recv = site.name.rsplit(".", 1)[0]
    parts = recv.split(".")
    if parts and parts[0] in f.local_aliases:
        parts = f.local_aliases[parts[0]].split(".") + parts[1:]
    if len(parts) >= 2 and parts[0] == "self":
        return parts[-1] in project.stub_timeout_attrs
    if len(parts) == 1:
        return parts[0] in f.stub_timeout_locals
    return False


def _check_retry_critical(
    project: Project, graph: RpcGraph, findings: List[Finding]
) -> None:
    for f in project.all_functions():
        for site in f.calls:
            method = is_stub_call(site)
            if method is None or not _retry_critical(method):
                continue
            if site.loop_depth == 0:
                continue  # one-shot call; caller's own deadline governs
            if _has_backoff_policy(f) or _stub_has_timeout(project, f, site):
                continue
            findings.append(
                Finding(
                    file=f.module, line=site.line, code="D003",
                    message=(
                        f"retry-critical RPC '{method}' in a loop with no "
                        "stub timeout and no transport.Backoff (a hung "
                        "peer stalls this path for the transport default)"
                    ),
                )
            )


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    graph = RpcGraph(project)
    _check_rpc_under_lock(project, graph, findings)
    _check_rpc_cycles(graph, findings)
    _check_retry_critical(project, graph, findings)
    return findings
