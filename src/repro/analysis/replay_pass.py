"""Pass 5 — replay determinism (P001, P002, P003, P004).

The HA guarantee (PR 6) is that replaying the journal — on restart, or
incrementally on a tailing hot standby — reproduces the primary's state
*byte-identically*.  The chaos harness samples that dynamically; this pass
pins the static precondition: everything reachable from the
``apply*_event`` entry points, and everything that constructs journal
payloads, must be deterministic.

* **P001** — a wall-clock / ``perf_counter`` / ``monotonic`` read on the
  replay path: replay happens at a different time than the original
  apply, so any time-derived state diverges between primary and standby.
* **P002** — unseeded randomness (``uuid4``, ``os.urandom``,
  ``random.*``) on the replay path, including one hop through a
  module-level helper (``new_id``): replayed ids would not match the
  journaled ones.
* **P003** — set-iteration order or thread identity feeding a journaled
  payload: the journal *records* would differ between two runs of the
  same primary (set order is hash-seed dependent), so a standby's mirror
  and the primary's log could not be compared byte-for-byte.
* **P004** — a provably non-JSON-stable value (a set) inside a
  ``_journal.append`` payload: even when the content is right, its
  serialization order is not.

Scope: the dispatcher class group (the one defining ``apply*_event``), the
same group the J-pass checks.  P001/P002 apply to the replay closure;
P003/P004 to every function that appends journal records.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set

from .findings import Finding
from .journal_pass import (
    _dispatcher_group,
    _is_apply_func,
    _journal_append_sites,
    _replay_closure,
)
from .model import FunctionInfo, Project, dotted_name

# Direct nondeterminism sources, by dotted-name suffix.
_WALL_CLOCK = {
    "time.time", "time.time_ns", "time.monotonic", "time.monotonic_ns",
    "time.perf_counter", "time.perf_counter_ns",
}
_WALL_CLOCK_SUFFIX = (".now", ".utcnow", ".today")
_RANDOM_EXACT = {"os.urandom"}
_RANDOM_SUFFIX = (".uuid1", ".uuid4", ".token_hex", ".token_bytes")
_RANDOM_MODULE_FNS = {
    "random", "uniform", "randint", "randrange", "choice", "choices",
    "shuffle", "sample", "getrandbits", "random.random",
}
_THREAD_IDENTITY = {"threading.get_ident", "threading.current_thread"}


def _is_wall_clock(name: str) -> bool:
    return name in _WALL_CLOCK or name.endswith(_WALL_CLOCK_SUFFIX)


def _is_random(name: str) -> bool:
    if name in _RANDOM_EXACT or name.endswith(_RANDOM_SUFFIX):
        return True
    parts = name.split(".")
    return len(parts) == 2 and parts[0] == "random" and parts[1] in _RANDOM_MODULE_FNS


def _nondet_helpers(project: Project) -> Set[str]:
    """Module-level functions that directly mint nondeterminism (one hop).

    ``protocol.new_id`` wraps ``uuid.uuid4``; calls to it are as
    nondeterministic as the uuid itself, so its bare name joins the
    predicate.
    """
    out: Set[str] = set()
    for mod in project.modules.values():
        for f in mod.functions.values():
            if any(_is_random(c.name) or _is_wall_clock(c.name) for c in f.calls):
                out.add(f.name)
    return out


def _check_replay_closure(
    project: Project, funcs: List[FunctionInfo], closure: Set[str],
    findings: List[Finding],
) -> None:
    helpers = _nondet_helpers(project)
    for f in funcs:
        if f.is_nested or f.name not in closure:
            continue
        for c in f.calls:
            if _is_wall_clock(c.name):
                findings.append(
                    Finding(
                        file=f.module, line=c.line, code="P001",
                        message=(
                            f"clock read '{c.name}' in '{f.name}' on the "
                            "replay path (diverges on standby/restart replay)"
                        ),
                    )
                )
            elif _is_random(c.name) or c.name in helpers:
                findings.append(
                    Finding(
                        file=f.module, line=c.line, code="P002",
                        message=(
                            f"nondeterministic call '{c.name}' in '{f.name}' "
                            "on the replay path (replayed value differs from "
                            "the journaled one)"
                        ),
                    )
                )


def _check_payload_order(funcs: List[FunctionInfo], findings: List[Finding]) -> None:
    """P003: journal appends whose order or content depends on set
    iteration or thread identity."""
    for f in funcs:
        appends = _journal_append_sites(f)
        if not appends:
            continue
        flagged_loops: Set[int] = set()
        for site in appends:
            for loop_line in site.set_loops:
                if loop_line in flagged_loops:
                    continue
                flagged_loops.add(loop_line)
                findings.append(
                    Finding(
                        file=f.module, line=loop_line, code="P003",
                        message=(
                            f"journal append of '{site.str_arg0 or '?'}' in "
                            f"'{f.name}' inside a set-iteration loop (record "
                            "order is hash-seed dependent; sort the set)"
                        ),
                    )
                )
        for c in f.calls:
            if c.name in _THREAD_IDENTITY:
                findings.append(
                    Finding(
                        file=f.module, line=c.line, code="P003",
                        message=(
                            f"thread identity '{c.name}' in journaling "
                            f"function '{f.name}' (not stable across "
                            "processes or replays)"
                        ),
                    )
                )


_PAYLOAD_CONSUMERS = {"sorted", "list", "tuple", "len", "sum", "min", "max"}


def _check_payload_types(
    project: Project, funcs: List[FunctionInfo], findings: List[Finding]
) -> None:
    """P004: set values inside append payload expressions (re-parses the
    module to see the actual argument AST, like the J/R passes do)."""
    by_module: Dict[str, List[FunctionInfo]] = {}
    for f in funcs:
        if _journal_append_sites(f):
            by_module.setdefault(f.module, []).append(f)
    for module, mod_funcs in sorted(by_module.items()):
        path = project.root / module
        try:
            tree = ast.parse(path.read_text())
        except (OSError, SyntaxError):
            continue
        append_lines = {
            s.line: s.str_arg0
            for f in mod_funcs
            for s in _journal_append_sites(f)
        }
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call) or node.lineno not in append_lines:
                continue
            name = dotted_name(node.func)
            if not (name and name.rsplit(".", 1)[-1] == "append"):
                continue
            payload_exprs = list(node.args[1:]) + [
                kw.value for kw in node.keywords if kw.arg != "sync"
            ]
            for expr in payload_exprs:
                consumed: Set[int] = set()
                for sub in ast.walk(expr):
                    if isinstance(sub, ast.Call):
                        fn = sub.func
                        if isinstance(fn, ast.Name) and fn.id in _PAYLOAD_CONSUMERS:
                            consumed.update(
                                id(a) for a in sub.args
                                if isinstance(a, (ast.Set, ast.SetComp))
                            )
                for sub in ast.walk(expr):
                    if isinstance(sub, (ast.Set, ast.SetComp)) and id(sub) not in consumed:
                        findings.append(
                            Finding(
                                file=module, line=node.lineno, code="P004",
                                message=(
                                    f"set inside the journal payload of "
                                    f"'{append_lines[node.lineno] or '?'}' "
                                    "(serialization order is not stable)"
                                ),
                            )
                        )
                        break


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    group = _dispatcher_group(project)
    if not group:
        return findings
    funcs: List[FunctionInfo] = [f for c in group for f in c.functions.values()]
    closure = _replay_closure(group)
    _check_replay_closure(project, funcs, closure, findings)
    _check_payload_order(funcs, findings)
    _check_payload_types(project, funcs, findings)
    return findings
