"""Pass 3 — RPC surface conformance (R001, R002, R003).

Handlers are the ``rpc_*`` methods dispatched by ``Dispatcher.handle`` /
``Worker.handle``.  For each one:

* **R001** — the bare method name (without the ``rpc_`` prefix) must appear
  in the ``protocol.py`` module docstring: that docstring IS the protocol
  spec; an undocumented method is an undocumented wire surface.
* **R002** — some client-side stub call site must invoke it: a call whose
  callee ends in ``call`` with the method name as a string first argument
  (``stub.call("get_shard", ...)``, ``self._try_call("complete_shard", …)``).
  A handler nothing calls is dead wire surface — or its caller builds the
  method name dynamically, which defeats this pass and grep alike.
* **R003** — the handler must return dict payloads (both transports ship
  dicts; a set anywhere in the payload does not survive msgpack/JSON).
  Only provable violations are flagged: a literal non-dict return, or a
  set literal inside the returned expression.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from .findings import Finding
from .model import FunctionInfo, Project


def _protocol_docstring(project: Project) -> Tuple[Optional[str], str]:
    for relpath, mod in sorted(project.modules.items()):
        if relpath.rsplit("/", 1)[-1] == "protocol.py":
            return relpath, mod.docstring
    return None, ""


def _handlers(project: Project) -> List[FunctionInfo]:
    out = []
    for mod in project.modules.values():
        for cls in mod.classes.values():
            for f in cls.functions.values():
                if f.name.startswith("rpc_") and not f.is_nested:
                    out.append(f)
    return out


def _stub_called_methods(project: Project) -> Set[str]:
    called: Set[str] = set()
    for f in project.all_functions():
        for c in f.calls:
            if c.str_arg0 is not None and c.name.rsplit(".", 1)[-1].endswith("call"):
                called.add(c.str_arg0)
    return called


def _check_returns(project: Project, func: FunctionInfo) -> List[Tuple[int, str]]:
    """Provable non-dict / non-serializable returns in one handler."""
    path = project.root / func.module
    try:
        tree = ast.parse(path.read_text())
    except (OSError, SyntaxError):
        return []
    target = None
    for node in ast.walk(tree):
        if (
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and node.name == func.name
            and node.lineno == func.line
        ):
            target = node
            break
    if target is None:
        return []
    bad: List[Tuple[int, str]] = []
    returns: List[ast.Return] = []
    stack: List[ast.AST] = list(target.body)
    while stack:  # stop at nested def/class boundaries (their returns aren't ours)
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Lambda, ast.ClassDef)):
            continue
        if isinstance(node, ast.Return):
            returns.append(node)
        stack.extend(ast.iter_child_nodes(node))
    for node in sorted(returns, key=lambda n: n.lineno):
        if node.value is None:
            continue
        v = node.value
        if isinstance(v, (ast.Set, ast.SetComp)):
            bad.append((node.lineno, "returns a set (not wire-serializable)"))
        elif isinstance(v, (ast.Tuple, ast.List, ast.ListComp)):
            bad.append((node.lineno, "returns a non-dict payload"))
        elif isinstance(v, ast.Constant) and not isinstance(v.value, dict):
            bad.append((node.lineno, "returns a non-dict constant payload"))
        else:
            # A set that is immediately consumed by a list-/scalar-producing
            # builtin (``sorted({...})``) never reaches the wire.
            consumed = set()
            for sub in ast.walk(v):
                if isinstance(sub, ast.Call):
                    fn = sub.func
                    if isinstance(fn, ast.Name) and fn.id in (
                        "sorted", "list", "tuple", "len", "sum",
                        "min", "max", "any", "all",
                    ):
                        consumed.update(
                            id(a) for a in sub.args
                            if isinstance(a, (ast.Set, ast.SetComp))
                        )
            for sub in ast.walk(v):
                if isinstance(sub, (ast.Set, ast.SetComp)) and id(sub) not in consumed:
                    bad.append(
                        (node.lineno, "set literal inside the returned payload")
                    )
                    break
    return bad


def run(project: Project) -> List[Finding]:
    findings: List[Finding] = []
    handlers = _handlers(project)
    if not handlers:
        return findings
    proto_path, proto_doc = _protocol_docstring(project)
    called = _stub_called_methods(project)

    for f in sorted(handlers, key=lambda f: (f.module, f.line)):
        method = f.name[len("rpc_"):]
        if proto_path is not None and not re.search(
            rf"(?<!\w){re.escape(method)}(?!\w)", proto_doc
        ):
            findings.append(
                Finding(
                    file=f.module, line=f.line, code="R001",
                    message=(
                        f"rpc handler '{method}' is not documented in "
                        f"{proto_path}"
                    ),
                )
            )
        if method not in called:
            findings.append(
                Finding(
                    file=f.module, line=f.line, code="R002",
                    message=(
                        f"rpc handler '{method}' has no client stub call "
                        "site (dead wire surface?)"
                    ),
                )
            )
        for line, why in _check_returns(project, f):
            findings.append(
                Finding(
                    file=f.module, line=line, code="R003",
                    message=f"rpc handler '{method}' {why}",
                )
            )
    return findings
