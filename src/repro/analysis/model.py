"""Shared AST extraction for the analysis passes.

One walk per module produces a language-neutral model:

* which classes own ``threading.Lock/RLock/Condition`` attributes,
* every write to a ``self.<attr>`` (with the stack of ``with``-items held
  at the write site),
* every call site (dotted callee name, held ``with``-items, string first
  argument, keyword constants),
* class inheritance, so mixin families (the dispatcher is four classes)
  are analyzed as one unit ("class group").

The model is intentionally syntactic — no type inference beyond a small
``attr name -> class`` registry built from ``x.<attr> = ClassName(...)``
assignments.  The passes consume it in a resolve phase where the merged
class groups are known.

On top of the per-module extraction sits the **inter-process call graph**
(:class:`RpcGraph`): every stub call site — a call whose callee ends in
``call`` with a string first argument, e.g. ``stub.call("get_shard", ...)``
— is resolved to the ``rpc_get_shard`` handler(s) defined anywhere in the
project, and both ends are tagged with a *process role* inferred from the
module path (client / worker / dispatcher / standby / orchestrator /
tooling).  The D/T pass families (distributed blocking, rpc cycles,
thread lifecycle in handlers) are consumers.
"""
from __future__ import annotations

import ast
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional, Set, Tuple

LOCK_CTORS = {"Lock", "RLock", "Condition"}


def dotted_name(node: ast.AST) -> Optional[str]:
    """Render ``a.b.c`` chains; None for anything not a pure name chain."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted_name(node.value)
        return f"{base}.{node.attr}" if base else None
    if isinstance(node, ast.Call):
        # ``Stub(addr).call`` — render the callee chain with () marker so
        # consumers can still match the trailing attribute.
        base = dotted_name(node.func)
        return f"{base}()" if base else None
    return None


def _is_lock_ctor(node: ast.AST) -> bool:
    """``threading.Lock()`` / ``Lock()`` / ``field(default_factory=threading.Lock)``."""
    if not isinstance(node, ast.Call):
        return False
    name = dotted_name(node.func) or ""
    last = name.rsplit(".", 1)[-1]
    if last in LOCK_CTORS:
        return True
    if last == "field":
        for kw in node.keywords:
            if kw.arg == "default_factory":
                factory = dotted_name(kw.value) or ""
                if factory.rsplit(".", 1)[-1] in LOCK_CTORS:
                    return True
    return False


@dataclass
class AttrWrite:
    attr: str  # first attribute after the root (``self._seq`` -> ``_seq``)
    root: str  # root name of the target chain (usually ``self``)
    line: int
    with_items: Tuple[str, ...]  # dotted exprs of enclosing with-statements
    func: "FunctionInfo" = field(repr=False, default=None)  # back-ref
    augmented: bool = False


@dataclass
class CallSite:
    name: str  # dotted callee, e.g. ``self._journal.append`` or ``time.sleep``
    line: int
    with_items: Tuple[str, ...]
    str_arg0: Optional[str] = None  # first positional arg if a str constant
    const_kwargs: Dict[str, object] = field(default_factory=dict)
    func: "FunctionInfo" = field(repr=False, default=None)
    loop_depth: int = 0  # number of enclosing for/while loops
    # lines of enclosing ``for`` loops whose iterable is provably a set
    # (set literal/comprehension, ``set(...)``, or a local bound to one)
    set_loops: Tuple[int, ...] = ()


@dataclass
class ThreadCtor:
    """A ``threading.Thread(...)`` / ``multiprocessing.Process(...)`` /
    ``SharedMemory(create=True)`` construction and where it was stored."""

    target: Optional[str]  # dotted store target (``self._thread``, ``t``), or None
    line: int
    daemon: Optional[object]  # const value of ``daemon=`` kwarg, None if absent
    func: "FunctionInfo" = field(repr=False, default=None)
    kind: str = "thread"  # "thread" | "process" | "shm"


@dataclass
class WithAcquire:
    item: str  # dotted expr of the with-item, e.g. ``self._lock``
    line: int
    held_before: Tuple[str, ...]  # with-items already held at this point


@dataclass
class FunctionInfo:
    name: str
    qualname: str  # Class.meth or Class.meth.<locals>.inner
    class_name: Optional[str]
    module: str  # relpath of the module
    line: int
    docstring: str
    is_nested: bool
    writes: List[AttrWrite] = field(default_factory=list)
    calls: List[CallSite] = field(default_factory=list)
    acquires: List[WithAcquire] = field(default_factory=list)
    returns: List[ast.Return] = field(default_factory=list)
    # ``mgr = job.shard_mgr`` — lets the lock-order pass resolve
    # ``with mgr._lock:`` one alias hop deep.
    local_aliases: Dict[str, str] = field(default_factory=dict)
    # exception type names (last dotted segment) this function catches
    handled_exceptions: Set[str] = field(default_factory=set)
    thread_ctors: List[ThreadCtor] = field(default_factory=list)  # threads + processes
    shm_ctors: List[ThreadCtor] = field(default_factory=list)  # SharedMemory(create=True)
    # local names bound to ``Stub(..., timeout=...)`` in this function
    stub_timeout_locals: Set[str] = field(default_factory=set)


@dataclass
class ClassInfo:
    name: str
    module: str
    line: int
    bases: List[str]
    lock_attrs: Set[str] = field(default_factory=set)
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)


@dataclass
class ModuleInfo:
    relpath: str
    classes: Dict[str, ClassInfo] = field(default_factory=dict)
    functions: Dict[str, FunctionInfo] = field(default_factory=dict)  # module-level
    docstring: str = ""


@dataclass
class Project:
    root: Path
    modules: Dict[str, ModuleInfo] = field(default_factory=dict)
    # attr name -> class names assigned via ``<x>.<attr> = ClassName(...)``
    attr_classes: Dict[str, Set[str]] = field(default_factory=dict)
    # attrs assigned a ``Stub(..., timeout=...)`` — stubs with an explicit
    # RPC deadline (the D003 discipline check consults this)
    stub_timeout_attrs: Set[str] = field(default_factory=set)

    def all_classes(self) -> List[ClassInfo]:
        return [c for m in self.modules.values() for c in m.classes.values()]

    def all_functions(self) -> List[FunctionInfo]:
        out: List[FunctionInfo] = []
        for m in self.modules.values():
            out.extend(m.functions.values())
            for c in m.classes.values():
                out.extend(c.functions.values())
        return out

    def class_groups(self) -> List[List[ClassInfo]]:
        """Merge classes related by (name-resolved) inheritance.

        ``Dispatcher(ControlPlaneMixin, FleetMixin, CommitterMixin)`` and its
        mixins form one group: the lock lives on the subclass but the guarded
        writes live in the mixins.
        """
        by_name: Dict[str, List[ClassInfo]] = {}
        for c in self.all_classes():
            by_name.setdefault(c.name, []).append(c)
        parent: Dict[Tuple[str, str], Tuple[str, str]] = {}

        def key(c: ClassInfo) -> Tuple[str, str]:
            return (c.module, c.name)

        def find(k):
            while parent.get(k, k) != k:
                parent[k] = parent.get(parent[k], parent[k])
                k = parent[k]
            return k

        def union(a, b):
            ra, rb = find(a), find(b)
            if ra != rb:
                parent[ra] = rb

        for c in self.all_classes():
            parent.setdefault(key(c), key(c))
            for base in c.bases:
                base_name = base.rsplit(".", 1)[-1]
                for bc in by_name.get(base_name, []):
                    parent.setdefault(key(bc), key(bc))
                    union(key(c), key(bc))
        groups: Dict[Tuple[str, str], List[ClassInfo]] = {}
        for c in self.all_classes():
            groups.setdefault(find(key(c)), []).append(c)
        return list(groups.values())


class _FunctionWalker(ast.NodeVisitor):
    """Walk one function body tracking the enclosing with-item stack."""

    def __init__(self, info: FunctionInfo, collector: "_ModuleCollector"):
        self.info = info
        self.collector = collector
        self.with_stack: List[str] = []
        # (line, iterable_is_a_set) per enclosing loop
        self.loop_stack: List[Tuple[int, bool]] = []
        self.set_locals: Set[str] = set()  # locals bound to a set expression

    # -- scope boundaries --------------------------------------------------
    def _nested_function(self, node) -> None:
        # A nested def runs later, not under the locks held at the def site.
        qual = f"{self.info.qualname}.<locals>.{node.name}"
        self.collector.collect_function(
            node, qual, self.info.class_name, nested=True
        )

    def visit_FunctionDef(self, node: ast.FunctionDef) -> None:
        self._nested_function(node)

    def visit_AsyncFunctionDef(self, node: ast.AsyncFunctionDef) -> None:
        self._nested_function(node)

    def visit_ClassDef(self, node: ast.ClassDef) -> None:
        self.collector.collect_class(node, nested_in=self.info.qualname)

    def visit_Lambda(self, node: ast.Lambda) -> None:
        pass  # lambdas run later; their bodies rarely matter here

    # -- with / writes / calls --------------------------------------------
    def visit_With(self, node: ast.With) -> None:
        items: List[str] = []
        for item in node.items:
            name = dotted_name(item.context_expr)
            if name:
                self.info.acquires.append(
                    WithAcquire(
                        item=name, line=item.context_expr.lineno,
                        held_before=tuple(self.with_stack),
                    )
                )
                items.append(name)
            # visit the context expression itself (it may be a call)
            self.visit(item.context_expr)
        self.with_stack.extend(items)
        for stmt in node.body:
            self.visit(stmt)
        del self.with_stack[len(self.with_stack) - len(items):]

    # -- loops / exception handlers ---------------------------------------
    def _is_set_expr(self, node: ast.AST) -> bool:
        if isinstance(node, (ast.Set, ast.SetComp)):
            return True
        if isinstance(node, ast.Name) and node.id in self.set_locals:
            return True
        if isinstance(node, ast.Call):
            fn = dotted_name(node.func)
            if fn in ("set", "frozenset"):
                return True
        return False

    def _loop(self, node, is_set: bool) -> None:
        self.loop_stack.append((node.lineno, is_set))
        for stmt in node.body:
            self.visit(stmt)
        self.loop_stack.pop()
        for stmt in node.orelse:
            self.visit(stmt)

    def visit_For(self, node: ast.For) -> None:
        self.visit(node.iter)
        self._loop(node, self._is_set_expr(node.iter))

    def visit_While(self, node: ast.While) -> None:
        self.visit(node.test)
        self._loop(node, False)

    def visit_Try(self, node: ast.Try) -> None:
        for h in node.handlers:
            types: List[ast.AST] = []
            if isinstance(h.type, ast.Tuple):
                types = list(h.type.elts)
            elif h.type is not None:
                types = [h.type]
            for t in types:
                name = dotted_name(t)
                if name:
                    self.info.handled_exceptions.add(name.rsplit(".", 1)[-1])
        self.generic_visit(node)

    def _record_write(self, target: ast.AST, augmented: bool) -> None:
        # Render the full store path, seeing through subscripts:
        # ``self._tasks[tid] = ...``         -> attr ``_tasks``
        # ``self.metrics.rpc_count += 1``    -> attr ``metrics.rpc_count``
        # ``self._jobs[jid].finished = ...`` -> attr ``_jobs.finished``
        # Full paths keep guard inference per-field: mutating a field of a
        # shared sub-object is distinct from rebinding the attribute.
        line = getattr(target, "lineno", None)
        parts: List[str] = []
        node = target
        while True:
            if isinstance(node, ast.Attribute):
                parts.append(node.attr)
                node = node.value
            elif isinstance(node, ast.Subscript):
                node = node.value
            elif isinstance(node, ast.Name):
                parts.append(node.id)
                break
            else:
                return
        parts.reverse()
        if len(parts) < 2 or line is None:
            return
        self.info.writes.append(
            AttrWrite(
                attr=".".join(parts[1:]),
                root=parts[0],
                line=line,
                with_items=tuple(self.with_stack),
                func=self.info,
                augmented=augmented,
            )
        )

    def visit_Assign(self, node: ast.Assign) -> None:
        for t in node.targets:
            if isinstance(t, ast.Tuple):
                for el in t.elts:
                    self._record_write(el, augmented=False)
            else:
                self._record_write(t, augmented=False)
        self.collector.register_attr_class(node)
        self.collector.register_lock_attr(node, self.info.class_name)
        if (
            len(node.targets) == 1
            and isinstance(node.targets[0], ast.Name)
            and isinstance(node.value, (ast.Attribute, ast.Name))
        ):
            chain = dotted_name(node.value)
            if chain:
                self.info.local_aliases[node.targets[0].id] = chain
        if len(node.targets) == 1 and isinstance(node.targets[0], ast.Name):
            name = node.targets[0].id
            if self._is_set_expr(node.value):
                self.set_locals.add(name)
            else:
                self.set_locals.discard(name)
        self._register_ctor_facts(node)
        self.visit(node.value)

    def _register_ctor_facts(self, node: ast.Assign) -> None:
        """Thread/process/shm constructions and timeout'd stubs, with their
        store target."""
        if not isinstance(node.value, ast.Call):
            return
        ctor = dotted_name(node.value.func) or ""
        last = ctor.rsplit(".", 1)[-1]
        target = node.targets[0] if len(node.targets) == 1 else None
        target_chain = dotted_name(target) if target is not None else None
        if last in ("Thread", "Process"):
            daemon = None
            for kw in node.value.keywords:
                if kw.arg == "daemon" and isinstance(kw.value, ast.Constant):
                    daemon = kw.value.value
            self.info.thread_ctors.append(
                ThreadCtor(
                    target=target_chain, line=node.value.lineno,
                    daemon=daemon, func=self.info,
                    kind="thread" if last == "Thread" else "process",
                )
            )
        elif last == "SharedMemory" and any(
            kw.arg == "create"
            and isinstance(kw.value, ast.Constant)
            and kw.value.value is True
            for kw in node.value.keywords
        ):
            self.info.shm_ctors.append(
                ThreadCtor(
                    target=target_chain, line=node.value.lineno,
                    daemon=None, func=self.info, kind="shm",
                )
            )
        elif last.endswith("Stub") and any(
            kw.arg == "timeout" for kw in node.value.keywords
        ):
            if isinstance(target, ast.Attribute):
                self.collector.project.stub_timeout_attrs.add(target.attr)
            elif isinstance(target, ast.Name):
                self.info.stub_timeout_locals.add(target.id)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        self._record_write(node.target, augmented=True)
        self.visit(node.value)

    def visit_AnnAssign(self, node: ast.AnnAssign) -> None:
        if node.value is not None:
            self._record_write(node.target, augmented=False)
            self.visit(node.value)

    def visit_Delete(self, node: ast.Delete) -> None:
        for t in node.targets:
            self._record_write(t, augmented=False)

    def visit_Call(self, node: ast.Call) -> None:
        name = dotted_name(node.func)
        if name:
            str_arg0 = None
            if node.args and isinstance(node.args[0], ast.Constant) and isinstance(
                node.args[0].value, str
            ):
                str_arg0 = node.args[0].value
            const_kwargs = {
                kw.arg: kw.value.value
                for kw in node.keywords
                if kw.arg and isinstance(kw.value, ast.Constant)
            }
            self.info.calls.append(
                CallSite(
                    name=name, line=node.lineno,
                    with_items=tuple(self.with_stack),
                    str_arg0=str_arg0, const_kwargs=const_kwargs,
                    func=self.info,
                    loop_depth=len(self.loop_stack),
                    set_loops=tuple(l for l, is_set in self.loop_stack if is_set),
                )
            )
        self.generic_visit(node)

    def visit_Return(self, node: ast.Return) -> None:
        self.info.returns.append(node)
        if node.value is not None:
            self.visit(node.value)


class _ModuleCollector:
    def __init__(self, project: Project, relpath: str, tree: ast.Module):
        self.project = project
        self.mod = ModuleInfo(relpath=relpath, docstring=ast.get_docstring(tree) or "")
        self.current_class: Optional[ClassInfo] = None
        project.modules[relpath] = self.mod
        for node in tree.body:
            self._top(node)

    def _top(self, node: ast.AST) -> None:
        if isinstance(node, ast.ClassDef):
            self.collect_class(node, nested_in=None)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            self.collect_function(node, node.name, class_name=None, nested=False)
        elif isinstance(node, ast.Assign):
            self.register_attr_class(node)

    def collect_class(self, node: ast.ClassDef, nested_in: Optional[str]) -> None:
        name = node.name if not nested_in else f"{nested_in}.<locals>.{node.name}"
        cls = ClassInfo(
            name=node.name, module=self.mod.relpath, line=node.lineno,
            bases=[dotted_name(b) or "?" for b in node.bases],
        )
        # Keep nested classes distinct (``TCPServer.__init__.<locals>._Server``).
        self.mod.classes[name] = cls
        prev = self.current_class
        self.current_class = cls
        for stmt in node.body:
            if isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self.collect_function(
                    stmt, f"{cls.name}.{stmt.name}", class_name=cls.name, nested=False
                )
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                # dataclass-style: ``_lock: threading.Lock = field(...)``
                if isinstance(stmt.target, ast.Name) and _is_lock_ctor(stmt.value):
                    cls.lock_attrs.add(stmt.target.id)
            elif isinstance(stmt, ast.Assign) and _is_lock_ctor(stmt.value):
                for t in stmt.targets:
                    if isinstance(t, ast.Name):
                        cls.lock_attrs.add(t.id)
            elif isinstance(stmt, ast.ClassDef):
                self.collect_class(stmt, nested_in=cls.name)
        self.current_class = prev

    def collect_function(
        self, node, qualname: str, class_name: Optional[str], nested: bool
    ) -> None:
        info = FunctionInfo(
            name=node.name, qualname=qualname, class_name=class_name,
            module=self.mod.relpath, line=node.lineno,
            docstring=ast.get_docstring(node) or "", is_nested=nested,
        )
        owner = self.current_class
        if owner is not None:
            owner.functions[qualname.split(".", 1)[-1] if not nested else qualname] = info
        elif class_name is None:
            self.mod.functions[qualname] = info
        walker = _FunctionWalker(info, self)
        for stmt in node.body:
            walker.visit(stmt)

    def register_lock_attr(self, node: ast.Assign, class_name: Optional[str]) -> None:
        """``self.X = threading.Lock()`` inside a method registers X on the class."""
        if not _is_lock_ctor(node.value) or self.current_class is None:
            return
        for t in node.targets:
            if isinstance(t, ast.Attribute):
                self.current_class.lock_attrs.add(t.attr)

    def register_attr_class(self, node: ast.Assign) -> None:
        """``<x>.<attr> = ClassName(...)`` feeds the attr -> class registry."""
        if not isinstance(node.value, ast.Call):
            return
        ctor = dotted_name(node.value.func)
        if not ctor:
            return
        cls_name = ctor.rsplit(".", 1)[-1]
        if not cls_name or not cls_name[0].isupper():
            return
        for t in node.targets:
            if isinstance(t, ast.Attribute):
                self.project.attr_classes.setdefault(t.attr, set()).add(cls_name)


# ---------------------------------------------------------------------------
# Inter-process call graph
# ---------------------------------------------------------------------------
# Module-path fragments -> process role.  First match wins; checked against
# the file name first, then every path component.  Generic enough to
# classify both the live tree (core/client.py, core/dispatcher/*, ...) and
# the analysis fixtures (client.py / worker.py / dispatcher.py).
_ROLE_PATTERNS: Tuple[Tuple[str, str], ...] = (
    ("replica", "standby"),
    ("standby", "standby"),
    ("worker", "worker"),
    ("client", "client"),
    ("feed", "client"),
    ("service", "orchestrator"),
    ("orchestrator", "orchestrator"),
    ("dispatcher", "dispatcher"),
    ("obs", "tooling"),
)


def process_role(relpath: str) -> Optional[str]:
    """Process role of a module, inferred from its path; None if unknown."""
    parts = relpath.split("/")
    stem = parts[-1].rsplit(".", 1)[0]
    for fragment, role in _ROLE_PATTERNS:
        if fragment in stem:
            return role
    for fragment, role in _ROLE_PATTERNS:
        if any(fragment in p for p in parts[:-1]):
            return role
    return None


def is_stub_call(site: CallSite) -> Optional[str]:
    """The RPC method name if ``site`` is a client-stub call, else None.

    A stub call is any call whose callee's last segment ends in ``call``
    (``stub.call(...)``, ``self._try_call(...)``) with a string-constant
    first argument naming the method — the same predicate the R-pass uses.
    """
    if site.str_arg0 is None:
        return None
    if site.name.rsplit(".", 1)[-1].endswith("call"):
        return site.str_arg0
    return None


@dataclass
class RpcEdge:
    """One resolved cross-process call: stub site -> rpc_<method> handlers."""

    site: CallSite
    method: str
    caller: FunctionInfo
    caller_role: Optional[str]
    handlers: List[FunctionInfo]  # rpc_<method> definitions, any module

    def handler_roles(self) -> List[str]:
        return sorted({process_role(h.module) or "?" for h in self.handlers})


class RpcGraph:
    """Stub call sites resolved to ``rpc_*`` handlers across process roles.

    Also exposes the combined function-level call graph (intra-process
    ``self.<meth>()`` / module-level edges plus the cross-process stub
    edges) that the D002 cycle search walks.
    """

    def __init__(self, project: Project):
        self.project = project
        self.handlers: Dict[str, List[FunctionInfo]] = {}
        for mod in project.modules.values():
            for cls in mod.classes.values():
                for f in cls.functions.values():
                    if f.name.startswith("rpc_") and not f.is_nested:
                        self.handlers.setdefault(f.name[len("rpc_"):], []).append(f)
        for methods in self.handlers.values():
            methods.sort(key=lambda f: (f.module, f.line))
        self.edges: List[RpcEdge] = []
        for f in project.all_functions():
            for site in f.calls:
                method = is_stub_call(site)
                if method is None:
                    continue
                targets = self.handlers.get(method)
                if not targets:
                    continue
                self.edges.append(
                    RpcEdge(
                        site=site, method=method, caller=f,
                        caller_role=process_role(f.module), handlers=targets,
                    )
                )

    def handlers_for(self, method: str) -> List[FunctionInfo]:
        return self.handlers.get(method, [])

    def call_graph(self) -> Dict[int, List[Tuple[FunctionInfo, Optional[RpcEdge]]]]:
        """``id(func) -> [(callee, cross_edge_or_None)]``.

        Intra-process edges: ``self.<meth>()`` within the caller's class
        group and bare-name calls to module-level functions of the same
        module.  Cross-process edges: the resolved stub calls.
        """
        group_methods: Dict[int, Dict[str, List[FunctionInfo]]] = {}
        func_group: Dict[int, Dict[str, List[FunctionInfo]]] = {}
        for gi, group in enumerate(self.project.class_groups()):
            methods: Dict[str, List[FunctionInfo]] = {}
            for c in group:
                for f in c.functions.values():
                    if not f.is_nested:
                        methods.setdefault(f.name, []).append(f)
            group_methods[gi] = methods
            for fs in methods.values():
                for f in fs:
                    func_group[id(f)] = methods
        adj: Dict[int, List[Tuple[FunctionInfo, Optional[RpcEdge]]]] = {}
        for mod in self.project.modules.values():
            all_funcs = list(mod.functions.values()) + [
                f for c in mod.classes.values() for f in c.functions.values()
            ]
            for f in all_funcs:
                out = adj.setdefault(id(f), [])
                methods = func_group.get(id(f), {})
                for site in f.calls:
                    parts = site.name.split(".")
                    if len(parts) == 2 and parts[0] == "self" and parts[1] in methods:
                        out.extend((callee, None) for callee in methods[parts[1]])
                    elif len(parts) == 1 and parts[0] in mod.functions:
                        out.append((mod.functions[parts[0]], None))
        for edge in self.edges:
            out = adj.setdefault(id(edge.caller), [])
            out.extend((h, edge) for h in edge.handlers)
        return adj


def build_project(root: Path, skip_dirs: Tuple[str, ...] = ()) -> Project:
    """Parse every ``.py`` under ``root`` into a :class:`Project` model."""
    root = root.resolve()
    project = Project(root=root)
    for path in sorted(root.rglob("*.py")):
        rel = path.relative_to(root)
        if any(part in skip_dirs or part == "__pycache__" for part in rel.parts):
            continue
        try:
            tree = ast.parse(path.read_text(), filename=str(path))
        except SyntaxError:
            continue  # not our job; python/pytest will report it
        _ModuleCollector(project, rel.as_posix(), tree)
    return project
