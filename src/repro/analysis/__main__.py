"""CLI for the static-analysis suite.

    python -m repro.analysis                 # report all findings
    python -m repro.analysis --strict        # CI gate: exit 1 on NEW findings
                                             # or STALE baseline entries
    python -m repro.analysis --update-baseline
    python -m repro.analysis --timings       # per-pass wall seconds (stderr)
    python -m repro.analysis --root PATH     # analyze a different tree
                                             # (used by the seeded-divergence test)
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path
from typing import Dict

from . import default_baseline, default_root, run_analysis
from .findings import (
    SuppressionIndex,
    load_baseline,
    split_new,
    stale_entries,
    write_baseline,
)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: the installed src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: analysis/baseline.txt)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 on findings not baselined/suppressed, and "
                         "on stale baseline entries")
    ap.add_argument("--write-baseline", "--update-baseline",
                    dest="write_baseline", action="store_true",
                    help="accept all current findings into the baseline "
                         "(also drops stale entries)")
    ap.add_argument("--show-accepted", action="store_true",
                    help="also print baselined/suppressed findings")
    ap.add_argument("--timings", action="store_true",
                    help="print per-pass wall time to stderr")
    args = ap.parse_args(argv)

    root = (args.root or default_root()).resolve()
    baseline_path = args.baseline or default_baseline()

    timings: Dict[str, float] = {}
    findings = run_analysis(root, timings if args.timings else None)
    suppressions = SuppressionIndex.scan(root, sorted(root.rglob("*.py")))

    if args.write_baseline:
        kept = [f for f in findings if not suppressions.allows(f)]
        write_baseline(baseline_path, kept)
        print(f"wrote {len(kept)} finding(s) to {baseline_path}")
        return 0

    baseline = load_baseline(baseline_path)
    new, accepted = split_new(findings, baseline, suppressions)
    stale = stale_entries(baseline, findings)
    for f in new:
        print(f.render())
    for key in stale:
        print(f"stale baseline entry (no finding matches): {key}")
    if args.show_accepted:
        for f in accepted:
            print(f"[accepted] {f.render()}")
    if args.timings:
        total = sum(timings.values())
        per = "  ".join(f"{name}={dt * 1000:.0f}ms" for name, dt in timings.items())
        print(f"pass timings: {per}  total={total * 1000:.0f}ms", file=sys.stderr)
    summary = (
        f"{len(new)} new finding(s), {len(accepted)} accepted "
        f"(baseline/inline), {len(stale)} stale baseline entr"
        f"{'y' if len(stale) == 1 else 'ies'}"
    )
    print(summary, file=sys.stderr)
    if args.strict and (new or stale):
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
