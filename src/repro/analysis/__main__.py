"""CLI for the static-analysis suite.

    python -m repro.analysis                 # report all findings
    python -m repro.analysis --strict        # CI gate: exit 1 on NEW findings
    python -m repro.analysis --write-baseline
    python -m repro.analysis --root PATH     # analyze a different tree
                                             # (used by the seeded-divergence test)
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from . import analyze, default_baseline, default_root, run_analysis
from .findings import SuppressionIndex, write_baseline


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--root", type=Path, default=None,
                    help="tree to analyze (default: the installed src/repro)")
    ap.add_argument("--baseline", type=Path, default=None,
                    help="baseline file (default: analysis/baseline.txt)")
    ap.add_argument("--strict", action="store_true",
                    help="exit 1 if any finding is not baselined/suppressed")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the baseline")
    ap.add_argument("--show-accepted", action="store_true",
                    help="also print baselined/suppressed findings")
    args = ap.parse_args(argv)

    root = (args.root or default_root()).resolve()
    baseline_path = args.baseline or default_baseline()

    if args.write_baseline:
        findings = run_analysis(root)
        suppressions = SuppressionIndex.scan(root, sorted(root.rglob("*.py")))
        kept = [f for f in findings if not suppressions.allows(f)]
        write_baseline(baseline_path, kept)
        print(f"wrote {len(kept)} finding(s) to {baseline_path}")
        return 0

    new, accepted = analyze(root, baseline_path)
    for f in new:
        print(f.render())
    if args.show_accepted:
        for f in accepted:
            print(f"[accepted] {f.render()}")
    summary = f"{len(new)} new finding(s), {len(accepted)} accepted (baseline/inline)"
    print(summary, file=sys.stderr)
    if args.strict and new:
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
