"""Autocache: compute / write-through / read decisions per job.

The live ``SlidingWindowCache`` only helps jobs that OVERLAP in time;
materialization helps jobs separated in time — the compute-vs-cache trade
Cachew automates.  The policy keys on the pipeline content fingerprint
(the same key ephemeral sharing uses, §3.5) and decides per job:

* ``READ``          — a finished snapshot exists: consume it, skip the CPU.
* ``WRITE_THROUGH`` — compute AND materialize, so future jobs can READ.
* ``COMPUTE``       — just compute (snapshot in progress elsewhere, or the
                      expected reuse doesn't pay for the write).

The write-through call is an Eq.-1 (core.cost) comparison: materialize when
the preprocessing cost future jobs would re-pay exceeds the one-time write
overhead.  Observed sharing efficiency feeds in as a demand signal: worker
heartbeats surface SlidingWindowCache stats, and a fingerprint whose
batches are served far more often than produced is demonstrably hot —
jobs are already re-reading this pipeline, so persist it.
"""
from __future__ import annotations

import enum
import os
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Any, Dict, Optional

from .reader import snapshot_exists, snapshot_finished

if TYPE_CHECKING:  # runtime import is deferred (core<->snapshot import cycle)
    from ..core.cost import CostRates, JobResources  # noqa: F401


def _default_compute_resources():
    from ..core.cost import JobResources

    return JobResources(
        duration_hours=1.0,
        num_workers=4,
        worker_cpu_util_cores=6.0,
        worker_mem_util_gb=16.0,
        num_trainers=0,
        accelerators_per_trainer=0,
    )


class Decision(str, enum.Enum):
    COMPUTE = "compute"
    WRITE_THROUGH = "write_through"
    READ = "read"


@dataclass
class AutocacheConfig:
    # expected number of FUTURE jobs that would re-run this pipeline
    # (restarts, hparam sweeps, eval re-runs); the paper's fleet data and
    # 2501.10546 both put typical input-pipeline reuse well above 1.
    expected_future_jobs: float = 2.0
    # reading a snapshot costs roughly compute/read_speedup worker-CPU
    # (decompress + deserialize instead of the full pipeline).
    read_speedup: float = 4.0
    # one-time write overhead as a fraction of one compute pass (encode +
    # compress + fsync ride along with production).
    write_overhead_frac: float = 0.25
    # served/produced ratio above which a fingerprint counts as hot
    # (multiple jobs demonstrably consuming one pipeline's output).
    hot_share_ratio: float = 1.5
    # an unfinished snapshot with no manifest progress for this long is
    # considered abandoned (its deployment died and lost the journal) and
    # gets restarted instead of pinning the policy to COMPUTE forever
    stale_write_timeout_s: float = 3600.0
    # assumed resource profile of one compute pass, for the Eq.-1 comparison
    compute_resources: "JobResources" = field(default_factory=_default_compute_resources)


@dataclass
class AutocacheDecision:
    decision: Decision
    snapshot_path: str
    reason: str

    @property
    def value(self) -> str:
        return self.decision.value


class AutocachePolicy:
    def __init__(
        self,
        root: str,
        config: Optional[AutocacheConfig] = None,
        rates: Optional["CostRates"] = None,
    ):
        from ..core.cost import GCP_RATES

        self.root = root
        self.config = config or AutocacheConfig()
        self.rates = rates or GCP_RATES

    def path_for(self, fingerprint: str) -> str:
        return os.path.join(self.root, f"snap-{fingerprint}")

    # ------------------------------------------------------------------
    def decide(
        self,
        fingerprint: str,
        cache_stats: Optional[Dict[str, Any]] = None,
        resources: Optional["JobResources"] = None,
    ) -> AutocacheDecision:
        """Pick a mode for one job keyed by its pipeline fingerprint.

        ``cache_stats`` is the dispatcher's heartbeat-aggregated
        SlidingWindowCache counters for this fingerprint
        (produced/served/evicted/skipped), when ephemeral sharing has
        observed the pipeline before.
        """
        import time

        from .reader import last_progress_unix

        cfg = self.config
        path = self.path_for(fingerprint)
        if snapshot_finished(path):
            return AutocacheDecision(Decision.READ, path, "finished snapshot on disk")
        if snapshot_exists(path):
            # wall clock on purpose: last_progress_unix is a mtime written
            # by ANOTHER process, so only epoch time is comparable to it
            idle = time.time() - last_progress_unix(path)
            if idle > cfg.stale_write_timeout_s:
                # abandoned write (owning deployment died): restart it —
                # the dispatcher clears the stale directory on start
                return AutocacheDecision(
                    Decision.WRITE_THROUGH,
                    path,
                    f"unfinished write idle {idle:.0f}s > "
                    f"{cfg.stale_write_timeout_s:.0f}s: restarting",
                )
            # someone is actively materializing it: don't double-write; the
            # job computes (and shares ephemerally) while the write finishes
            return AutocacheDecision(
                Decision.COMPUTE, path, "snapshot write already in progress"
            )
        if cache_stats:
            produced = float(cache_stats.get("produced", 0))
            served = float(cache_stats.get("served", 0))
            if produced > 0 and served / produced >= cfg.hot_share_ratio:
                return AutocacheDecision(
                    Decision.WRITE_THROUGH,
                    path,
                    f"hot pipeline: served/produced={served / produced:.2f} "
                    f">= {cfg.hot_share_ratio}",
                )
        res = resources or cfg.compute_resources
        from ..core.cost import job_cost

        one_pass = job_cost(res, self.rates)
        compute_cost = one_pass["cpu_cost"] + one_pass["mem_cost"]
        read_cost = compute_cost / max(1.0, cfg.read_speedup)
        saved = cfg.expected_future_jobs * (compute_cost - read_cost)
        write_overhead = cfg.write_overhead_frac * compute_cost
        if saved > write_overhead:
            return AutocacheDecision(
                Decision.WRITE_THROUGH,
                path,
                f"expected saving ${saved:.4f} > write overhead ${write_overhead:.4f} "
                f"(Eq. 1, {cfg.expected_future_jobs:g} future jobs)",
            )
        return AutocacheDecision(
            Decision.COMPUTE,
            path,
            f"expected saving ${saved:.4f} <= write overhead ${write_overhead:.4f}",
        )
