"""On-disk layout of a materialized snapshot (distributed-FS friendly).

A snapshot persists the OUTPUT of a preprocessing pipeline — the batches a
worker would have served over the data plane — so later jobs and restarted
jobs skip the CPU work entirely (the production tf.data service's
materialization mode; cf. Cachew and the `snapshot` transformation of
tf.data).  Everything is plain files under one directory so any process
that can reach the shared filesystem can read it, with no dispatcher in
the loop:

    <snapshot_dir>/
      SNAPSHOT.json                    # immutable metadata, written at start
      DONE.json                        # committer's finalization marker
      streams/
        stream_00000/
          MANIFEST.json                # committed-chunk index (atomic rewrite)
          chunk_0000000000_000128.chk  # seq 0, 128 elements
          chunk_0000000001_000130.chk
          ...

Chunk files carry a magic header followed by ONE codec-compressed frame of
``data.elements.encode_elements`` — the exact framing + codec registry the
live data plane uses, so snapshot bytes and wire bytes share one code path.
Chunks become visible only on atomic commit: the writer stages to a
``.tmp-<nonce>`` sibling, fsyncs, renames, then rewrites the manifest.
Readers trust the MANIFEST (never a directory glob), so a half-written or
orphaned chunk file can never be observed.

Crash-safety contract: chunk content is a *deterministic* function of
(stream shards, stream seed, chunk_bytes) — pipelines re-seed stochastic
ops per stream, not per worker — so a replacement writer resuming a dead
worker's stream re-produces byte-identical chunks for any suffix the
dispatcher had not acknowledged.  Every commit race (stale tmp files,
re-written chunks, manifest rewrites racing a zombie writer) therefore
converges to identical bytes; manifests are merged by chunk seq on rewrite.
"""
from __future__ import annotations

import json
import os
import struct
import uuid
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

from ..data.elements import Element, decode_elements, encode_elements

# NOTE: repro.core imports this package from its own __init__ chain
# (dispatcher/worker), so core imports here must stay function-local to
# keep repro.snapshot importable from either direction.

SNAPSHOT_FORMAT_VERSION = 1

CHUNK_MAGIC = b"RSNP1\x00"
METADATA_FILE = "SNAPSHOT.json"
DONE_FILE = "DONE.json"
MANIFEST_FILE = "MANIFEST.json"
STREAMS_DIR = "streams"


@dataclass(frozen=True)
class ChunkRecord:
    """One committed chunk of a stream."""

    seq: int
    count: int  # elements in the chunk
    nbytes: int  # compressed payload bytes (for storage accounting)

    @property
    def filename(self) -> str:
        return f"chunk_{self.seq:010d}_{self.count:06d}.chk"

    def to_json(self) -> Dict[str, Any]:
        return {"seq": self.seq, "count": self.count, "nbytes": self.nbytes}

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "ChunkRecord":
        return ChunkRecord(int(d["seq"]), int(d["count"]), int(d.get("nbytes", 0)))


@dataclass
class StreamManifest:
    """Committed-chunk index for one stream. Atomically rewritten on commit."""

    stream_id: int
    chunks: List[ChunkRecord] = field(default_factory=list)
    done: bool = False

    @property
    def num_elements(self) -> int:
        return sum(c.count for c in self.chunks)

    def to_json(self) -> Dict[str, Any]:
        return {
            "stream_id": self.stream_id,
            "done": self.done,
            "chunks": [c.to_json() for c in sorted(self.chunks, key=lambda c: c.seq)],
        }

    @staticmethod
    def from_json(d: Dict[str, Any]) -> "StreamManifest":
        return StreamManifest(
            stream_id=int(d["stream_id"]),
            chunks=[ChunkRecord.from_json(c) for c in d.get("chunks", [])],
            done=bool(d.get("done", False)),
        )


# ---------------------------------------------------------------------------
# Path helpers
# ---------------------------------------------------------------------------
def stream_dir(root: str, stream_id: int) -> str:
    return os.path.join(root, STREAMS_DIR, f"stream_{stream_id:05d}")

def chunk_path(root: str, stream_id: int, rec: ChunkRecord) -> str:
    return os.path.join(stream_dir(root, stream_id), rec.filename)

def metadata_path(root: str) -> str:
    return os.path.join(root, METADATA_FILE)

def done_path(root: str) -> str:
    return os.path.join(root, DONE_FILE)

def manifest_path(root: str, stream_id: int) -> str:
    return os.path.join(stream_dir(root, stream_id), MANIFEST_FILE)


# ---------------------------------------------------------------------------
# Atomic small-file writes (metadata / manifests / DONE marker)
# ---------------------------------------------------------------------------
def _write_json_atomic(path: str, payload: Dict[str, Any]) -> None:
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    tmp = f"{path}.tmp-{uuid.uuid4().hex[:8]}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)


def _read_json(path: str) -> Optional[Dict[str, Any]]:
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError):
        return None


# ---------------------------------------------------------------------------
# Snapshot-level metadata
# ---------------------------------------------------------------------------
def write_metadata(
    root: str,
    snapshot_id: str,
    fingerprint: str,
    codec: Optional[str],
    chunk_bytes: int,
    num_streams: int,
    seed_base: int,
    created_unix: float,
) -> None:
    # created_unix is the caller's clock, not ours: the dispatcher mints it
    # once when the snapshot is journaled and passes the SAME value on
    # replay, so a standby re-writing this file reproduces it byte-for-byte
    # instead of clobbering the primary's timestamp
    _write_json_atomic(
        metadata_path(root),
        {
            "version": SNAPSHOT_FORMAT_VERSION,
            "snapshot_id": snapshot_id,
            "fingerprint": fingerprint,
            "codec": codec,
            "chunk_bytes": chunk_bytes,
            "num_streams": num_streams,
            "seed_base": seed_base,
            "created_unix": created_unix,
        },
    )


def read_metadata(root: str) -> Optional[Dict[str, Any]]:
    return _read_json(metadata_path(root))


def write_done(root: str, summary: Dict[str, Any]) -> None:
    _write_json_atomic(done_path(root), dict(summary, finished=True))


def read_done(root: str) -> Optional[Dict[str, Any]]:
    return _read_json(done_path(root))


# ---------------------------------------------------------------------------
# Manifests
# ---------------------------------------------------------------------------
def read_manifest(root: str, stream_id: int) -> StreamManifest:
    d = _read_json(manifest_path(root, stream_id))
    if d is None:
        return StreamManifest(stream_id=stream_id)
    return StreamManifest.from_json(d)


def write_manifest(root: str, manifest: StreamManifest) -> None:
    """Atomically rewrite a stream manifest, MERGING with the on-disk copy.

    The merge (union by chunk seq, done is sticky) makes concurrent rewrites
    by a zombie writer and its replacement commute: chunk content is
    deterministic, so entries for the same seq are interchangeable and the
    union never loses a committed chunk.
    """
    existing = read_manifest(root, manifest.stream_id)
    by_seq = {c.seq: c for c in existing.chunks}
    by_seq.update({c.seq: c for c in manifest.chunks})
    merged = StreamManifest(
        stream_id=manifest.stream_id,
        chunks=[by_seq[s] for s in sorted(by_seq)],
        done=manifest.done or existing.done,
    )
    _write_json_atomic(manifest_path(root, manifest.stream_id), merged.to_json())


# ---------------------------------------------------------------------------
# Chunk files
# ---------------------------------------------------------------------------
def frame_encoded(encoded: List[bytes]) -> bytes:
    """Assemble an ``encode_elements``-identical frame from pre-encoded
    elements (the writer sizes each element at append time; re-encoding the
    whole buffer at commit would double the serialization CPU)."""
    parts = [struct.pack("<I", len(encoded))]
    for b in encoded:
        parts.append(struct.pack("<I", len(b)))
        parts.append(b)
    return b"".join(parts)


def write_chunk(
    root: str,
    stream_id: int,
    seq: int,
    elements: List[Element],
    codec: Optional[str],
    encoded: Optional[List[bytes]] = None,
) -> ChunkRecord:
    """Stage, fsync, and atomically commit one chunk file.

    Returns the ChunkRecord the caller must add to the manifest — the chunk
    is invisible to readers until the manifest names it.  ``encoded``
    supplies the elements pre-serialized (same order as ``elements``) so
    callers that already encoded them don't pay twice.
    """
    from ..core.codecs import compress  # deferred: avoid core<->snapshot cycle

    count = len(encoded if encoded is not None else elements)
    frame = frame_encoded(encoded) if encoded is not None else encode_elements(elements)
    payload = compress(frame, codec)
    rec = ChunkRecord(seq=seq, count=count, nbytes=len(payload))
    final = chunk_path(root, stream_id, rec)
    os.makedirs(os.path.dirname(final), exist_ok=True)
    tmp = f"{final}.tmp-{uuid.uuid4().hex[:8]}"
    with open(tmp, "wb") as f:
        f.write(CHUNK_MAGIC)
        f.write(struct.pack("<I", len(payload)))
        f.write(payload)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, final)
    return rec


def read_chunk(path: str) -> List[Element]:
    from ..core.codecs import decompress  # deferred: avoid core<->snapshot cycle

    with open(path, "rb") as f:
        magic = f.read(len(CHUNK_MAGIC))
        if magic != CHUNK_MAGIC:
            raise ValueError(f"{path}: not a snapshot chunk file")
        (n,) = struct.unpack("<I", f.read(4))
        payload = f.read(n)
        if len(payload) < n:
            raise ValueError(f"{path}: truncated chunk payload")
    return decode_elements(decompress(payload))


def clean_stale_tmp(root: str, stream_id: int) -> int:
    """Remove staged-but-never-committed files left by a dead writer."""
    d = stream_dir(root, stream_id)
    removed = 0
    if not os.path.isdir(d):
        return 0
    for name in os.listdir(d):
        if ".tmp-" in name:
            try:
                os.remove(os.path.join(d, name))
                removed += 1
            except OSError:
                pass
    return removed
