"""Worker-side snapshot stream writer.

A ``StreamWriter`` turns a stream of pipeline elements into size-bounded,
atomically-committed chunk files.  Commit order per chunk:

  1. stage + fsync + rename the chunk file       (format.write_chunk)
  2. rewrite the stream MANIFEST naming it        (format.write_manifest)
  3. report the commit to the committer via the ``on_commit`` callback
     (the dispatcher journals it; a False return means the stream was
     reassigned away from this writer — stop immediately)

Local-commit-before-report means a crash between (2) and (3) leaves the
manifest AHEAD of the dispatcher's journal; the replacement writer then
re-produces the unacknowledged suffix deterministically and the manifest
merge converges (see format.py's crash-safety contract).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, List, Optional

from ..data.elements import Element, encode_element
from .format import (
    ChunkRecord,
    StreamManifest,
    clean_stale_tmp,
    write_chunk,
    write_manifest,
)


class StreamReassigned(RuntimeError):
    """The committer no longer recognizes this writer as the stream owner."""


@dataclass
class WriterStats:
    elements: int = 0
    chunks: int = 0
    bytes_written: int = 0


class StreamWriter:
    def __init__(
        self,
        root: str,
        stream_id: int,
        codec: Optional[str] = None,
        chunk_bytes: int = 1 << 20,
        committed: Optional[List[ChunkRecord]] = None,
        on_commit: Optional[Callable[[ChunkRecord], bool]] = None,
    ):
        self._root = root
        self._stream_id = stream_id
        self._codec = codec
        self._chunk_bytes = max(1, int(chunk_bytes))
        # resume support: the committed prefix (from the dispatcher's journal)
        # fixes the next chunk seq; the caller skips the already-committed
        # element prefix before appending.
        self._committed: List[ChunkRecord] = list(committed or [])
        self._on_commit = on_commit
        self._pending: List[bytes] = []  # elements pre-encoded at append time
        self._pending_bytes = 0
        self.stats = WriterStats()
        clean_stale_tmp(root, stream_id)

    @property
    def next_seq(self) -> int:
        return self._committed[-1].seq + 1 if self._committed else 0

    @property
    def elements_committed(self) -> int:
        return sum(c.count for c in self._committed)

    # ------------------------------------------------------------------
    def append(self, elem: Element) -> Optional[ChunkRecord]:
        """Buffer one element; commit a chunk when the size bound is hit.

        Chunk boundaries depend only on the element stream and
        ``chunk_bytes`` (the encoded size is deterministic), which is what
        lets a resumed stream re-produce identical chunks.  Elements are
        encoded ONCE here; the commit assembles the chunk frame from the
        stored bytes.
        """
        enc = encode_element(elem)
        self._pending.append(enc)
        self._pending_bytes += len(enc)
        self.stats.elements += 1
        if self._pending_bytes >= self._chunk_bytes:
            return self._commit_chunk()
        return None

    def finish(self) -> StreamManifest:
        """Commit any partial tail chunk and mark the stream done."""
        if self._pending:
            self._commit_chunk()
        manifest = StreamManifest(
            stream_id=self._stream_id, chunks=list(self._committed), done=True
        )
        write_manifest(self._root, manifest)
        return manifest

    def abort(self) -> None:
        """Drop uncommitted buffered elements (worker shutting down)."""
        self._pending.clear()
        self._pending_bytes = 0

    # ------------------------------------------------------------------
    def _commit_chunk(self) -> ChunkRecord:
        rec = write_chunk(
            self._root, self._stream_id, self.next_seq, [], self._codec,
            encoded=self._pending,
        )
        self._committed.append(rec)
        self._pending.clear()
        self._pending_bytes = 0
        self.stats.chunks += 1
        self.stats.bytes_written += rec.nbytes
        write_manifest(
            self._root,
            StreamManifest(stream_id=self._stream_id, chunks=list(self._committed)),
        )
        if self._on_commit is not None and not self._on_commit(rec):
            raise StreamReassigned(
                f"stream {self._stream_id}: committer rejected chunk {rec.seq}"
            )
        return rec
