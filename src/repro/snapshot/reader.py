"""Snapshot read path: committed chunks as a first-class dataset source.

Readers need only the shared filesystem — no dispatcher.  Two modes:

* **finished snapshot** — iterate every committed chunk; with a service job
  on top, ``list_snapshot_shards`` exposes chunk-granularity shards so the
  DYNAMIC policy load-balances chunks across workers exactly like source
  files (paper §3.3), and ``resume_offsets`` element-offset recovery works
  unchanged (offsets index into a chunk's element list).
* **tail mode** — a job may consume a snapshot MID-WRITE: read all chunks
  committed so far, then poll the manifests for newly committed chunks
  until the committer's DONE marker appears.  Chunks are interleaved
  round-robin across streams (order across streams is unspecified — the
  paper's relaxed-visitation stance).
"""
from __future__ import annotations

import os
import time
from typing import Any, Dict, Iterator, List, Optional

from ..data.elements import Element
from .format import (
    ChunkRecord,
    chunk_path,
    read_chunk,
    read_done,
    read_manifest,
    read_metadata,
)


def snapshot_exists(root: str) -> bool:
    return read_metadata(root) is not None


def last_progress_unix(root: str) -> float:
    """Wall time of the newest metadata/manifest write under ``root``.

    The staleness signal for unfinished snapshots: manifests are rewritten
    on every chunk commit, so an idle mtime means no writer is making
    progress (e.g. the owning deployment died and lost its journal).
    Returns 0.0 when nothing is on disk.
    """
    meta = read_metadata(root)
    if meta is None:
        return 0.0
    from .format import manifest_path, metadata_path

    latest = 0.0
    candidates = [metadata_path(root)]
    for sid in range(int(meta.get("num_streams", 0))):
        candidates.append(manifest_path(root, sid))
    for p in candidates:
        try:
            latest = max(latest, os.path.getmtime(p))
        except OSError:
            continue
    return latest


def snapshot_finished(root: str) -> bool:
    return read_done(root) is not None


def snapshot_status(root: str) -> Dict[str, Any]:
    """Point-in-time view assembled purely from on-disk state."""
    meta = read_metadata(root)
    if meta is None:
        return {"exists": False, "finished": False, "streams": [], "elements": 0}
    streams = []
    total_elements = total_chunks = total_bytes = 0
    for sid in range(int(meta.get("num_streams", 0))):
        m = read_manifest(root, sid)
        streams.append(
            {
                "stream_id": sid,
                "done": m.done,
                "chunks": len(m.chunks),
                "elements": m.num_elements,
            }
        )
        total_elements += m.num_elements
        total_chunks += len(m.chunks)
        total_bytes += sum(c.nbytes for c in m.chunks)
    return {
        "exists": True,
        "finished": snapshot_finished(root),
        "fingerprint": meta.get("fingerprint"),
        "codec": meta.get("codec"),
        "num_streams": int(meta.get("num_streams", 0)),
        "streams": streams,
        "elements": total_elements,
        "chunks": total_chunks,
        "bytes": total_bytes,
    }


def committed_chunks(root: str, stream_id: int) -> List[ChunkRecord]:
    return read_manifest(root, stream_id).chunks


def list_snapshot_shards(root: str) -> List[Dict[str, Any]]:
    """Chunk-granularity shard descriptors for the dispatcher.

    For a FINISHED snapshot this is the complete, stable element set.  For
    an in-progress snapshot it is the committed prefix at call time — a
    sharded job sees a point-in-time cut; use tail mode (a non-sharded
    read) to follow a live write.
    """
    meta = read_metadata(root)
    if meta is None:
        raise FileNotFoundError(f"no snapshot at {root}")
    shards: List[Dict[str, Any]] = []
    for sid in range(int(meta.get("num_streams", 0))):
        for rec in committed_chunks(root, sid):
            shards.append(
                {
                    "kind": "snapshot_chunk",
                    "path": chunk_path(root, sid, rec),
                    "stream": sid,
                    "seq": rec.seq,
                    "count": rec.count,
                }
            )
    return shards


def iterate_snapshot(
    root: str,
    tail: bool = False,
    poll_interval: float = 0.05,
    timeout: Optional[float] = None,
) -> Iterator[Element]:
    """Yield every element of a snapshot, interleaving streams round-robin.

    ``tail=True`` keeps polling for new chunks while the snapshot is being
    written, returning once the DONE marker appears and all committed
    chunks have been drained.  ``timeout`` bounds the total wait for a
    tailing read (None = wait forever).
    """
    meta = read_metadata(root)
    if meta is None:
        raise FileNotFoundError(f"no snapshot at {root}")
    num_streams = int(meta.get("num_streams", 0))
    next_seq = [0] * num_streams  # next chunk seq to read per stream
    deadline = time.monotonic() + timeout if timeout is not None else None
    while True:
        progressed = False
        all_done = True
        for sid in range(num_streams):
            m = read_manifest(root, sid)
            by_seq = {c.seq: c for c in m.chunks}
            while next_seq[sid] in by_seq:
                rec = by_seq[next_seq[sid]]
                yield from read_chunk(chunk_path(root, sid, rec))
                next_seq[sid] += 1
                progressed = True
            if not m.done or next_seq[sid] < len(m.chunks):
                all_done = False
        if snapshot_finished(root) or (all_done and not tail):
            # drain any chunks committed between the stream scan and the
            # DONE check, then stop
            for sid in range(num_streams):
                m = read_manifest(root, sid)
                by_seq = {c.seq: c for c in m.chunks}
                while next_seq[sid] in by_seq:
                    rec = by_seq[next_seq[sid]]
                    yield from read_chunk(chunk_path(root, sid, rec))
                    next_seq[sid] += 1
            return
        if not tail:
            return  # in-progress snapshot, point-in-time read
        if not progressed:
            if deadline is not None and time.monotonic() > deadline:
                raise TimeoutError(f"tailing {root}: no progress before timeout")
            time.sleep(poll_interval)
