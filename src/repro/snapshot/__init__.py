"""repro.snapshot — distributed snapshot & materialization.

Persists preprocessed batches to chunked, codec-compressed shard files on
shared storage and serves them back as a first-class dataset source, so
later jobs and restarted jobs skip redundant CPU work entirely (the
production tf.data service's materialization mode; cf. Cachew and
tf.data's `snapshot` transformation).

Layers:
  format   — on-disk chunk/manifest/metadata formats (atomic commits)
  writer   — worker-side size-bounded chunk writer with resume support
  reader   — committed-chunk iteration, tail-the-live-write, shard listing
  manager  — dispatcher-side stream partitioning/assignment/commit state
  policy   — autocache: compute vs write-through vs read, via core.cost
"""
from .format import (
    ChunkRecord,
    StreamManifest,
    read_chunk,
    read_manifest,
    read_metadata,
    write_chunk,
    write_manifest,
    write_metadata,
)
from .manager import SnapshotState, StreamState, partition_streams
from .policy import AutocacheConfig, AutocacheDecision, AutocachePolicy, Decision
from .reader import (
    iterate_snapshot,
    list_snapshot_shards,
    snapshot_exists,
    snapshot_finished,
    snapshot_status,
)
from .writer import StreamReassigned, StreamWriter

__all__ = [
    "AutocacheConfig",
    "AutocacheDecision",
    "AutocachePolicy",
    "ChunkRecord",
    "Decision",
    "SnapshotState",
    "StreamManifest",
    "StreamReassigned",
    "StreamState",
    "StreamWriter",
    "iterate_snapshot",
    "list_snapshot_shards",
    "partition_streams",
    "read_chunk",
    "read_manifest",
    "read_metadata",
    "snapshot_exists",
    "snapshot_finished",
    "snapshot_status",
    "write_chunk",
    "write_manifest",
    "write_metadata",
]
