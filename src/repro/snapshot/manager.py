"""Dispatcher-side snapshot bookkeeping (the committer / metadata layer).

The dispatcher partitions a snapshot into ``num_streams`` streams (each a
round-robin slice of the source's shards), assigns streams to workers, and
acknowledges chunk commits.  Every state change is journaled through the
dispatcher's write-ahead journal BEFORE it is applied, so a restarted
dispatcher recovers exactly which chunks were acknowledged, which streams
are done, and which worker owns each stream — the snapshot-specific
analogue of the job/shard recovery in §3.4.

This module is deliberately dispatcher-agnostic: pure state + transition
helpers, with the Dispatcher wiring them to RPCs, the journal, and the
heartbeat/failure machinery.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple

from ..data.graph import Graph
from ..data.sources import list_shards
from .format import ChunkRecord


@dataclass
class StreamState:
    stream_id: int
    shards: List[Dict[str, Any]]
    assigned_to: Optional[str] = None  # worker_id
    committed: List[Tuple[int, int, int]] = field(default_factory=list)  # (seq, count, nbytes)
    done: bool = False

    @property
    def elements_committed(self) -> int:
        return sum(count for _, count, _ in self.committed)

    @property
    def next_seq(self) -> int:
        return self.committed[-1][0] + 1 if self.committed else 0


@dataclass
class SnapshotState:
    snapshot_id: str
    path: str
    dataset_id: str
    fingerprint: str
    codec: Optional[str]
    chunk_bytes: int
    seed_base: int
    streams: List[StreamState] = field(default_factory=list)
    finished: bool = False

    # -- queries -----------------------------------------------------------
    @property
    def all_streams_done(self) -> bool:
        return bool(self.streams) and all(s.done for s in self.streams)

    def undone_streams(self) -> List[StreamState]:
        return [s for s in self.streams if not s.done]

    def streams_for_worker(self, worker_id: str) -> List[StreamState]:
        return [
            s for s in self.streams if s.assigned_to == worker_id and not s.done
        ]

    def view(self) -> Dict[str, Any]:
        return {
            "snapshot_id": self.snapshot_id,
            "path": self.path,
            "dataset_id": self.dataset_id,
            "fingerprint": self.fingerprint,
            "codec": self.codec,
            "finished": self.finished,
            "num_streams": len(self.streams),
            "streams": [
                {
                    "stream_id": s.stream_id,
                    "assigned_to": s.assigned_to,
                    "done": s.done,
                    "chunks": len(s.committed),
                    "elements": s.elements_committed,
                }
                for s in self.streams
            ],
        }

    def summary(self) -> Dict[str, Any]:
        return {
            "snapshot_id": self.snapshot_id,
            "fingerprint": self.fingerprint,
            "num_streams": len(self.streams),
            "chunks": sum(len(s.committed) for s in self.streams),
            "elements": sum(s.elements_committed for s in self.streams),
        }

    # -- wire payload for a worker's stream assignment ----------------------
    def stream_spec(self, stream: StreamState, graph_bytes: bytes) -> Dict[str, Any]:
        """Everything a worker needs to (re)start writing one stream.

        ``resume_offset``/``next_seq``/``committed`` come from the journal:
        a replacement worker skips the acknowledged element prefix and
        continues the chunk sequence without duplicating committed chunks.
        """
        return {
            "snapshot_id": self.snapshot_id,
            "path": self.path,
            "stream_id": stream.stream_id,
            "graph_bytes": graph_bytes,
            "shards": [dict(sh) for sh in stream.shards],
            "codec": self.codec,
            "chunk_bytes": self.chunk_bytes,
            "seed": self.seed_base + stream.stream_id,
            "resume_offset": stream.elements_committed,
            "next_seq": stream.next_seq,
            "committed": list(stream.committed),
        }

    # -- journal (de)hydration ----------------------------------------------
    def to_payload(self) -> Dict[str, Any]:
        return {
            "snapshot_id": self.snapshot_id,
            "path": self.path,
            "dataset_id": self.dataset_id,
            "fingerprint": self.fingerprint,
            "codec": self.codec,
            "chunk_bytes": self.chunk_bytes,
            "seed_base": self.seed_base,
            "finished": self.finished,
            "streams": [
                {
                    "stream_id": s.stream_id,
                    "shards": s.shards,
                    "assigned_to": s.assigned_to,
                    "committed": list(s.committed),
                    "done": s.done,
                }
                for s in self.streams
            ],
        }

    @staticmethod
    def from_payload(p: Dict[str, Any]) -> "SnapshotState":
        return SnapshotState(
            snapshot_id=p["snapshot_id"],
            path=p["path"],
            dataset_id=p["dataset_id"],
            fingerprint=p["fingerprint"],
            codec=p.get("codec"),
            chunk_bytes=p["chunk_bytes"],
            seed_base=p.get("seed_base", 0),
            finished=p.get("finished", False),
            streams=[
                StreamState(
                    stream_id=s["stream_id"],
                    shards=s["shards"],
                    assigned_to=s.get("assigned_to"),
                    committed=[tuple(c) for c in s.get("committed", [])],
                    done=s.get("done", False),
                )
                for s in p.get("streams", [])
            ],
        )


def partition_streams(
    graph: Graph, num_streams: int, overpartition: int = 4
) -> List[List[Dict[str, Any]]]:
    """Slice the source's shards round-robin into ``num_streams`` streams.

    Over-partitioning the source (more shards than streams) keeps stream
    sizes balanced for uneven sources, mirroring the dispatcher's shard
    hand-out hint (§3.3).  Streams may come out empty for tiny sources —
    the writer then just commits an empty stream.
    """
    num_streams = max(1, num_streams)
    src = graph.source
    shards = list_shards(
        src.params, src.op, num_shards_hint=num_streams * max(1, overpartition)
    )
    return [shards[i::num_streams] for i in range(num_streams)]


def apply_chunk_committed(stream: StreamState, seq: int, count: int, nbytes: int) -> bool:
    """Idempotently record an acknowledged chunk. Returns False on a gap
    (a commit for a seq later than the next expected — caller bug or a
    writer that desynced from the journal; reject so it resets)."""
    if seq < stream.next_seq:
        return True  # duplicate ack (redelivered report) — already recorded
    if seq != stream.next_seq:
        return False
    stream.committed.append((seq, count, nbytes))
    return True


def chunk_records(stream: StreamState) -> List[ChunkRecord]:
    return [ChunkRecord(seq, count, nbytes) for seq, count, nbytes in stream.committed]
