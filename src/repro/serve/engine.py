"""Serving layer: batched KV-cache decoding.

``make_serve_step(model)`` builds the pure one-token step lowered in the
dry-run's decode cells (a single new token against a seq_len-deep cache).
``ServeEngine`` is the small-scale runnable engine used by examples: batched
greedy/temperature decoding with continuous batching slots fed by the data
service (requests are preprocessed prompts — the paper's serving story is
the same disaggregated feed).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import Model


def make_serve_step(model: Model) -> Callable:
    """Pure decode step: (params, cache, tokens(B,)) -> (next_tokens, cache)."""

    def step(params: Any, cache: Dict[str, Any], tokens: jnp.ndarray):
        logits, cache = model.decode_step(params, cache, tokens)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), cache

    return step


@dataclass
class Request:
    prompt: List[int]
    max_new_tokens: int = 16
    generated: List[int] = field(default_factory=list)
    done: bool = False


class ServeEngine:
    """Minimal batched decoder with static slots (example/test scale)."""

    def __init__(self, model: Model, params: Any, batch_size: int, max_seq: int):
        self.model = model
        self.params = params
        self.B = batch_size
        self.max_seq = max_seq
        if model.cfg.family == "encdec":
            raise NotImplementedError("ServeEngine drives decoder-only models")
        self.cache = model.init_cache(batch_size, max_seq)
        self._step = jax.jit(make_serve_step(model))
        self.slots: List[Optional[Request]] = [None] * batch_size

    def admit(self, req: Request) -> bool:
        for i, s in enumerate(self.slots):
            if s is None:
                self.slots[i] = req
                return True
        return False

    def run(self, requests: List[Request]) -> List[Request]:
        """Prefill via repeated decode (token-at-a-time) then generate."""
        pending = list(requests)
        for r in pending:
            if not self.admit(r):
                raise RuntimeError("batch full")
        # teacher-force prompts token by token (simple; prefill fusion is the
        # model.forward path, exercised separately)
        max_prompt = max(len(r.prompt) for r in pending)
        tokens = jnp.zeros((self.B,), jnp.int32)
        for t in range(max_prompt + max(r.max_new_tokens for r in pending)):
            feed = []
            for i, r in enumerate(self.slots):
                if r is None:
                    feed.append(0)
                elif t < len(r.prompt):
                    feed.append(r.prompt[t])
                elif not r.done:
                    feed.append(r.generated[-1] if r.generated else r.prompt[-1])
                else:
                    feed.append(0)
            nxt, self.cache = self._step(
                self.params, self.cache, jnp.asarray(feed, jnp.int32)
            )
            nxt_np = jax.device_get(nxt)
            for i, r in enumerate(self.slots):
                if r is None or r.done:
                    continue
                if t >= len(r.prompt) - 1:
                    r.generated.append(int(nxt_np[i]))
                    if len(r.generated) >= r.max_new_tokens:
                        r.done = True
            if all(r is None or r.done for r in self.slots):
                break
        return pending
