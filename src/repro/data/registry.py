"""Named-function registry for serializable pipeline graphs.

The dispatcher ships dataset *definitions* (not code) to workers, mirroring
tf.data service shipping a GraphDef.  User-defined transformations therefore
must be referenceable by name: workers resolve ``registry:<name>`` against the
same module import, which is how production systems (TF, Beam) handle UDFs.

Closures are still supported for in-process execution via a pickle fallback —
``FnRef.from_callable`` picks the strongest representation available.
"""
from __future__ import annotations

import importlib
import pickle
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional, Tuple

_REGISTRY: Dict[str, Callable] = {}
# Process-local stash for unpicklable callables (lambdas/closures) used with
# in-process deployments; see FnRef.__getstate__.  Tokens are memoized per
# function object so repeated serializations of the same pipeline yield
# identical bytes (content fingerprints must be stable for data sharing).
_LOCAL_FNS: Dict[str, Callable] = {}
_LOCAL_TOKENS: Dict[int, str] = {}


def register(name: str) -> Callable[[Callable], Callable]:
    """Decorator: register a function under a stable name."""

    def deco(fn: Callable) -> Callable:
        if name in _REGISTRY and _REGISTRY[name] is not fn:
            raise ValueError(f"function name already registered: {name}")
        _REGISTRY[name] = fn
        fn.__registry_name__ = name
        return fn

    return deco


def lookup(name: str) -> Callable:
    if name in _REGISTRY:
        return _REGISTRY[name]
    if name.startswith("__local__/"):
        fn = _LOCAL_FNS.get(name)
        if fn is None:
            raise KeyError(
                "pipeline function was defined in another process and is not "
                "serializable — register it with @repro.data.register(name) "
                "to ship it to remote workers"
            )
        return fn
    # Allow fully-qualified "module:attr" references that self-register on import.
    if ":" in name:
        mod, attr = name.split(":", 1)
        fn = importlib.import_module(mod)
        for part in attr.split("."):
            fn = getattr(fn, part)
        return fn  # type: ignore[return-value]
    raise KeyError(f"unknown registered function: {name}")


@dataclass
class FnRef:
    """A serializable reference to a transformation function.

    One of ``name`` (registry / module path), ``payload`` (pickled callable)
    or ``fn`` (direct in-process reference; serialized lazily) is set.
    ``kwargs`` are bound keyword arguments, letting a single registered
    function serve parameterized transforms (the common production pattern:
    config in the graph, code on the worker).

    Lambdas/closures work in-process; shipping them across processes requires
    them to be picklable (registered/module-level functions always are).
    """

    name: Optional[str] = None
    payload: Optional[bytes] = None
    kwargs: Tuple[Tuple[str, Any], ...] = ()
    fn: Optional[Callable] = None  # transient; dropped on serialization

    @staticmethod
    def from_callable(fn: Callable, **kwargs: Any) -> "FnRef":
        kw = tuple(sorted(kwargs.items()))
        name = getattr(fn, "__registry_name__", None)
        if name is not None:
            return FnRef(name=name, kwargs=kw)
        if (
            getattr(fn, "__module__", None)
            and getattr(fn, "__qualname__", "")
            and "<locals>" not in fn.__qualname__
            and "<lambda>" not in fn.__qualname__
        ):
            return FnRef(name=f"{fn.__module__}:{fn.__qualname__}", kwargs=kw)
        # Closure/lambda: keep the direct reference; pickle only if shipped.
        return FnRef(fn=fn, kwargs=kw)

    def __deepcopy__(self, memo: dict) -> "FnRef":
        # Functions are immutable — share the reference on graph copies so
        # in-process lambdas survive optimizer passes / shard binding.
        return FnRef(self.name, self.payload, self.kwargs, self.fn)

    def __copy__(self) -> "FnRef":
        return self.__deepcopy__({})

    def __getstate__(self) -> dict:
        name, payload = self.name, self.payload
        if name is None and payload is None:
            assert self.fn is not None
            try:
                payload = pickle.dumps(self.fn, protocol=pickle.HIGHEST_PROTOCOL)
            except Exception:
                # Same-process fallback: stash the callable in a process-local
                # side table (works for in-proc deployments / local workers;
                # a remote process resolving this token gets a clear error).
                key = id(self.fn)
                token = _LOCAL_TOKENS.get(key)
                if token is None or _LOCAL_FNS.get(token) is not self.fn:
                    import uuid

                    token = f"__local__/{uuid.uuid4().hex}"
                    _LOCAL_TOKENS[key] = token
                    _LOCAL_FNS[token] = self.fn
                name = token
        return {"name": name, "payload": payload, "kwargs": self.kwargs}

    def __setstate__(self, state: dict) -> None:
        self.name = state["name"]
        self.payload = state["payload"]
        self.kwargs = state["kwargs"]
        self.fn = None

    def resolve(self) -> Callable:
        if self.fn is not None:
            fn = self.fn
        elif self.name is not None:
            fn = lookup(self.name)
        else:
            assert self.payload is not None
            fn = pickle.loads(self.payload)
        if self.kwargs:
            bound = dict(self.kwargs)

            def wrapped(*args: Any) -> Any:
                return fn(*args, **bound)

            return wrapped
        return fn

    def describe(self) -> str:
        if self.name:
            return self.name
        if self.fn is not None:
            return getattr(self.fn, "__qualname__", "<callable>")
        return "<pickled>"
