"""Static graph optimization passes (paper §3.2).

Before a client registers an input pipeline with the dispatcher it is run
through these passes — the same set tf.data applies: dead-transformation
elimination, map/map and map/filter fusion, and transparent prefetch
injection.  Passes are pure Graph→Graph functions, individually testable.
"""
from __future__ import annotations

from typing import Callable, List

from .graph import AUTOTUNE, Graph, Node
from .registry import FnRef

Pass = Callable[[Graph], Graph]


def _fuse_callables(f_ref: FnRef, g_ref: FnRef) -> FnRef:
    f, g = f_ref.resolve(), g_ref.resolve()

    def fused(x):
        return g(f(x))

    return FnRef(fn=fused)


def fuse_maps(graph: Graph) -> Graph:
    """map(f) -> map(g)  ==>  map(g∘f).

    Fusing removes one hop of per-element dispatch overhead.  Parallelism of
    the fused op is the max of the two (AUTOTUNE wins if either is AUTOTUNE);
    stochastic ops keep their flag so re-seeding still reaches them.
    """
    nodes: List[Node] = []
    for node in graph.nodes:
        if nodes and node.op == "map" and nodes[-1].op == "map":
            prev = nodes[-1]
            p_par = prev.params.get("num_parallel_calls", 0)
            n_par = node.params.get("num_parallel_calls", 0)
            par = AUTOTUNE if AUTOTUNE in (p_par, n_par) else max(p_par, n_par)
            nodes[-1] = Node(
                "map",
                {
                    "fn": _fuse_callables(prev.params["fn"], node.params["fn"]),
                    "num_parallel_calls": par,
                    "stochastic": prev.params.get("stochastic", False)
                    or node.params.get("stochastic", False),
                },
            )
        else:
            nodes.append(node.copy())
    return Graph(nodes)


def fuse_map_filter(graph: Graph) -> Graph:
    """map(f) -> filter(p)  ==>  fused op evaluating p(f(x)) in one dispatch.

    Implemented as a flat_map returning [] or [f(x)] — one pass over the data,
    no intermediate hand-off between two python generators.
    """
    nodes: List[Node] = []
    for node in graph.nodes:
        if (
            nodes
            and node.op == "filter"
            and nodes[-1].op == "map"
            and not nodes[-1].params.get("num_parallel_calls")
        ):
            f = nodes[-1].params["fn"].resolve()
            p = node.params["fn"].resolve()

            def fused(x, _f=f, _p=p):
                y = _f(x)
                return [y] if _p(y) else []

            nodes[-1] = Node("flat_map", {"fn": FnRef(fn=fused)})
        else:
            nodes.append(node.copy())
    return Graph(nodes)


def eliminate_dead(graph: Graph) -> Graph:
    """Drop no-op transformations: take/skip(0)... prefetch->prefetch merges."""
    nodes: List[Node] = []
    for node in graph.nodes:
        if node.op == "skip" and int(node.params.get("count", 0)) == 0:
            continue
        if node.op == "prefetch" and nodes and nodes[-1].op == "prefetch":
            # consecutive prefetches: keep the larger buffer (AUTOTUNE dominates)
            a = nodes[-1].params.get("buffer_size", 2)
            b = node.params.get("buffer_size", 2)
            nodes[-1].params["buffer_size"] = (
                AUTOTUNE if AUTOTUNE in (a, b) else max(a, b)
            )
            continue
        if node.op == "shuffle" and nodes and nodes[-1].op == "shuffle":
            # shuffle∘shuffle: one shuffle with the larger buffer suffices
            nodes[-1].params["buffer_size"] = max(
                nodes[-1].params["buffer_size"], node.params["buffer_size"]
            )
            continue
        if node.op == "repeat" and nodes and nodes[-1].op == "repeat":
            a, b = nodes[-1].params.get("count"), node.params.get("count")
            nodes[-1].params["count"] = (
                None if None in (a, b) or -1 in (a, b) else a * b
            )
            continue
        nodes.append(node.copy())
    return Graph(nodes)


def inject_prefetch(graph: Graph) -> Graph:
    """Transparently append prefetch(AUTOTUNE) if the pipeline lacks a final
    prefetch — decouples producer and consumer (tf.data does the same)."""
    if graph.nodes and graph.nodes[-1].op != "prefetch":
        return graph.appended(Node("prefetch", {"buffer_size": AUTOTUNE}))
    return graph


DEFAULT_PASSES: List[Pass] = [eliminate_dead, fuse_maps, fuse_map_filter]


def optimize_graph(
    graph: Graph, passes: List[Pass] = None, add_prefetch: bool = False
) -> Graph:
    g = graph
    for p in passes if passes is not None else DEFAULT_PASSES:
        g = p(g)
    if add_prefetch:
        g = inject_prefetch(g)
    return g
