"""repro.data — tf.data-equivalent input pipeline framework (graph IR +
execution engine + static optimizations + runtime autotuning)."""
from .dataset import Dataset
from .graph import AUTOTUNE, Graph, Node
from .registry import FnRef, register
from .elements import (
    decode_element,
    decode_elements,
    element_nbytes,
    encode_element,
    encode_elements,
    padded_stack_elements,
    stack_elements,
)
from .iterators import ExecContext, build_iterator
from .optimizer import optimize_graph
from .autotune import Autotuner
from .sources import RecordWriter, from_snapshot, read_records, write_record_shards

__all__ = [
    "AUTOTUNE",
    "Autotuner",
    "Dataset",
    "ExecContext",
    "FnRef",
    "Graph",
    "Node",
    "RecordWriter",
    "build_iterator",
    "decode_element",
    "decode_elements",
    "element_nbytes",
    "encode_element",
    "encode_elements",
    "from_snapshot",
    "optimize_graph",
    "padded_stack_elements",
    "read_records",
    "register",
    "stack_elements",
    "write_record_shards",
]
