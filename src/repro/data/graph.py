"""Serializable pipeline graph IR.

A pipeline is a linear chain of ``Node``s rooted at a source.  The dispatcher
serializes the graph and ships it to every worker (mirroring tf.data service
shipping the tf.data GraphDef); workers deserialize and execute it, optionally
bound to a source *shard* and re-seeded per worker.

Nested pipelines (``interleave``) hold a sub-graph in their params.
"""
from __future__ import annotations

import copy
import pickle
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional

AUTOTUNE = -1  # sentinel for "let the runtime tune this parameter"

SOURCE_OPS = ("range", "files", "generator", "from_list", "snapshot")
# Ops whose per-element cost may warrant parallelism / autotuning.
PARALLELIZABLE_OPS = ("map",)


@dataclass
class Node:
    op: str
    params: Dict[str, Any] = field(default_factory=dict)

    def copy(self) -> "Node":
        return Node(self.op, copy.deepcopy(self.params))

    def describe(self) -> str:
        fn = self.params.get("fn")
        extra = f"({fn.describe()})" if fn is not None else ""
        return f"{self.op}{extra}"


@dataclass
class Graph:
    nodes: List[Node] = field(default_factory=list)

    # -- construction -----------------------------------------------------
    def appended(self, node: Node) -> "Graph":
        return Graph(self.nodes + [node])

    @property
    def source(self) -> Node:
        return self.nodes[0]

    # -- serialization ----------------------------------------------------
    def to_bytes(self) -> bytes:
        return pickle.dumps(self, protocol=pickle.HIGHEST_PROTOCOL)

    @staticmethod
    def from_bytes(data: bytes) -> "Graph":
        g = pickle.loads(data)
        if not isinstance(g, Graph):
            raise TypeError("payload is not a pipeline Graph")
        return g

    def copy(self) -> "Graph":
        return Graph([n.copy() for n in self.nodes])

    # -- worker-side binding ----------------------------------------------
    def bind_shard(self, shard: Dict[str, Any]) -> "Graph":
        """Return a copy whose source is restricted to ``shard``.

        Shard kinds:
          {"kind": "file", "path": p}            — one source file
          {"kind": "range", "start": a, "stop": b} — element index range
          {"kind": "mod", "num": n, "index": i}  — static mod-sharding
        """
        g = self.copy()
        g.source.params["shard"] = dict(shard)
        return g

    def bind_seed(self, seed: int) -> "Graph":
        """Re-seed all stochastic ops (shuffle, sampled maps) for a worker.

        With the OFF sharding policy each worker processes the full dataset in
        its own random order (paper §3.3) — this is the hook that makes the
        orders distinct.
        """
        g = self.copy()
        for i, node in enumerate(g.nodes):
            if node.op == "shuffle":
                node.params["seed"] = (seed * 1_000_003 + i) & 0x7FFFFFFF
            if node.op == "map" and node.params.get("stochastic"):
                node.params["seed"] = (seed * 10_007 + i) & 0x7FFFFFFF
        return g

    # -- introspection -----------------------------------------------------
    def describe(self) -> str:
        return " -> ".join(n.describe() for n in self.nodes)

    def fingerprint(self) -> str:
        """Stable content hash; identical pipelines across jobs share caches
        (ephemeral data sharing keys on this, paper §3.5)."""
        import hashlib

        return hashlib.sha256(self.to_bytes()).hexdigest()[:16]


def validate(graph: Graph) -> None:
    if not graph.nodes:
        raise ValueError("empty pipeline graph")
    if graph.nodes[0].op not in SOURCE_OPS:
        raise ValueError(
            f"pipeline must start with a source op, got '{graph.nodes[0].op}'"
        )
    for node in graph.nodes[1:]:
        if node.op in SOURCE_OPS:
            raise ValueError(f"source op '{node.op}' not at graph root")
