"""Pipeline execution engine.

``build_iterator(graph, ctx)`` compiles a pipeline Graph into a python
iterator chain.  Parallel maps use a thread pool whose width is a *shared
mutable* knob so the AUTOTUNE harness can adjust it while the pipeline runs
(mirrors tf.data's runtime autotuning, §3.2).  Prefetch runs a daemon thread
into a bounded queue.

Every node gets an ``OpStats`` slot in the context: element counts and
cumulative processing time feed both the autotuner and the benchmark harness
(per-op cost breakdown).
"""
from __future__ import annotations

import collections
import itertools
import queue
import random
import threading
import time
from concurrent.futures import Future, ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, List, Optional

import numpy as np

from .elements import Element, padded_stack_elements, stack_elements
from .graph import AUTOTUNE, SOURCE_OPS, Graph, Node

_END = object()


@dataclass
class Knob:
    """A shared, autotunable integer parameter."""

    value: int
    minimum: int = 1
    maximum: int = 64
    autotune: bool = False

    def get(self) -> int:
        return max(self.minimum, min(self.value, self.maximum))


@dataclass
class OpStats:
    name: str = ""
    elements: int = 0
    busy_time: float = 0.0  # cumulative WALL seconds inside the op's fn
    cpu_time: float = 0.0  # cumulative CPU (thread_time) seconds in the fn
    parallelism: Optional[Knob] = None
    buffer_size: Optional[Knob] = None
    buffer_occupancy: float = 0.0  # EMA of queue fill fraction

    def record(self, dt: float, n: int = 1, cpu: float = 0.0) -> None:
        self.elements += n
        self.busy_time += dt
        self.cpu_time += cpu

    @property
    def mean_cost(self) -> float:
        return self.busy_time / self.elements if self.elements else 0.0


@dataclass
class ExecContext:
    seed: int = 0
    stop_event: threading.Event = field(default_factory=threading.Event)
    stats: Dict[int, OpStats] = field(default_factory=dict)
    cache_store: Dict[int, List[Element]] = field(default_factory=dict)
    default_parallelism: int = 4

    def stat(self, idx: int, name: str) -> OpStats:
        if idx not in self.stats:
            self.stats[idx] = OpStats(name=name)
        return self.stats[idx]


# ---------------------------------------------------------------------------
# Threaded operators
# ---------------------------------------------------------------------------
class _ParallelMap:
    """Ordered parallel map with a dynamically adjustable thread-pool width.

    Keeps at most ``parallelism`` futures in flight; yields results in input
    order (deterministic by default, like tf.data's deterministic=True).
    """

    def __init__(
        self,
        upstream: Iterator[Element],
        fn: Callable[[Element], Element],
        knob: Knob,
        stats: OpStats,
        stop_event: threading.Event,
    ):
        self._up = upstream
        self._fn = fn
        self._knob = knob
        self._stats = stats
        self._stop = stop_event
        self._pool = ThreadPoolExecutor(max_workers=knob.maximum)
        self._pending: collections.deque[Future] = collections.deque()
        self._exhausted = False

    def _timed(self, elem: Element) -> Element:
        # wall vs CPU split: a map dominated by wall-but-not-CPU time is
        # blocked on I/O, not compute — stall attribution reads both
        t0 = time.perf_counter()
        c0 = time.thread_time()
        out = self._fn(elem)
        self._stats.record(
            time.perf_counter() - t0, cpu=time.thread_time() - c0
        )
        return out

    def _fill(self) -> None:
        while not self._exhausted and len(self._pending) < self._knob.get():
            try:
                elem = next(self._up)
            except StopIteration:
                self._exhausted = True
                return
            self._pending.append(self._pool.submit(self._timed, elem))

    def __iter__(self) -> Iterator[Element]:
        try:
            self._fill()
            while self._pending:
                if self._stop.is_set():
                    break
                fut = self._pending.popleft()
                result = fut.result()
                self._fill()
                yield result
        finally:
            self._pool.shutdown(wait=False, cancel_futures=True)


class _Prefetch:
    """Background-thread prefetch into a bounded queue."""

    def __init__(
        self,
        upstream: Iterator[Element],
        knob: Knob,
        stats: OpStats,
        stop_event: threading.Event,
    ):
        self._up = upstream
        self._knob = knob
        self._stats = stats
        self._stop = stop_event
        self._q: "queue.Queue[Any]" = queue.Queue(maxsize=max(1, knob.get()))
        self._thread = threading.Thread(target=self._run, daemon=True)

    def _run(self) -> None:
        try:
            for elem in self._up:
                while True:
                    if self._stop.is_set():
                        return
                    try:
                        self._q.put(elem, timeout=0.1)
                        break
                    except queue.Full:
                        continue
            self._q.put(_END)
        except BaseException as e:  # propagate upstream failures to consumer
            self._q.put(e)

    def __iter__(self) -> Iterator[Element]:
        self._thread.start()
        while True:
            if self._stop.is_set():
                return
            item = self._q.get()
            occ = self._q.qsize() / max(1, self._q.maxsize)
            self._stats.buffer_occupancy = 0.9 * self._stats.buffer_occupancy + 0.1 * occ
            if item is _END:
                return
            if isinstance(item, BaseException):
                raise item
            self._stats.elements += 1
            yield item


# ---------------------------------------------------------------------------
# Pure-python operators
# ---------------------------------------------------------------------------
def _shuffle(up: Iterator[Element], buffer_size: int, seed: int) -> Iterator[Element]:
    rng = random.Random(seed)
    buf: List[Element] = []
    for elem in up:
        buf.append(elem)
        if len(buf) >= buffer_size:
            i = rng.randrange(len(buf))
            buf[i], buf[-1] = buf[-1], buf[i]
            yield buf.pop()
    rng.shuffle(buf)
    yield from buf


def _batch(
    up: Iterator[Element], batch_size: int, drop_remainder: bool
) -> Iterator[Element]:
    chunk: List[Element] = []
    for elem in up:
        chunk.append(elem)
        if len(chunk) == batch_size:
            yield stack_elements(chunk)
            chunk = []
    if chunk and not drop_remainder:
        yield stack_elements(chunk)


def _padded_batch(
    up: Iterator[Element],
    batch_size: int,
    drop_remainder: bool,
    pad_value: float,
    pad_to_multiple: int,
) -> Iterator[Element]:
    chunk: List[Element] = []
    for elem in up:
        chunk.append(elem)
        if len(chunk) == batch_size:
            yield padded_stack_elements(chunk, pad_value, pad_to_multiple)
            chunk = []
    if chunk and not drop_remainder:
        yield padded_stack_elements(chunk, pad_value, pad_to_multiple)


def _unbatch(up: Iterator[Element]) -> Iterator[Element]:
    for elem in up:
        if isinstance(elem, dict):
            n = len(next(iter(elem.values())))
            for i in range(n):
                yield {k: v[i] for k, v in elem.items()}
        else:
            yield from elem


def _bucket_by_sequence_length(
    up: Iterator[Element],
    boundaries: List[int],
    batch_size: int,
    length_fn: Callable[[Element], int],
    pad_value: float,
    drop_remainder: bool,
    emit_bucket_id: bool,
    pad_to_boundary: bool,
) -> Iterator[Element]:
    """Bucketize variable-length elements; emit per-bucket padded batches.

    Buckets are (0, b0], (b0, b1], ..., (bn, inf).  This is the front half of
    the paper's coordinated-reads pipeline (Fig. 7).
    """
    buckets: Dict[int, List[Element]] = collections.defaultdict(list)
    bounds = list(boundaries)

    def bucket_of(n: int) -> int:
        for i, b in enumerate(bounds):
            if n <= b:
                return i
        return len(bounds)

    def emit(bid: int, items: List[Element]) -> Element:
        pad_mult = 1
        if pad_to_boundary and bid < len(bounds):
            batch = padded_stack_elements(items, pad_value, 1)
            # pad fully up to the bucket boundary for shape-stable executables
            batch = _pad_batch_to(batch, bounds[bid], pad_value)
        else:
            batch = padded_stack_elements(items, pad_value, pad_mult)
        if emit_bucket_id:
            if not isinstance(batch, dict):
                batch = {"data": batch}
            batch = dict(batch)
            batch["_bucket"] = np.int64(bid)
        return batch

    for elem in up:
        bid = bucket_of(int(length_fn(elem)))
        buckets[bid].append(elem)
        if len(buckets[bid]) == batch_size:
            yield emit(bid, buckets.pop(bid))
    if not drop_remainder:
        for bid in sorted(buckets):
            yield emit(bid, buckets[bid])


def _pad_batch_to(batch: Element, length: int, pad_value: float) -> Element:
    def pad(a: np.ndarray) -> np.ndarray:
        if a.ndim < 2 or a.shape[1] >= length:
            return a
        out = np.full((a.shape[0], length) + a.shape[2:], pad_value, dtype=a.dtype)
        out[:, : a.shape[1]] = a
        return out

    if isinstance(batch, dict):
        return {k: (pad(v) if isinstance(v, np.ndarray) else v) for k, v in batch.items()}
    return pad(batch)


def _group_by_window(
    up: Iterator[Element],
    key_fn: Callable[[Element], int],
    window_size: int,
    drop_remainder: bool,
) -> Iterator[List[Element]]:
    windows: Dict[int, List[Element]] = collections.defaultdict(list)
    for elem in up:
        k = int(key_fn(elem))
        windows[k].append(elem)
        if len(windows[k]) == window_size:
            yield windows.pop(k)
    if not drop_remainder:
        for k in sorted(windows):
            yield windows[k]


# ---------------------------------------------------------------------------
# Builder
# ---------------------------------------------------------------------------
def build_iterator(graph: Graph, ctx: Optional[ExecContext] = None) -> Iterator[Element]:
    ctx = ctx or ExecContext()
    return _build_from(graph, len(graph.nodes), ctx)


def _build_from(graph: Graph, upto: int, ctx: ExecContext) -> Iterator[Element]:
    from .sources import iterate_source  # local import to avoid cycle

    it: Optional[Iterator[Element]] = None
    for idx in range(upto):
        node = graph.nodes[idx]
        op, p = node.op, node.params
        stats = ctx.stat(idx, node.describe())

        if op in SOURCE_OPS:
            it = iterate_source(p, op)
        elif op == "map":
            fn = p["fn"].resolve()
            npar = p.get("num_parallel_calls", 0) or 0
            if npar == 0:
                it = _sequential_map(it, fn, stats)
            else:
                if stats.parallelism is None:
                    auto = npar == AUTOTUNE
                    width = ctx.default_parallelism if auto else int(npar)
                    stats.parallelism = Knob(
                        value=width, minimum=1, maximum=32, autotune=auto
                    )
                it = iter(
                    _ParallelMap(it, fn, stats.parallelism, stats, ctx.stop_event)
                )
        elif op == "filter":
            fn = p["fn"].resolve()
            it = (e for e in it if fn(e))
        elif op == "batch":
            it = _batch(it, int(p["batch_size"]), bool(p.get("drop_remainder", False)))
        elif op == "padded_batch":
            it = _padded_batch(
                it,
                int(p["batch_size"]),
                bool(p.get("drop_remainder", False)),
                p.get("pad_value", 0),
                int(p.get("pad_to_multiple", 1)),
            )
        elif op == "unbatch":
            it = _unbatch(it)
        elif op == "shuffle":
            it = _shuffle(it, int(p["buffer_size"]), int(p.get("seed", ctx.seed)))
        elif op == "repeat":
            it = _repeat(graph, idx, p.get("count"), ctx)
        elif op == "take":
            it = itertools.islice(it, int(p["count"]))
        elif op == "skip":
            it = itertools.islice(it, int(p["count"]), None)
        elif op == "prefetch":
            size = int(p.get("buffer_size", 2))
            auto = p.get("buffer_size") == AUTOTUNE
            if stats.buffer_size is None:
                stats.buffer_size = Knob(
                    value=2 if auto else size, minimum=1, maximum=128, autotune=auto
                )
            it = iter(_Prefetch(it, stats.buffer_size, stats, ctx.stop_event))
        elif op == "cache":
            it = _cache(it, idx, ctx)
        elif op == "flat_map":
            fn = p["fn"].resolve()
            it = (x for e in it for x in fn(e))
        elif op == "interleave":
            fn = p["fn"].resolve()
            it = _interleave(it, fn, int(p.get("cycle_length", 2)))
        elif op == "bucket_by_sequence_length":
            it = _bucket_by_sequence_length(
                it,
                list(p["boundaries"]),
                int(p["batch_size"]),
                p["length_fn"].resolve(),
                p.get("pad_value", 0),
                bool(p.get("drop_remainder", False)),
                bool(p.get("emit_bucket_id", False)),
                bool(p.get("pad_to_boundary", True)),
            )
        elif op == "group_by_window":
            it = _group_by_window(
                it,
                p["key_fn"].resolve(),
                int(p["window_size"]),
                bool(p.get("drop_remainder", False)),
            )
        else:
            raise ValueError(f"unknown pipeline op: {op}")
    assert it is not None
    return it


def _sequential_map(
    up: Iterator[Element], fn: Callable, stats: OpStats
) -> Iterator[Element]:
    for elem in up:
        t0 = time.perf_counter()
        c0 = time.thread_time()
        out = fn(elem)
        stats.record(time.perf_counter() - t0, cpu=time.thread_time() - c0)
        yield out


def _repeat(
    graph: Graph, idx: int, count: Optional[int], ctx: ExecContext
) -> Iterator[Element]:
    epochs = itertools.count() if count in (None, -1) else range(int(count))
    for _ in epochs:
        if ctx.stop_event.is_set():
            return
        yield from _build_from(graph, idx, ctx)


def _cache(up: Iterator[Element], idx: int, ctx: ExecContext) -> Iterator[Element]:
    if idx in ctx.cache_store:
        yield from ctx.cache_store[idx]
        return
    acc: List[Element] = []
    for elem in up:
        acc.append(elem)
        yield elem
    ctx.cache_store[idx] = acc


def _interleave(
    up: Iterator[Element], fn: Callable[[Element], Any], cycle_length: int
) -> Iterator[Element]:
    active: List[Iterator[Element]] = []
    upstream_done = False

    def refill() -> None:
        nonlocal upstream_done
        while not upstream_done and len(active) < cycle_length:
            try:
                active.append(iter(fn(next(up))))
            except StopIteration:
                upstream_done = True

    refill()
    i = 0
    while active:
        it = active[i % len(active)]
        try:
            yield next(it)
            i += 1
        except StopIteration:
            active.remove(it)
            refill()
