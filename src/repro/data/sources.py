"""Shardable data sources + an on-disk record format.

The paper assumes source data lives in a distributed FS as many files, with a
file being the natural shard granularity (§3.3).  We mirror that with a local
record-file format (length-prefixed encoded elements — a TFRecord equivalent)
plus synthetic in-memory sources for benchmarks.

Every source supports:
  * ``iterate(params)``       — yield elements (optionally restricted to a shard)
  * ``list_shards(params)``   — enumerate shard descriptors for the dispatcher
"""
from __future__ import annotations

import glob as _glob
import os
import struct
from typing import Any, Dict, Iterator, List, Optional

import numpy as np

from .elements import Element, decode_element, encode_element
from .registry import lookup

_MAGIC = b"RPR1"


# ---------------------------------------------------------------------------
# Record file format (TFRecord-like): MAGIC, then [u32 len][payload]*
# ---------------------------------------------------------------------------
class RecordWriter:
    def __init__(self, path: str):
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        self._f = open(path, "wb")
        self._f.write(_MAGIC)

    def write(self, elem: Element) -> None:
        payload = encode_element(elem)
        self._f.write(struct.pack("<I", len(payload)))
        self._f.write(payload)

    def close(self) -> None:
        self._f.close()

    def __enter__(self) -> "RecordWriter":
        return self

    def __exit__(self, *exc: Any) -> None:
        self.close()


def read_records(path: str) -> Iterator[Element]:
    with open(path, "rb") as f:
        if f.read(4) != _MAGIC:
            raise ValueError(f"{path}: not a repro record file")
        while True:
            hdr = f.read(4)
            if not hdr:
                return
            (n,) = struct.unpack("<I", hdr)
            yield decode_element(f.read(n))


def write_record_shards(
    elements: List[Element], directory: str, num_shards: int, prefix: str = "data"
) -> List[str]:
    """Write elements round-robin across ``num_shards`` files."""
    paths = [
        os.path.join(directory, f"{prefix}-{i:05d}-of-{num_shards:05d}.rec")
        for i in range(num_shards)
    ]
    writers = [RecordWriter(p) for p in paths]
    for i, e in enumerate(elements):
        writers[i % num_shards].write(e)
    for w in writers:
        w.close()
    return paths


# ---------------------------------------------------------------------------
# Source iteration (used by the execution engine for graph source nodes)
# ---------------------------------------------------------------------------
def _apply_range_shard(n: int, shard: Optional[Dict[str, Any]]) -> range:
    if shard is None:
        return range(n)
    if shard["kind"] == "range":
        return range(shard["start"], min(shard["stop"], n))
    if shard["kind"] == "mod":
        return range(shard["index"], n, shard["num"])
    raise ValueError(f"range source cannot apply shard kind {shard['kind']}")


def iterate_source(params: Dict[str, Any], op: str) -> Iterator[Element]:
    shard = params.get("shard")
    if op == "range":
        for i in _apply_range_shard(int(params["n"]), shard):
            yield np.int64(i)
        return
    if op == "from_list":
        items = params["items"]
        idx = _apply_range_shard(len(items), shard)
        for i in idx:
            yield items[i]
        return
    if op == "files":
        paths = sorted(_glob.glob(params["pattern"]))
        if shard is not None:
            if shard["kind"] == "file":
                paths = [shard["path"]]
            elif shard["kind"] == "mod":
                paths = paths[shard["index"] :: shard["num"]]
            elif shard["kind"] == "range":
                paths = paths[shard["start"] : shard["stop"]]
        for p in paths:
            yield from read_records(p)
        return
    if op == "generator":
        fn = params["fn"].resolve()
        gen_shard = shard
        try:
            it = fn(shard=gen_shard)
        except TypeError:
            it = fn()
        yield from it
        return
    if op == "snapshot":
        # materialized preprocessed data (repro.snapshot): elements here are
        # the PIPELINE'S OUTPUT (typically batches) — no recomputation.
        from ..snapshot import reader as snap_reader  # lazy: optional layer

        if shard is not None:
            if shard["kind"] == "snapshot_chunk":
                from ..snapshot.format import read_chunk

                yield from read_chunk(shard["path"])
                return
            raise ValueError(f"snapshot source cannot apply shard kind {shard['kind']}")
        yield from snap_reader.iterate_snapshot(
            params["path"],
            tail=bool(params.get("tail", False)),
            poll_interval=float(params.get("poll", 0.05)),
            timeout=params.get("timeout"),
        )
        return
    raise ValueError(f"unknown source op {op}")


def list_shards(params: Dict[str, Any], op: str, num_shards_hint: int = 0) -> List[Dict[str, Any]]:
    """Enumerate shard descriptors for a source node (dispatcher-side).

    File sources shard at file granularity (the paper's default).  Element
    sources shard into ``num_shards_hint`` contiguous ranges (dispatcher
    over-partitions relative to worker count for load balancing, §3.3).
    """
    if op == "files":
        paths = sorted(_glob.glob(params["pattern"]))
        return [{"kind": "file", "path": p} for p in paths]
    if op in ("range", "from_list"):
        n = int(params["n"]) if op == "range" else len(params["items"])
        k = max(1, num_shards_hint or 1)
        per = -(-n // k)
        return [
            {"kind": "range", "start": i * per, "stop": min((i + 1) * per, n)}
            for i in range(k)
            if i * per < n
        ]
    if op == "generator":
        fn_params = dict(params.get("shards") or {})
        if fn_params:
            return list(fn_params)
        k = max(1, num_shards_hint or 1)
        return [{"kind": "mod", "num": k, "index": i} for i in range(k)]
    if op == "snapshot":
        # committed chunks are the shard granularity — the materialized
        # analogue of file shards.  For a finished snapshot this is the
        # complete element set; for an in-progress one it is a point-in-time
        # cut (use tail mode / a non-sharded read to follow a live write).
        from ..snapshot.reader import list_snapshot_shards

        return list_snapshot_shards(params["path"])
    raise ValueError(f"unknown source op {op}")


# ---------------------------------------------------------------------------
# Snapshot source factory (repro.snapshot's read path as a Dataset)
# ---------------------------------------------------------------------------
def from_snapshot(path: str, tail: bool = False, timeout: Optional[float] = None):
    """A Dataset over a materialized snapshot's committed batches.

    ``tail=True`` lets a job consume a snapshot MID-WRITE: committed chunks
    are read immediately and the live stream is followed until the
    committer finalizes the snapshot.  Elements are the original pipeline's
    OUTPUT (typically batches): no preprocessing re-runs.
    """
    from .dataset import Dataset  # lazy: avoid cycle

    params: Dict[str, Any] = {"path": path, "tail": bool(tail)}
    if timeout is not None:
        params["timeout"] = float(timeout)
    from .graph import Graph, Node

    return Dataset(Graph([Node("snapshot", params)]))
