"""Pipeline executors: where a worker actually runs its dataset graphs.

The paper's workers are single processes that execute every assigned task's
pipeline on internal threads (§3.1).  That is this module's
:class:`InThreadExecutor`, and it remains the default.  For CPU-heavy
user-defined transforms, Python's GIL makes one worker process a hard
ceiling no matter how many ``_ParallelMap`` threads the autotuner adds —
so :class:`ProcessPoolExecutor` runs pipelines in a small pool of forked
child processes instead, with the parent worker keeping ownership of the
control plane (RPCs, checkpoints, snapshots, heartbeats).

Invariants both engines honour:

* **Request affinity** — ``iterate(..., affinity=key)`` pins a given key to
  one child for the executor's lifetime (``crc32(key) % processes``), so a
  shard's elements always come from the same child: per-stream seeding,
  resume offsets and snapshot byte-identity are preserved exactly as in
  the in-thread engine.
* **Deterministic sequence numbers** — ``iterate`` yields
  ``(absolute_seq, element)`` with ``absolute_seq`` starting at
  ``offset + 1``; skipping for resume happens at the source (child side
  for the pool — skipped elements never cross the IPC boundary).
* **Observability flows back** — children ship cumulative per-op stats
  snapshots which the parent folds into the request's own ``ExecContext``,
  so stall attribution, ``metrics_dump`` and ``trace_dump`` see pooled
  pipelines exactly like in-thread ones.  Parent-side knob writes (e.g. an
  autotuner adjusting parallelism) are forwarded to the owning child.

Failure contract: a child that dies or errors *before yielding anything*
triggers a transparent in-thread retry (covers graphs that capture
process-local state a fork can't see, e.g. ``__local__/`` registry tokens
created after the child forked).  A child lost *mid-stream* raises
``ExecutorError`` — the worker's task machinery already treats a runner
error as a task failure and the dispatcher reassigns.

The pool uses the ``fork`` start method deliberately: forked children
inherit ``data.registry._LOCAL_FNS``, so lambda/closure transforms that
were registered before the child started resolve without being picklable.
"""
from __future__ import annotations

import itertools
import logging
import pickle
import queue
import threading
import time
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .iterators import ExecContext, Knob, build_iterator

logger = logging.getLogger(__name__)

# Flow control: a child may have this many elements in flight before it
# blocks; the parent replenishes in batches so steady state costs one
# control message per REPLENISH_EVERY elements, not one per element.
INITIAL_CREDITS = 64
REPLENISH_EVERY = 32
STATS_INTERVAL_S = 0.2


class ExecutorError(RuntimeError):
    """A pooled pipeline failed after it had already produced elements."""


class PipelineExecutor:
    """Engine interface: turn a bound graph into a numbered element stream."""

    #: how many pipelines can genuinely make progress at once
    width: int = 1

    def iterate(
        self,
        graph: Any,
        ctx: ExecContext,
        *,
        affinity: str,
        offset: int = 0,
    ) -> Iterator[Tuple[int, Any]]:
        """Yield ``(absolute_seq, element)`` with seq starting at offset+1.

        ``ctx`` is the request's parent-side ExecContext: its ``stats``
        receive the pipeline's per-op profile and its ``stop_event``
        aborts the stream.  ``affinity`` pins the request to one execution
        lane (same key → same child process) for determinism.
        """
        raise NotImplementedError

    def stop(self) -> None:
        """Release engine resources; in-flight iterators abort."""


class InThreadExecutor(PipelineExecutor):
    """The paper's engine: run the pipeline on the calling worker's threads."""

    width = 1

    def iterate(self, graph, ctx, *, affinity, offset=0):
        for i, elem in enumerate(build_iterator(graph, ctx)):
            if i < offset:
                continue
            yield i + 1, elem

    def stop(self) -> None:
        pass


# ---------------------------------------------------------------------------
# Child process side
# ---------------------------------------------------------------------------
class _ChildRequest:
    __slots__ = ("rid", "stop", "credits", "ctx")

    def __init__(self, rid: str, initial_credits: int):
        self.rid = rid
        self.stop = threading.Event()
        self.credits = threading.Semaphore(initial_credits)
        self.ctx: Optional[ExecContext] = None


def _stats_snapshot(ctx: ExecContext) -> Dict[int, Dict[str, Any]]:
    out: Dict[int, Dict[str, Any]] = {}
    for idx, st in list(ctx.stats.items()):
        out[idx] = {
            "name": st.name,
            "elements": st.elements,
            "busy_time": st.busy_time,
            "cpu_time": st.cpu_time,
            "buffer_occupancy": st.buffer_occupancy,
            "parallelism": st.parallelism.get() if st.parallelism else None,
            "buffer_size": st.buffer_size.get() if st.buffer_size else None,
        }
    return out


def _run_request(req: _ChildRequest, graph_blob, seed, offset, default_par, out_q):
    ctx = ExecContext(
        seed=seed, stop_event=req.stop, default_parallelism=default_par
    )
    req.ctx = ctx
    sent = 0
    last_stats = time.monotonic()
    try:
        graph = pickle.loads(graph_blob)
        for i, elem in enumerate(build_iterator(graph, ctx)):
            if req.stop.is_set():
                break
            if i < offset:
                continue
            # block on flow-control credit, staying responsive to cancel
            while not req.credits.acquire(timeout=0.1):
                if req.stop.is_set():
                    break
            if req.stop.is_set():
                break
            out_q.put(("elem", req.rid, i + 1, elem))
            sent += 1
            now = time.monotonic()
            if now - last_stats >= STATS_INTERVAL_S:
                out_q.put(("stats", req.rid, _stats_snapshot(ctx)))
                last_stats = now
    except Exception as e:  # ship the failure; the parent decides policy
        try:
            out_q.put(("stats", req.rid, _stats_snapshot(ctx)))
            out_q.put(("err", req.rid, repr(e), sent))
        except Exception:
            pass
        return
    try:
        out_q.put(("stats", req.rid, _stats_snapshot(ctx)))
        out_q.put(("end", req.rid))
    except Exception:
        pass


def _child_main(ctrl_q, out_q) -> None:
    """Entry point of one executor child: a tiny request multiplexer.

    Runs each ``start`` request on its own thread so one child serves
    several affinity keys concurrently; ``credit``/``knob``/``cancel``
    messages are applied to the matching live request.
    """
    active: Dict[str, _ChildRequest] = {}
    lock = threading.Lock()
    while True:
        msg = ctrl_q.get()
        kind = msg[0]
        if kind == "shutdown":
            with lock:
                reqs = list(active.values())
            for req in reqs:
                req.stop.set()
                req.credits.release()
            return
        if kind == "start":
            _, rid, graph_blob, seed, offset, default_par = msg
            req = _ChildRequest(rid, INITIAL_CREDITS)
            with lock:
                active[rid] = req

            def _run(req=req, blob=graph_blob, seed=seed, offset=offset, dp=default_par):
                try:
                    _run_request(req, blob, seed, offset, dp, out_q)
                finally:
                    with lock:
                        active.pop(req.rid, None)

            threading.Thread(
                target=_run, daemon=True, name=f"exec-req-{rid}"
            ).start()
        elif kind == "credit":
            _, rid, n = msg
            with lock:
                req = active.get(rid)
            if req is not None:
                for _ in range(n):
                    req.credits.release()
        elif kind == "cancel":
            _, rid = msg
            with lock:
                req = active.get(rid)
            if req is not None:
                req.stop.set()
                req.credits.release()  # wake a credit-blocked producer
        elif kind == "knob":
            _, rid, idx, knob_kind, value = msg
            with lock:
                req = active.get(rid)
            st = req.ctx.stats.get(idx) if req is not None and req.ctx else None
            knob = getattr(st, knob_kind, None) if st is not None else None
            if isinstance(knob, Knob):
                knob.value = max(knob.minimum, min(knob.maximum, int(value)))


# ---------------------------------------------------------------------------
# Parent side
# ---------------------------------------------------------------------------
class ProcessPoolExecutor(PipelineExecutor):
    """Run pipelines in ``processes`` forked children with request affinity."""

    def __init__(self, processes: int):
        import multiprocessing

        self.width = max(1, int(processes))
        self._mp = multiprocessing.get_context("fork")
        self._children: List[Optional[Any]] = [None] * self.width
        self._ctrl: List[Optional[Any]] = [None] * self.width
        self._out: List[Optional[Any]] = [None] * self.width
        self._lock = threading.Lock()
        # rid -> (child_index, parent-side delivery queue); plain dict reads
        # from the router threads are GIL-safe
        self._pending: Dict[str, Tuple[int, "queue.Queue[Any]"]] = {}
        self._last_knob: Dict[str, Dict[Tuple[int, str], int]] = {}
        self._rid_counter = itertools.count()
        self._stopping = threading.Event()
        self._fallback = InThreadExecutor()

    # -- child lifecycle ---------------------------------------------------
    def _ensure_child(self, i: int) -> Tuple[Any, Any]:
        """Start (or restart after death) child ``i``; returns (ctrl, proc)."""
        with self._lock:
            proc = self._children[i]
            if proc is not None and proc.is_alive():
                return self._ctrl[i], proc
            if self._stopping.is_set():
                raise ExecutorError("executor is stopped")
            ctrl = self._mp.Queue()
            out = self._mp.Queue()
            proc = self._mp.Process(
                target=_child_main,
                args=(ctrl, out),
                daemon=True,
                name=f"repro-exec-{i}",
            )
            proc.start()
            self._children[i], self._ctrl[i], self._out[i] = proc, ctrl, out
            threading.Thread(
                target=self._route,
                args=(i, proc, out),
                daemon=True,
                name=f"exec-route-{i}",
            ).start()
            return ctrl, proc

    def _route(self, i: int, proc, out_q) -> None:
        """Demultiplex one child's output queue to per-request queues."""
        while not self._stopping.is_set():
            try:
                msg = out_q.get(timeout=0.2)
            except queue.Empty:
                if proc.is_alive():
                    continue
                # child died: poison every request routed to it, then exit
                with self._lock:
                    victims = [
                        q for rid, (ci, q) in self._pending.items() if ci == i
                    ]
                for q in victims:
                    q.put(("died",))
                return
            q = None
            entry = self._pending.get(msg[1])
            if entry is not None:
                q = entry[1]
            if q is not None:
                q.put(msg)

    # -- stats / knob plumbing ----------------------------------------------
    def _apply_stats(self, ctx: ExecContext, snap, rid: str, ctrl) -> None:
        last = self._last_knob.setdefault(rid, {})
        for idx, s in snap.items():
            st = ctx.stat(idx, s["name"])
            st.elements = s["elements"]
            st.busy_time = s["busy_time"]
            st.cpu_time = s["cpu_time"]
            st.buffer_occupancy = s["buffer_occupancy"]
            for kind in ("parallelism", "buffer_size"):
                child_val = s.get(kind)
                if child_val is None:
                    continue
                knob = getattr(st, kind)
                if knob is None:
                    setattr(st, kind, Knob(value=int(child_val)))
                    last[(idx, kind)] = int(child_val)
                    continue
                prev = last.get((idx, kind))
                if (
                    prev is not None
                    and knob.get() != prev
                    and knob.get() != child_val
                ):
                    # the parent side moved the knob (autotuner): forward to
                    # the owning child instead of clobbering the new value
                    try:
                        ctrl.put(("knob", rid, idx, kind, knob.get()))
                    except Exception:
                        pass
                    last[(idx, kind)] = knob.get()
                else:
                    knob.value = int(child_val)
                    last[(idx, kind)] = int(child_val)

    # -- the engine ----------------------------------------------------------
    def iterate(self, graph, ctx, *, affinity, offset=0):
        child_idx = zlib.crc32(str(affinity).encode("utf-8")) % self.width
        rid = f"r{next(self._rid_counter)}"
        # Pickle BEFORE (possibly) forking the child: FnRef.__getstate__
        # stashes non-picklable transforms (lambdas/closures) into the
        # process-local registry at pickle time, and a child forked AFTER
        # the stash inherits it — so lazily started children can still
        # resolve locally-defined functions.
        try:
            graph_blob = pickle.dumps(graph, protocol=pickle.HIGHEST_PROTOCOL)
        except Exception as e:
            logger.warning(
                "graph not picklable for executor pool (%r); running in-thread",
                e,
            )
            yield from self._fallback.iterate(
                graph, ctx, affinity=affinity, offset=offset
            )
            return
        try:
            ctrl, proc = self._ensure_child(child_idx)
        except ExecutorError:
            raise
        except Exception as e:
            logger.warning(
                "executor child %d failed to start (%r); running in-thread",
                child_idx,
                e,
            )
            yield from self._fallback.iterate(
                graph, ctx, affinity=affinity, offset=offset
            )
            return

        inq: "queue.Queue[Any]" = queue.Queue()
        with self._lock:
            self._pending[rid] = (child_idx, inq)
        started = False
        yielded = 0
        uncredited = 0
        try:
            try:
                ctrl.put(
                    (
                        "start",
                        rid,
                        graph_blob,
                        ctx.seed,
                        offset,
                        ctx.default_parallelism,
                    )
                )
                started = True
            except Exception as e:  # unpicklable graph, dead queue, ...
                logger.warning(
                    "executor dispatch failed (%r); running in-thread", e
                )
                yield from self._fallback.iterate(
                    graph, ctx, affinity=affinity, offset=offset
                )
                return
            while True:
                if ctx.stop_event.is_set():
                    return
                try:
                    msg = inq.get(timeout=0.1)
                except queue.Empty:
                    if not proc.is_alive():
                        msg = ("died",)
                    else:
                        continue
                kind = msg[0]
                if kind == "elem":
                    _, _, seq, elem = msg
                    yield seq, elem
                    yielded += 1
                    uncredited += 1
                    if uncredited >= REPLENISH_EVERY:
                        try:
                            ctrl.put(("credit", rid, uncredited))
                        except Exception:
                            pass
                        uncredited = 0
                elif kind == "stats":
                    self._apply_stats(ctx, msg[2], rid, ctrl)
                elif kind == "end":
                    return
                elif kind == "err":
                    _, _, err_repr, sent = msg
                    if yielded == 0 and sent == 0:
                        # failed before producing anything: the graph may
                        # reference state the fork predates — retry inline
                        logger.warning(
                            "executor child error before first element "
                            "(%s); running in-thread",
                            err_repr,
                        )
                        yield from self._fallback.iterate(
                            graph, ctx, affinity=affinity, offset=offset
                        )
                        return
                    raise ExecutorError(f"pipeline failed in child: {err_repr}")
                elif kind == "died":
                    if yielded == 0:
                        logger.warning(
                            "executor child %d died before first element; "
                            "running in-thread",
                            child_idx,
                        )
                        yield from self._fallback.iterate(
                            graph, ctx, affinity=affinity, offset=offset
                        )
                        return
                    raise ExecutorError(
                        f"executor child {child_idx} died mid-request"
                    )
        finally:
            with self._lock:
                self._pending.pop(rid, None)
                self._last_knob.pop(rid, None)
            if started:
                try:
                    ctrl.put(("cancel", rid))
                except Exception:
                    pass

    def stop(self) -> None:
        self._stopping.set()
        with self._lock:
            pairs = [
                (self._children[i], self._ctrl[i]) for i in range(self.width)
            ]
        for proc, ctrl in pairs:
            if proc is None:
                continue
            try:
                ctrl.put(("shutdown",))
            except Exception:
                pass
        for proc, _ in pairs:
            if proc is None:
                continue
            proc.join(timeout=2.0)
            if proc.is_alive():
                proc.terminate()
                proc.join(timeout=1.0)
        with self._lock:
            queues = [q for q in self._ctrl + self._out if q is not None]
            self._children = [None] * self.width
            self._ctrl = [None] * self.width
            self._out = [None] * self.width
        for q in queues:
            try:
                q.cancel_join_thread()
                q.close()
            except Exception:
                pass


def make_executor(processes: int) -> PipelineExecutor:
    """Build the engine for ``worker_processes=N`` (0/1-thread semantics: 0
    keeps the paper's in-thread engine; N >= 1 runs an N-child pool)."""
    if processes and processes > 0:
        return ProcessPoolExecutor(processes)
    return InThreadExecutor()
