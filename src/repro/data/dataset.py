"""Fluent, tf.data-style Dataset API over the serializable Graph IR.

Datasets are immutable descriptions; iteration compiles the graph (after
static optimization passes) and executes it.  ``Dataset.distribute(...)``
hands the graph to a tf.data-service-style deployment (repro.core) and
returns a client-backed dataset — the same one-line opt-in as the paper's
Fig. 4.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Iterator, List, Optional, Sequence

from .elements import Element
from .graph import AUTOTUNE, Graph, Node, validate
from .iterators import ExecContext, build_iterator
from .registry import FnRef


class Dataset:
    def __init__(self, graph: Graph):
        self.graph = graph

    # -- sources -----------------------------------------------------------
    @staticmethod
    def range(n: int) -> "Dataset":
        return Dataset(Graph([Node("range", {"n": int(n)})]))

    @staticmethod
    def from_list(items: Sequence[Element]) -> "Dataset":
        return Dataset(Graph([Node("from_list", {"items": list(items)})]))

    @staticmethod
    def from_files(pattern: str) -> "Dataset":
        return Dataset(Graph([Node("files", {"pattern": pattern})]))

    @staticmethod
    def from_generator(fn: Callable, **kwargs: Any) -> "Dataset":
        return Dataset(
            Graph([Node("generator", {"fn": FnRef.from_callable(fn, **kwargs)})])
        )

    @staticmethod
    def from_snapshot(path: str, tail: bool = False, timeout: Optional[float] = None) -> "Dataset":
        """Read a materialized snapshot (repro.snapshot) as a dataset source.

        Elements are the snapshotted pipeline's OUTPUT batches — consuming
        them re-runs none of the original preprocessing.  ``tail=True``
        follows a snapshot still being written (read committed chunks, then
        tail the live stream until finalization).
        """
        from .sources import from_snapshot as _from_snapshot

        return _from_snapshot(path, tail=tail, timeout=timeout)

    # -- transforms ----------------------------------------------------------
    def _with(self, op: str, **params: Any) -> "Dataset":
        return Dataset(self.graph.appended(Node(op, params)))

    def map(
        self,
        fn: Callable,
        num_parallel_calls: int = 0,
        stochastic: bool = False,
        **fn_kwargs: Any,
    ) -> "Dataset":
        return self._with(
            "map",
            fn=FnRef.from_callable(fn, **fn_kwargs),
            num_parallel_calls=num_parallel_calls,
            stochastic=stochastic,
        )

    def filter(self, fn: Callable, **fn_kwargs: Any) -> "Dataset":
        return self._with("filter", fn=FnRef.from_callable(fn, **fn_kwargs))

    def batch(self, batch_size: int, drop_remainder: bool = False) -> "Dataset":
        return self._with("batch", batch_size=batch_size, drop_remainder=drop_remainder)

    def padded_batch(
        self,
        batch_size: int,
        drop_remainder: bool = False,
        pad_value: float = 0,
        pad_to_multiple: int = 1,
    ) -> "Dataset":
        return self._with(
            "padded_batch",
            batch_size=batch_size,
            drop_remainder=drop_remainder,
            pad_value=pad_value,
            pad_to_multiple=pad_to_multiple,
        )

    def unbatch(self) -> "Dataset":
        return self._with("unbatch")

    def shuffle(self, buffer_size: int, seed: Optional[int] = None) -> "Dataset":
        params: Dict[str, Any] = {"buffer_size": buffer_size}
        if seed is not None:
            params["seed"] = seed
        return Dataset(self.graph.appended(Node("shuffle", params)))

    def repeat(self, count: Optional[int] = None) -> "Dataset":
        return self._with("repeat", count=count)

    def take(self, count: int) -> "Dataset":
        return self._with("take", count=count)

    def skip(self, count: int) -> "Dataset":
        return self._with("skip", count=count)

    def prefetch(self, buffer_size: int = AUTOTUNE) -> "Dataset":
        return self._with("prefetch", buffer_size=buffer_size)

    def cache(self) -> "Dataset":
        return self._with("cache")

    def flat_map(self, fn: Callable, **fn_kwargs: Any) -> "Dataset":
        return self._with("flat_map", fn=FnRef.from_callable(fn, **fn_kwargs))

    def interleave(self, fn: Callable, cycle_length: int = 2, **fn_kwargs: Any) -> "Dataset":
        return self._with(
            "interleave", fn=FnRef.from_callable(fn, **fn_kwargs), cycle_length=cycle_length
        )

    def bucket_by_sequence_length(
        self,
        boundaries: Sequence[int],
        batch_size: int,
        length_fn: Callable,
        pad_value: float = 0,
        drop_remainder: bool = False,
        emit_bucket_id: bool = False,
        pad_to_boundary: bool = True,
    ) -> "Dataset":
        return self._with(
            "bucket_by_sequence_length",
            boundaries=list(boundaries),
            batch_size=batch_size,
            length_fn=FnRef.from_callable(length_fn),
            pad_value=pad_value,
            drop_remainder=drop_remainder,
            emit_bucket_id=emit_bucket_id,
            pad_to_boundary=pad_to_boundary,
        )

    def group_by_window(
        self, key_fn: Callable, window_size: int, drop_remainder: bool = False
    ) -> "Dataset":
        return self._with(
            "group_by_window",
            key_fn=FnRef.from_callable(key_fn),
            window_size=window_size,
            drop_remainder=drop_remainder,
        )

    # -- service hand-off ------------------------------------------------------
    def distribute(
        self,
        service: Any = None,
        processing_mode: str = "off",
        job_name: Optional[str] = None,
        num_consumers: int = 0,
        consumer_index: int = 0,
        sharing: bool = False,
        compression: Optional[str] = None,
        target_workers: str = "any",
        max_workers: int = 0,
        weight: float = 1.0,
        resume_offsets: bool = False,
        autocache: bool = False,
        buffer_size: int = 8,
        fetch_window: Optional[int] = None,
        max_batch: Optional[int] = None,
        prefer_batched: bool = True,
        trace_sample: float = 0.0,
    ) -> "Dataset":
        """Process this dataset in a tf.data-service-style deployment.

        ``service`` is a ``repro.core.service.ServiceHandle`` (or dispatcher
        address string for TCP deployments).  Mirrors the paper's Fig. 4 API.
        ``fetch_window``/``max_batch`` tune the batched, pipelined data
        plane (outstanding requests per worker task / elements per RPC;
        ``None`` = the protocol defaults); ``prefer_batched=False`` forces
        the v1 one-element-per-RPC path (baseline measurements, mixed-
        version drills); ``compression`` names a codec (or ``"auto"``)
        negotiated with the dispatcher; ``autocache=True`` lets the
        dispatcher's snapshot policy (repro.snapshot) decide per job
        whether to compute, write-through a snapshot, or read a finished
        one (requires a deployment configured with ``snapshot_root``).
        On a multi-tenant deployment (``scheduling=True``), ``weight``
        sets the job's fleet-scheduler share weight and ``max_workers``
        caps its worker allocation — together the per-job right-sizing
        knobs from the paper's shared-fleet production setup (§3).
        ``trace_sample`` > 0 enables cross-process tracing: the session
        mints a root trace context and samples that fraction of element
        fetches into spans (see ``repro.obs``).
        """
        from ..core.client import DistributedDataset  # lazy: avoid cycle
        from ..core.protocol import DEFAULT_FETCH_WINDOW, DEFAULT_MAX_BATCH

        if fetch_window is None:
            fetch_window = DEFAULT_FETCH_WINDOW
        if max_batch is None:
            max_batch = DEFAULT_MAX_BATCH

        return DistributedDataset(
            graph=self.graph,
            service=service,
            processing_mode=processing_mode,
            job_name=job_name,
            num_consumers=num_consumers,
            consumer_index=consumer_index,
            sharing=sharing,
            compression=compression,
            target_workers=target_workers,
            max_workers=max_workers,
            weight=weight,
            resume_offsets=resume_offsets,
            autocache=autocache,
            buffer_size=buffer_size,
            fetch_window=fetch_window,
            max_batch=max_batch,
            prefer_batched=prefer_batched,
            trace_sample=trace_sample,
        )

    # -- execution --------------------------------------------------------------
    def __iter__(self) -> Iterator[Element]:
        return self.iterator()

    def iterator(
        self,
        ctx: Optional[ExecContext] = None,
        optimize: bool = True,
        autotune: bool = False,
    ) -> Iterator[Element]:
        from .optimizer import optimize_graph  # lazy: avoid cycle

        graph = optimize_graph(self.graph) if optimize else self.graph
        validate(graph)
        ctx = ctx or ExecContext()
        it = build_iterator(graph, ctx)
        if autotune:
            from .autotune import Autotuner

            tuner = Autotuner(ctx)
            tuner.start()
            return _closing_iter(it, tuner.stop)
        return it

    def as_numpy(self, limit: Optional[int] = None) -> List[Element]:
        out = []
        for i, e in enumerate(self):
            if limit is not None and i >= limit:
                break
            out.append(e)
        return out


def _closing_iter(it: Iterator[Element], on_close: Callable[[], None]) -> Iterator[Element]:
    try:
        yield from it
    finally:
        on_close()
