"""Element model for the data pipeline.

An *element* flowing through a pipeline is either a single numpy array or a
(possibly nested) dict of numpy arrays / python scalars.  Elements must be
(a) cheaply size-estimable (for buffer accounting and autotuning),
(b) serializable (workers ship batches to clients over a transport), and
(c) paddable/stackable (for `batch` / `padded_batch`).

Serialization uses a small self-describing binary format (length-prefixed
msgpack with a raw-buffer extension for ndarrays) so that client/worker
processes do not need to share a pickle codebase version.  Pickle remains
available as a fallback codec for exotic payloads.

Zero-copy framing (the ``shm://`` data plane): :func:`encode_elements_into`
writes a frame *directly into a caller-provided buffer* (a shared-memory
ring slot) with no intermediate ``bytes`` object — ndarray payloads are one
``memoryview`` copy into the slot.  Such frames carry the ``R`` (raw
structured) element tag; :func:`decode_elements` over a ``memoryview``
decodes them into ndarray *views borrowing the underlying buffer* (readers
hand out buffer views; see ``core.shm_ring`` for the lease protocol).  The
``R`` tag never appears in persisted data (snapshots keep the ``M``/``P``
encoders byte-for-byte unchanged).
"""
from __future__ import annotations

import io
import pickle
import struct
from typing import Any, Dict, Iterator, List, Mapping, Tuple

import numpy as np

try:  # msgpack is available in-container; fall back to pickle otherwise.
    import msgpack

    _HAVE_MSGPACK = True
except Exception:  # pragma: no cover
    _HAVE_MSGPACK = False

Element = Any  # np.ndarray | scalar | Dict[str, "Element"]

_NDARRAY_EXT = 42


def _pack_ndarray(arr: np.ndarray) -> bytes:
    """Header (dtype, shape) + raw bytes. C-contiguous copy if needed."""
    shape = arr.shape  # before ascontiguousarray: it promotes 0-d to (1,)
    arr = np.ascontiguousarray(arr)
    header = msgpack.packb((arr.dtype.str, shape), use_bin_type=True)
    return struct.pack("<I", len(header)) + header + arr.tobytes()


def _unpack_ndarray(data: bytes) -> np.ndarray:
    (hlen,) = struct.unpack_from("<I", data, 0)
    dtype_str, shape = msgpack.unpackb(data[4 : 4 + hlen], raw=False)
    return np.frombuffer(data[4 + hlen :], dtype=np.dtype(dtype_str)).reshape(shape)


def _default(obj: Any) -> Any:
    if isinstance(obj, np.ndarray):
        return msgpack.ExtType(_NDARRAY_EXT, _pack_ndarray(obj))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot msgpack-encode {type(obj)}")


def _ext_hook(code: int, data: bytes) -> Any:
    if code == _NDARRAY_EXT:
        return _unpack_ndarray(data)
    return msgpack.ExtType(code, data)  # pragma: no cover


def encode_element(elem: Element, codec: str = "msgpack") -> bytes:
    """Serialize an element. codec: 'msgpack' (default) or 'pickle'."""
    if codec == "msgpack" and _HAVE_MSGPACK:
        try:
            return b"M" + msgpack.packb(elem, default=_default, use_bin_type=True)
        except TypeError:
            pass  # fall through to pickle for unsupported payloads
    return b"P" + pickle.dumps(elem, protocol=pickle.HIGHEST_PROTOCOL)


def decode_element(data: Any) -> Element:
    """Decode one element from any bytes-like buffer.

    ``bytes``/``bytearray``/``memoryview`` are all accepted; ``R``-tagged
    elements decoded from a ``memoryview`` yield ndarray views that BORROW
    the buffer (zero copy) — callers owning a transient buffer (a shm ring
    slot) must keep it alive until the views are dead or copy them out.
    """
    tag = bytes(data[:1])
    body = data[1:]
    if tag == b"M":
        return msgpack.unpackb(body, ext_hook=_ext_hook, raw=False, strict_map_key=False)
    if tag == b"P":
        return pickle.loads(body)
    if tag == b"R":
        mv = body if isinstance(body, memoryview) else memoryview(bytes(body))
        val, _ = _r_decode(mv, 0)
        return val
    raise ValueError(f"unknown element codec tag {tag!r}")


# ---------------------------------------------------------------------------
# Raw structured encoding (tag ``R``): buffer-direct, zero-copy decodable
# ---------------------------------------------------------------------------
class FrameTooLarge(ValueError):
    """A frame does not fit the destination buffer (fall back inline)."""


class _NotRaw(Exception):
    """Element not representable in the raw format (use msgpack/pickle)."""


_R_NDARRAY, _R_DICT, _R_LIST, _R_TUPLE = 1, 2, 3, 4
_R_BOOL, _R_INT, _R_FLOAT, _R_NONE, _R_STR, _R_BYTES = 5, 6, 7, 8, 9, 10


def _need(buf: memoryview, off: int, n: int) -> None:
    if off + n > len(buf):
        raise FrameTooLarge(f"frame needs {off + n} bytes, slot has {len(buf)}")


def _r_encode(elem: Any, buf: memoryview, off: int) -> int:
    """Write ``elem`` into ``buf`` at ``off``; returns the end offset."""
    if isinstance(elem, np.ndarray):
        if elem.dtype.hasobject or elem.dtype.names:
            raise _NotRaw
        shape = elem.shape  # before ascontiguousarray: it promotes 0-d to (1,)
        arr = np.ascontiguousarray(elem)
        ds = arr.dtype.str.encode("ascii")
        ndim = len(shape)
        if len(ds) > 255 or ndim > 255:
            raise _NotRaw
        head = 1 + 1 + len(ds) + 1 + 4 * ndim
        _need(buf, off, head + arr.nbytes)
        struct.pack_into("<BB", buf, off, _R_NDARRAY, len(ds))
        off += 2
        buf[off : off + len(ds)] = ds
        off += len(ds)
        struct.pack_into("<B", buf, off, ndim)
        off += 1
        for d in shape:
            struct.pack_into("<I", buf, off, d)
            off += 4
        if arr.nbytes:
            buf[off : off + arr.nbytes] = arr.data.cast("B")
        return off + arr.nbytes
    if isinstance(elem, (bool, np.bool_)):  # before int: bool <: int
        _need(buf, off, 2)
        struct.pack_into("<BB", buf, off, _R_BOOL, 1 if elem else 0)
        return off + 2
    if isinstance(elem, (int, np.integer)):
        v = int(elem)
        if not -(2**63) <= v < 2**63:
            raise _NotRaw
        _need(buf, off, 9)
        struct.pack_into("<Bq", buf, off, _R_INT, v)
        return off + 9
    if isinstance(elem, (float, np.floating)):
        _need(buf, off, 9)
        struct.pack_into("<Bd", buf, off, _R_FLOAT, float(elem))
        return off + 9
    if elem is None:
        _need(buf, off, 1)
        struct.pack_into("<B", buf, off, _R_NONE)
        return off + 1
    if isinstance(elem, str):
        b = elem.encode("utf-8")
        _need(buf, off, 5 + len(b))
        struct.pack_into("<BI", buf, off, _R_STR, len(b))
        buf[off + 5 : off + 5 + len(b)] = b
        return off + 5 + len(b)
    if isinstance(elem, (bytes, bytearray)):
        _need(buf, off, 5 + len(elem))
        struct.pack_into("<BI", buf, off, _R_BYTES, len(elem))
        buf[off + 5 : off + 5 + len(elem)] = bytes(elem)
        return off + 5 + len(elem)
    if isinstance(elem, Mapping):
        items = list(elem.items())
        if not all(isinstance(k, str) for k, _ in items):
            raise _NotRaw
        _need(buf, off, 5)
        struct.pack_into("<BI", buf, off, _R_DICT, len(items))
        off += 5
        for k, v in items:
            kb = k.encode("utf-8")
            if len(kb) > 0xFFFF:
                raise _NotRaw
            _need(buf, off, 2 + len(kb))
            struct.pack_into("<H", buf, off, len(kb))
            buf[off + 2 : off + 2 + len(kb)] = kb
            off = _r_encode(v, buf, off + 2 + len(kb))
        return off
    if isinstance(elem, (list, tuple)):
        _need(buf, off, 5)
        struct.pack_into(
            "<BI", buf, off, _R_LIST if isinstance(elem, list) else _R_TUPLE, len(elem)
        )
        off += 5
        for v in elem:
            off = _r_encode(v, buf, off)
        return off
    raise _NotRaw


def _r_decode(buf: memoryview, off: int) -> Tuple[Any, int]:
    (kind,) = struct.unpack_from("<B", buf, off)
    off += 1
    if kind == _R_NDARRAY:
        (dslen,) = struct.unpack_from("<B", buf, off)
        off += 1
        dt = np.dtype(bytes(buf[off : off + dslen]).decode("ascii"))
        off += dslen
        (ndim,) = struct.unpack_from("<B", buf, off)
        off += 1
        shape = []
        for _ in range(ndim):
            (d,) = struct.unpack_from("<I", buf, off)
            shape.append(d)
            off += 4
        nbytes = dt.itemsize * int(np.prod(shape, dtype=np.int64)) if shape else dt.itemsize
        arr = np.frombuffer(buf[off : off + nbytes], dtype=dt).reshape(shape)
        # the view may borrow writable (shared) memory; readers must not
        # scribble on the producer's ring slot through it
        arr.flags.writeable = False
        return arr, off + nbytes
    if kind == _R_BOOL:
        (v,) = struct.unpack_from("<B", buf, off)
        return bool(v), off + 1
    if kind == _R_INT:
        (v,) = struct.unpack_from("<q", buf, off)
        return v, off + 8
    if kind == _R_FLOAT:
        (v,) = struct.unpack_from("<d", buf, off)
        return v, off + 8
    if kind == _R_NONE:
        return None, off
    if kind == _R_STR:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        return bytes(buf[off : off + n]).decode("utf-8"), off + n
    if kind == _R_BYTES:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        return bytes(buf[off : off + n]), off + n
    if kind == _R_DICT:
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        d: Dict[str, Any] = {}
        for _ in range(n):
            (kl,) = struct.unpack_from("<H", buf, off)
            off += 2
            k = bytes(buf[off : off + kl]).decode("utf-8")
            off += kl
            d[k], off = _r_decode(buf, off)
        return d, off
    if kind in (_R_LIST, _R_TUPLE):
        (n,) = struct.unpack_from("<I", buf, off)
        off += 4
        vals = []
        for _ in range(n):
            v, off = _r_decode(buf, off)
            vals.append(v)
        return (vals if kind == _R_LIST else tuple(vals)), off
    raise ValueError(f"unknown raw element kind {kind}")


def encode_element_into(elem: Element, buf: memoryview, off: int = 0) -> int:
    """Encode one element directly into ``buf`` at ``off``; returns end.

    Prefers the raw structured format (tag ``R``: one ``memoryview`` copy
    per ndarray, zero-copy decodable); payloads it cannot represent fall
    back to :func:`encode_element` bytes copied in.  Raises
    :class:`FrameTooLarge` when the element does not fit.
    """
    try:
        _need(buf, off, 1)
        end = _r_encode(elem, buf, off + 1)
        buf[off : off + 1] = b"R"
        return end
    except _NotRaw:
        b = encode_element(elem)
        _need(buf, off, len(b))
        buf[off : off + len(b)] = b
        return off + len(b)


def encode_elements_into(elems: List[Element], buf: memoryview) -> int:
    """Write an :func:`encode_elements`-layout frame directly into ``buf``.

    Returns the frame length.  The layout is identical to
    :func:`encode_elements` (``<u32 count> (<u32 len> <element>)*``) so
    :func:`decode_elements` reads either; only the per-element tag differs
    (``R`` where representable).  Raises :class:`FrameTooLarge` when the
    frame overflows ``buf`` — callers fall back to the inline path.
    """
    _need(buf, 0, 4)
    struct.pack_into("<I", buf, 0, len(elems))
    off = 4
    for e in elems:
        _need(buf, off, 4)
        end = encode_element_into(e, buf, off + 4)
        struct.pack_into("<I", buf, off, end - off - 4)
        off = end
    return off


def copy_element(elem: Element) -> Element:
    """Deep-copy any buffer-borrowing ndarray views out of an element.

    Used by consumers of zero-copy frames that need the element to outlive
    the underlying ring slot lease.
    """

    def leaf(x: Any) -> Any:
        if isinstance(x, np.ndarray) and not x.flags.owndata:
            return np.array(x, copy=True)
        return x

    return map_structure(leaf, elem)


def encode_elements(elems: List[Element], codec: str = "msgpack") -> bytes:
    """Serialize a LIST of elements into one self-describing frame.

    Frame layout: ``<u32 count> (<u32 len> <encoded element>)*``.  Used by
    the batched data plane (``get_elements``): a worker encodes up to
    ``max_batch`` elements into one frame and compresses the frame ONCE, so
    per-RPC compression and framing overhead is amortized across the batch.
    """
    parts = [struct.pack("<I", len(elems))]
    for e in elems:
        b = encode_element(e, codec)
        parts.append(struct.pack("<I", len(b)))
        parts.append(b)
    return b"".join(parts)


def decode_elements(data: Any) -> List[Element]:
    """Inverse of :func:`encode_elements` / :func:`encode_elements_into`.

    Accepts any bytes-like buffer.  Over a ``memoryview``, ``R``-tagged
    elements decode into views that borrow the buffer (zero copy).
    """
    mv = data if isinstance(data, memoryview) else memoryview(data)
    (count,) = struct.unpack_from("<I", mv, 0)
    off = 4
    out: List[Element] = []
    for _ in range(count):
        (n,) = struct.unpack_from("<I", mv, off)
        off += 4
        out.append(decode_element(mv[off : off + n]))
        off += n
    return out


def element_nbytes(elem: Element) -> int:
    """Approximate in-memory footprint of an element (for buffer accounting)."""
    if isinstance(elem, np.ndarray):
        return elem.nbytes
    if isinstance(elem, Mapping):
        return sum(element_nbytes(v) for v in elem.values())
    if isinstance(elem, (list, tuple)):
        return sum(element_nbytes(v) for v in elem)
    if isinstance(elem, (bytes, bytearray, str)):
        return len(elem)
    return 8  # scalar


def map_structure(fn, elem: Element) -> Element:
    if isinstance(elem, Mapping):
        return {k: map_structure(fn, v) for k, v in elem.items()}
    if isinstance(elem, (list, tuple)):
        return type(elem)(map_structure(fn, v) for v in elem)
    return fn(elem)


def flatten_structure(elem: Element) -> List[Any]:
    out: List[Any] = []

    def rec(e):
        if isinstance(e, Mapping):
            for k in sorted(e.keys()):
                rec(e[k])
        elif isinstance(e, (list, tuple)):
            for v in e:
                rec(v)
        else:
            out.append(e)

    rec(elem)
    return out


def _as_array(x: Any) -> np.ndarray:
    return x if isinstance(x, np.ndarray) else np.asarray(x)


def stack_elements(elems: List[Element]) -> Element:
    """Stack a list of same-structure elements into one batched element."""
    first = elems[0]
    if isinstance(first, Mapping):
        return {k: stack_elements([e[k] for e in elems]) for k in first.keys()}
    if isinstance(first, (list, tuple)):
        return type(first)(
            stack_elements([e[i] for e in elems]) for i in range(len(first))
        )
    return np.stack([_as_array(e) for e in elems])


def padded_stack_elements(
    elems: List[Element], pad_value: float = 0, pad_to_multiple: int = 1
) -> Element:
    """Stack variable-length leading-dim arrays, padding to the max length.

    ``pad_to_multiple`` rounds the padded length up (bucket-friendly shapes).
    Scalars/uniform arrays are stacked normally.
    """
    first = elems[0]
    if isinstance(first, Mapping):
        return {
            k: padded_stack_elements([e[k] for e in elems], pad_value, pad_to_multiple)
            for k in first.keys()
        }
    if isinstance(first, (list, tuple)):
        return type(first)(
            padded_stack_elements([e[i] for e in elems], pad_value, pad_to_multiple)
            for i in range(len(first))
        )
    arrs = [_as_array(e) for e in elems]
    if arrs[0].ndim == 0:
        return np.stack(arrs)
    max_len = max(a.shape[0] for a in arrs)
    if pad_to_multiple > 1:
        max_len = -(-max_len // pad_to_multiple) * pad_to_multiple
    out = np.full(
        (len(arrs), max_len) + arrs[0].shape[1:], pad_value, dtype=arrs[0].dtype
    )
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
    return out


def element_spec(elem: Element) -> Element:
    """(shape, dtype) spec tree for an element."""

    def spec(x):
        a = _as_array(x)
        return (tuple(a.shape), str(a.dtype))

    return map_structure(spec, elem)
