"""Element model for the data pipeline.

An *element* flowing through a pipeline is either a single numpy array or a
(possibly nested) dict of numpy arrays / python scalars.  Elements must be
(a) cheaply size-estimable (for buffer accounting and autotuning),
(b) serializable (workers ship batches to clients over a transport), and
(c) paddable/stackable (for `batch` / `padded_batch`).

Serialization uses a small self-describing binary format (length-prefixed
msgpack with a raw-buffer extension for ndarrays) so that client/worker
processes do not need to share a pickle codebase version.  Pickle remains
available as a fallback codec for exotic payloads.
"""
from __future__ import annotations

import io
import pickle
import struct
from typing import Any, Dict, Iterator, List, Mapping, Tuple

import numpy as np

try:  # msgpack is available in-container; fall back to pickle otherwise.
    import msgpack

    _HAVE_MSGPACK = True
except Exception:  # pragma: no cover
    _HAVE_MSGPACK = False

Element = Any  # np.ndarray | scalar | Dict[str, "Element"]

_NDARRAY_EXT = 42


def _pack_ndarray(arr: np.ndarray) -> bytes:
    """Header (dtype, shape) + raw bytes. C-contiguous copy if needed."""
    arr = np.ascontiguousarray(arr)
    header = msgpack.packb((arr.dtype.str, arr.shape), use_bin_type=True)
    return struct.pack("<I", len(header)) + header + arr.tobytes()


def _unpack_ndarray(data: bytes) -> np.ndarray:
    (hlen,) = struct.unpack_from("<I", data, 0)
    dtype_str, shape = msgpack.unpackb(data[4 : 4 + hlen], raw=False)
    return np.frombuffer(data[4 + hlen :], dtype=np.dtype(dtype_str)).reshape(shape)


def _default(obj: Any) -> Any:
    if isinstance(obj, np.ndarray):
        return msgpack.ExtType(_NDARRAY_EXT, _pack_ndarray(obj))
    if isinstance(obj, (np.integer,)):
        return int(obj)
    if isinstance(obj, (np.floating,)):
        return float(obj)
    if isinstance(obj, (np.bool_,)):
        return bool(obj)
    raise TypeError(f"cannot msgpack-encode {type(obj)}")


def _ext_hook(code: int, data: bytes) -> Any:
    if code == _NDARRAY_EXT:
        return _unpack_ndarray(data)
    return msgpack.ExtType(code, data)  # pragma: no cover


def encode_element(elem: Element, codec: str = "msgpack") -> bytes:
    """Serialize an element. codec: 'msgpack' (default) or 'pickle'."""
    if codec == "msgpack" and _HAVE_MSGPACK:
        try:
            return b"M" + msgpack.packb(elem, default=_default, use_bin_type=True)
        except TypeError:
            pass  # fall through to pickle for unsupported payloads
    return b"P" + pickle.dumps(elem, protocol=pickle.HIGHEST_PROTOCOL)


def decode_element(data: bytes) -> Element:
    tag, body = data[:1], data[1:]
    if tag == b"M":
        return msgpack.unpackb(body, ext_hook=_ext_hook, raw=False, strict_map_key=False)
    if tag == b"P":
        return pickle.loads(body)
    raise ValueError(f"unknown element codec tag {tag!r}")


def encode_elements(elems: List[Element], codec: str = "msgpack") -> bytes:
    """Serialize a LIST of elements into one self-describing frame.

    Frame layout: ``<u32 count> (<u32 len> <encoded element>)*``.  Used by
    the batched data plane (``get_elements``): a worker encodes up to
    ``max_batch`` elements into one frame and compresses the frame ONCE, so
    per-RPC compression and framing overhead is amortized across the batch.
    """
    parts = [struct.pack("<I", len(elems))]
    for e in elems:
        b = encode_element(e, codec)
        parts.append(struct.pack("<I", len(b)))
        parts.append(b)
    return b"".join(parts)


def decode_elements(data: bytes) -> List[Element]:
    """Inverse of :func:`encode_elements`."""
    (count,) = struct.unpack_from("<I", data, 0)
    off = 4
    out: List[Element] = []
    for _ in range(count):
        (n,) = struct.unpack_from("<I", data, off)
        off += 4
        out.append(decode_element(data[off : off + n]))
        off += n
    return out


def element_nbytes(elem: Element) -> int:
    """Approximate in-memory footprint of an element (for buffer accounting)."""
    if isinstance(elem, np.ndarray):
        return elem.nbytes
    if isinstance(elem, Mapping):
        return sum(element_nbytes(v) for v in elem.values())
    if isinstance(elem, (list, tuple)):
        return sum(element_nbytes(v) for v in elem)
    if isinstance(elem, (bytes, bytearray, str)):
        return len(elem)
    return 8  # scalar


def map_structure(fn, elem: Element) -> Element:
    if isinstance(elem, Mapping):
        return {k: map_structure(fn, v) for k, v in elem.items()}
    if isinstance(elem, (list, tuple)):
        return type(elem)(map_structure(fn, v) for v in elem)
    return fn(elem)


def flatten_structure(elem: Element) -> List[Any]:
    out: List[Any] = []

    def rec(e):
        if isinstance(e, Mapping):
            for k in sorted(e.keys()):
                rec(e[k])
        elif isinstance(e, (list, tuple)):
            for v in e:
                rec(v)
        else:
            out.append(e)

    rec(elem)
    return out


def _as_array(x: Any) -> np.ndarray:
    return x if isinstance(x, np.ndarray) else np.asarray(x)


def stack_elements(elems: List[Element]) -> Element:
    """Stack a list of same-structure elements into one batched element."""
    first = elems[0]
    if isinstance(first, Mapping):
        return {k: stack_elements([e[k] for e in elems]) for k in first.keys()}
    if isinstance(first, (list, tuple)):
        return type(first)(
            stack_elements([e[i] for e in elems]) for i in range(len(first))
        )
    return np.stack([_as_array(e) for e in elems])


def padded_stack_elements(
    elems: List[Element], pad_value: float = 0, pad_to_multiple: int = 1
) -> Element:
    """Stack variable-length leading-dim arrays, padding to the max length.

    ``pad_to_multiple`` rounds the padded length up (bucket-friendly shapes).
    Scalars/uniform arrays are stacked normally.
    """
    first = elems[0]
    if isinstance(first, Mapping):
        return {
            k: padded_stack_elements([e[k] for e in elems], pad_value, pad_to_multiple)
            for k in first.keys()
        }
    if isinstance(first, (list, tuple)):
        return type(first)(
            padded_stack_elements([e[i] for e in elems], pad_value, pad_to_multiple)
            for i in range(len(first))
        )
    arrs = [_as_array(e) for e in elems]
    if arrs[0].ndim == 0:
        return np.stack(arrs)
    max_len = max(a.shape[0] for a in arrs)
    if pad_to_multiple > 1:
        max_len = -(-max_len // pad_to_multiple) * pad_to_multiple
    out = np.full(
        (len(arrs), max_len) + arrs[0].shape[1:], pad_value, dtype=arrs[0].dtype
    )
    for i, a in enumerate(arrs):
        out[i, : a.shape[0]] = a
    return out


def element_spec(elem: Element) -> Element:
    """(shape, dtype) spec tree for an element."""

    def spec(x):
        a = _as_array(x)
        return (tuple(a.shape), str(a.dtype))

    return map_structure(spec, elem)
