"""Runtime AUTOTUNE harness (paper §3.2).

A background thread periodically inspects per-op stats and hill-climbs the
knobs flagged AUTOTUNE:

* parallel-map width — increased while the op is the pipeline bottleneck
  (highest busy-time share) and the last increase improved throughput;
  decreased when an increase regressed (classic 1D hill climb, the same shape
  as tf.data's gradient-free tuner).
* prefetch buffer size — increased while the buffer runs near-empty
  (consumer starving) and capped by a memory budget.
"""
from __future__ import annotations

import logging
import threading
import time
from dataclasses import dataclass, field
from typing import Dict, Optional, Set

from ..obs.profiling import attribute_stalls
from ..obs.registry import get_registry
from .iterators import ExecContext, Knob, OpStats

logger = logging.getLogger(__name__)


@dataclass
class _KnobState:
    last_value: int = 0
    last_rate: float = 0.0
    last_elements: int = 0
    last_time: float = 0.0
    direction: int = 1
    primed: bool = False  # last_rate holds a real measured window


class Autotuner:
    def __init__(
        self,
        ctx: ExecContext,
        interval: float = 0.25,
        ram_budget_bytes: int = 1 << 30,
    ):
        self._ctx = ctx
        self._interval = interval
        self._ram_budget = ram_budget_bytes
        self._states: Dict[int, _KnobState] = {}
        self._thread: Optional[threading.Thread] = None
        self._stop = threading.Event()
        self._logged_errors: Set[type] = set()
        # Serializes tuning steps: tests drive step() synchronously while
        # the start()ed background thread also calls it; the _KnobState
        # rate windows are read-modify-write, so two overlapping steps
        # would compute a bogus rate from a half-updated window.
        self._step_lock = threading.Lock()

    # -- lifecycle -------------------------------------------------------
    def start(self) -> None:
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def stop(self) -> None:
        self._stop.set()
        if self._thread:
            self._thread.join(timeout=1.0)

    def _run(self) -> None:
        while not self._stop.is_set() and not self._ctx.stop_event.is_set():
            time.sleep(self._interval)
            try:
                self.step()
            except Exception as e:
                # the tuner must never kill the pipeline, but a silent
                # bare-except disabled tuning forever without a trace —
                # count every occurrence, log the first of each type
                get_registry().counter(
                    "autotuner_errors_total",
                    "swallowed autotuner step failures, by exception type",
                ).labels(kind=type(e).__name__).inc()
                if type(e) not in self._logged_errors:
                    self._logged_errors.add(type(e))
                    logger.warning(
                        "autotuner step failed with %r (further %s "
                        "suppressed)",
                        e,
                        type(e).__name__,
                    )

    # -- one tuning step (also callable synchronously from tests) ---------
    def step(self) -> None:
        now = time.perf_counter()
        with self._step_lock:
            # ctx.stats values are written by the pipeline's iterator
            # threads WITHOUT this lock: OpStats counters are monotonic
            # and GIL-atomic, so an unlocked read is at worst one window
            # stale — it delays a tuning decision, never corrupts one.
            # list() snapshots the dict against concurrent op insertion.
            snapshot = list(self._ctx.stats.items())
            # Stall attribution replaces the old coarse rate probe: only
            # the op with the lowest modeled capacity gets its parallelism
            # climbed.  Widening a non-bottleneck op can't raise pipeline
            # throughput, so the old tune-everything loop spent its rate
            # windows oscillating knobs that didn't matter.  Before any op
            # has measured cost the report names no bottleneck and we fall
            # back to tuning every AUTOTUNE knob.
            report = attribute_stalls(self._ctx.stats)
            bottleneck_idx = report.get("bottleneck_index")
            for idx, stats in snapshot:
                if stats.parallelism is not None and stats.parallelism.autotune:
                    if bottleneck_idx is None or idx == bottleneck_idx:
                        self._tune_parallelism(idx, stats, now)
                if stats.buffer_size is not None and stats.buffer_size.autotune:
                    self._tune_buffer(stats)

    def _tune_parallelism(self, idx: int, stats: OpStats, now: float) -> None:
        """Caller must hold ``self._step_lock`` (_KnobState windows)."""
        knob = stats.parallelism
        st = self._states.setdefault(idx, _KnobState(last_value=knob.get()))
        dt = now - st.last_time
        if st.last_time == 0.0 or dt <= 0:
            st.last_time, st.last_elements = now, stats.elements
            return
        rate = (stats.elements - st.last_elements) / dt
        if not st.primed:
            # the first REAL measurement only seeds the baseline: last_rate
            # starts at 0.0, so comparing against it would count any rate —
            # including a fully stalled 0 elements/s — as a 5% improvement
            # and bump parallelism on zero evidence
            st.primed = True
        elif rate > 0 and rate >= st.last_rate * 1.05:
            # genuinely improving: keep moving in the same direction
            knob.value = max(knob.minimum, min(knob.maximum, knob.get() + st.direction))
        elif rate < st.last_rate * 0.95:
            # regressed: flip direction and step back
            st.direction = -st.direction
            knob.value = max(knob.minimum, min(knob.maximum, knob.get() + st.direction))
        st.last_rate, st.last_elements, st.last_time = rate, stats.elements, now

    def _tune_buffer(self, stats: OpStats) -> None:
        knob = stats.buffer_size
        # Consumer starving (buffer mostly empty) => producer-bound; a deeper
        # buffer only helps smooth bursts, grow gently. Buffer mostly full =>
        # already ahead; shrink to return memory.
        if stats.buffer_occupancy < 0.1:
            knob.value = min(knob.maximum, knob.get() + 1)
        elif stats.buffer_occupancy > 0.9 and knob.get() > knob.minimum:
            knob.value = knob.get() - 1

    # -- introspection ------------------------------------------------------
    def snapshot(self) -> Dict[int, Dict[str, float]]:
        out: Dict[int, Dict[str, float]] = {}
        for idx, stats in self._ctx.stats.items():
            out[idx] = {
                "name": stats.name,
                "elements": stats.elements,
                "mean_cost": stats.mean_cost,
                "parallelism": stats.parallelism.get() if stats.parallelism else 0,
                "buffer": stats.buffer_size.get() if stats.buffer_size else 0,
                "occupancy": stats.buffer_occupancy,
            }
        return out
