"""Canonical input pipelines mirroring the paper's workload domains.

The paper evaluates vision models (M1-M4, ResNet50/ImageNet+AutoAugment) and
NLP models (M5-M8, variable sequence length).  We provide equivalent
open pipelines with *registered* (serializable) UDFs:

* ``vision_pipeline`` — decode (simulated JPEG-cost) → random crop → flip →
  AutoAugment-like photometric ops → normalize → batch.  Heavy per-element
  CPU cost ⇒ input-bound jobs; the horizontal scale-out benchmark uses it.
* ``nlp_pipeline``   — tokenized variable-length sequences → (optional)
  bucket-by-length → padded batch.  Feeds the coordinated-reads benchmark.

Work knobs are explicit (``work_factor``) so benchmarks can dial
preprocessing cost to reproduce both input-bound and model-bound regimes.
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from .dataset import Dataset
from .graph import AUTOTUNE
from .registry import register


# ---------------------------------------------------------------------------
# Synthetic sources
# ---------------------------------------------------------------------------
@register("synthetic_raw_image")
def synthetic_raw_image(i: Any, *, size: int = 64, seed: int = 0) -> Dict[str, Any]:
    """Deterministic pseudo-'encoded' image: byte payload + label."""
    rng = np.random.RandomState((int(i) + seed * 1_000_003) & 0x7FFFFFFF)
    raw = rng.randint(0, 256, size=(size, size, 3), dtype=np.uint8)
    return {"raw": raw, "label": np.int64(int(i) % 1000), "index": np.int64(int(i))}


@register("synthetic_token_seq")
def synthetic_token_seq(
    i: Any, *, max_len: int = 512, vocab: int = 32000, seed: int = 0
) -> Dict[str, Any]:
    """Variable-length token sequence with a long-tail length distribution
    (mimics NLP corpora; drives straggler effects in distributed training)."""
    rng = np.random.RandomState((int(i) * 2_654_435 + seed) & 0x7FFFFFFF)
    # lognormal length, clipped to [4, max_len]
    ln = int(np.clip(rng.lognormal(mean=4.0, sigma=0.8), 4, max_len))
    toks = rng.randint(1, vocab, size=(ln,), dtype=np.int32)
    return {"tokens": toks, "length": np.int64(ln), "index": np.int64(int(i))}


# ---------------------------------------------------------------------------
# Vision transforms (decode + augment; the input-bound hot path)
# ---------------------------------------------------------------------------
@register("simulate_decode")
def simulate_decode(elem: Dict[str, Any], *, work_factor: int = 1) -> Dict[str, Any]:
    """Simulated JPEG decode: real FLOPs proportional to image size.

    Uses a DCT-like transform so the CPU cost profile matches decode+IDCT
    (the dominant cost in the paper's vision pipelines).
    """
    img = elem["raw"].astype(np.float32) / 255.0
    for _ in range(max(1, work_factor)):
        # 2D transform along W per channel — O(H*W*K) like a real IDCT
        img = np.tanh(np.einsum("hwc,wk->hkc", img, _dct_matrix(img.shape[1])))
    return {"image": img, "label": elem["label"], "index": elem["index"]}


_DCT_CACHE: Dict[int, np.ndarray] = {}


def _dct_matrix(n: int) -> np.ndarray:
    if n not in _DCT_CACHE:
        k = np.arange(n)
        _DCT_CACHE[n] = np.cos(np.pi / n * np.outer(k + 0.5, k)).astype(np.float32) / n
    return _DCT_CACHE[n]


@register("random_crop_flip")
def random_crop_flip(
    elem: Dict[str, Any], *, crop: int = 56, seed: int = 0
) -> Dict[str, Any]:
    img = elem["image"]
    rng = np.random.RandomState((int(elem["index"]) + seed) & 0x7FFFFFFF)
    h, w = img.shape[:2]
    if h > crop and w > crop:
        y, x = rng.randint(0, h - crop), rng.randint(0, w - crop)
        img = img[y : y + crop, x : x + crop]
    if rng.rand() < 0.5:
        img = img[:, ::-1]
    return {"image": np.ascontiguousarray(img), "label": elem["label"], "index": elem["index"]}


@register("autoaugment_like")
def autoaugment_like(elem: Dict[str, Any], *, seed: int = 0, ops: int = 2) -> Dict[str, Any]:
    """AutoAugment-style photometric policy (contrast/brightness/posterize/
    sharpen-ish convolutions) — the expensive augmentation in the paper's
    ResNet50 experiment."""
    img = elem["image"]
    rng = np.random.RandomState((int(elem["index"]) * 97 + seed) & 0x7FFFFFFF)
    for _ in range(ops):
        choice = rng.randint(0, 4)
        if choice == 0:  # contrast
            img = np.clip((img - img.mean()) * (0.5 + rng.rand()) + img.mean(), 0, 1)
        elif choice == 1:  # brightness
            img = np.clip(img + (rng.rand() - 0.5) * 0.4, 0, 1)
        elif choice == 2:  # posterize
            bits = rng.randint(4, 8)
            img = np.floor(img * (2**bits)) / (2**bits)
        else:  # 3x3 blur (separable)
            kernel = np.array([0.25, 0.5, 0.25], dtype=np.float32)
            img = _sep_conv3(img, kernel)
    return {"image": img.astype(np.float32), "label": elem["label"], "index": elem["index"]}


def _sep_conv3(img: np.ndarray, k: np.ndarray) -> np.ndarray:
    pad = np.pad(img, ((1, 1), (0, 0), (0, 0)), mode="edge")
    img = k[0] * pad[:-2] + k[1] * pad[1:-1] + k[2] * pad[2:]
    pad = np.pad(img, ((0, 0), (1, 1), (0, 0)), mode="edge")
    return k[0] * pad[:, :-2] + k[1] * pad[:, 1:-1] + k[2] * pad[:, 2:]


@register("normalize_image")
def normalize_image(elem: Dict[str, Any]) -> Dict[str, Any]:
    img = (elem["image"] - 0.45) / 0.225
    return {"image": img.astype(np.float32), "label": elem["label"]}


# ---------------------------------------------------------------------------
# NLP helpers
# ---------------------------------------------------------------------------
@register("seq_length")
def seq_length(elem: Dict[str, Any]) -> int:
    return int(elem["length"])


@register("batch_bucket_key")
def batch_bucket_key(batch: Dict[str, Any]) -> int:
    return int(batch["_bucket"])


@register("identity_window")
def identity_window(window: List[Any]) -> List[Any]:
    return window


# ---------------------------------------------------------------------------
# Pipeline factories
# ---------------------------------------------------------------------------
def vision_pipeline(
    num_elements: int = 1024,
    batch_size: int = 32,
    image_size: int = 64,
    crop: int = 56,
    work_factor: int = 1,
    parallelism: int = AUTOTUNE,
    shuffle_buffer: int = 256,
    seed: int = 0,
) -> Dataset:
    ds = Dataset.range(num_elements)
    ds = ds.map(synthetic_raw_image, size=image_size, seed=seed)
    ds = ds.shuffle(shuffle_buffer, seed=seed)
    ds = ds.map(
        simulate_decode, num_parallel_calls=parallelism, work_factor=work_factor
    )
    ds = ds.map(random_crop_flip, stochastic=True, crop=crop, seed=seed)
    ds = ds.map(autoaugment_like, stochastic=True, seed=seed)
    ds = ds.map(normalize_image)
    ds = ds.batch(batch_size, drop_remainder=True)
    return ds


def nlp_pipeline(
    num_elements: int = 4096,
    batch_size: int = 16,
    max_len: int = 512,
    vocab: int = 32000,
    bucket_boundaries: Optional[Sequence[int]] = None,
    num_consumers: int = 0,
    seed: int = 0,
) -> Dataset:
    """Variable-length NLP pipeline.

    Without buckets: naive padded-batch to the max length in each batch.
    With buckets (+ optional num_consumers): the paper's coordinated-reads
    front-end (Fig. 7) — bucket_by_sequence_length → group_by_window(m) →
    flat_map.
    """
    ds = Dataset.range(num_elements)
    ds = ds.map(synthetic_token_seq, max_len=max_len, vocab=vocab, seed=seed)
    if bucket_boundaries is None:
        return ds.padded_batch(batch_size, drop_remainder=True)
    ds = ds.bucket_by_sequence_length(
        boundaries=list(bucket_boundaries),
        batch_size=batch_size,
        length_fn=seq_length,
        drop_remainder=True,
        emit_bucket_id=True,
        pad_to_boundary=True,
    )
    if num_consumers > 1:
        ds = ds.group_by_window(
            key_fn=batch_bucket_key, window_size=num_consumers, drop_remainder=True
        )
        ds = ds.flat_map(identity_window)
    return ds


def materialized(ds: Dataset, snapshot_path: str, tail: bool = False) -> Dataset:
    """Swap a pipeline for its materialized snapshot when one is available.

    The manual (policy-free) entry point to snapshot reuse: if a finished
    snapshot exists at ``snapshot_path`` — or any snapshot exists and
    ``tail=True`` — return a dataset reading it (zero recomputation);
    otherwise return ``ds`` unchanged so the caller computes as usual.
    Pair with ``repro.core.materialize`` to write the snapshot; use
    ``autocache=True`` on ``Dataset.distribute`` for the cost-model-driven
    version of this decision.
    """
    from ..snapshot.reader import snapshot_exists, snapshot_finished

    if snapshot_finished(snapshot_path) or (tail and snapshot_exists(snapshot_path)):
        return Dataset.from_snapshot(snapshot_path, tail=tail)
    return ds
