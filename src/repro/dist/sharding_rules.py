"""Parameter / optimizer / batch / cache PartitionSpec derivation.

Megatron-style tensor parallelism over ``plan.model_axis`` plus FSDP
(ZeRO-3) over ``plan.fsdp_axis``:

  * projections IN to a wide space (wq/wk/wv, mlp w1/w3, ssm in_proj,
    lm_head) shard the wide output dim over the model axis and the d_model
    input dim over the fsdp axis;
  * projections OUT of the wide space (wo, mlp w2, ssm out_proj) shard the
    wide input dim over the model axis and d_model over fsdp;
  * MoE expert stacks shard the expert dim over ``plan.moe_expert_axis``
    (the ff dim additionally over the model axis when the expert axis is a
    different mesh axis);
  * the embedding shards vocab over the model axis (Megatron vocab
    parallelism), d_model over fsdp;
  * 1-D params (norm scales, biases, A_log/D/dt_bias) replicate — they are
    O(d) and not worth collective traffic.

Every axis assignment is divisibility-gated: a dim that the mesh axis does
not evenly divide falls back to replication for that dim instead of
crashing (head_dim 7 on a 4-way axis must degrade, not abort a launch).
Stacked scan-over-layers leaves are handled by aligning each rule to the
TRAILING dims and replicating the leading layer-stack dims.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Sequence, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig
from .context import ShardingPlan

# role tokens for trailing dims: F = fsdp axis, M = model axis,
# E = expert axis, X = model axis only if the expert axis differs from it,
# None = replicate
_Role = Optional[str]

_IN_PROJ: Tuple[_Role, ...] = ("F", "M")
_OUT_PROJ: Tuple[_Role, ...] = ("M", "F")

_LEAF_RULES: Dict[str, Tuple[_Role, ...]] = {
    "wq": _IN_PROJ,
    "wk": _IN_PROJ,
    "wv": _IN_PROJ,
    "wo": _OUT_PROJ,
    "in_proj": _IN_PROJ,
    "out_proj": _OUT_PROJ,
    "lm_head": _IN_PROJ,
    "embed": ("M", "F"),  # Megatron vocab-parallel embedding
    "router": ("F", None),
    "conv_w": (None, None),
}

_MOE_RULES: Dict[str, Tuple[_Role, ...]] = {
    "w1": ("E", "F", "X"),
    "w3": ("E", "F", "X"),
    "w2": ("E", "X", "F"),
}

_MLP_RULES: Dict[str, Tuple[_Role, ...]] = {
    "w1": _IN_PROJ,
    "w3": _IN_PROJ,
    "w2": _OUT_PROJ,
}


def _path_names(path: Sequence[Any]) -> Tuple[str, ...]:
    names = []
    for k in path:
        name = getattr(k, "key", None)
        if name is None:
            name = getattr(k, "name", None)
        if isinstance(name, str):
            names.append(name)
    return tuple(names)


def _trailing_roles(names: Tuple[str, ...]) -> Optional[Tuple[_Role, ...]]:
    leaf = names[-1] if names else ""
    if leaf in ("w1", "w2", "w3"):
        return _MOE_RULES[leaf] if "moe" in names else _MLP_RULES[leaf]
    return _LEAF_RULES.get(leaf)


def _role_to_axes(role: _Role, plan: ShardingPlan) -> Tuple[str, ...]:
    if role == "F":
        return plan.fsdp_axes
    if role == "M":
        return (plan.model_axis,)
    if role == "E":
        return (plan.moe_expert_axis,)
    if role == "X":
        if plan.moe_expert_axis != plan.model_axis:
            return (plan.model_axis,)
    return ()


def _axes_size(mesh: Mesh, axes: Tuple[str, ...]) -> int:
    size = 1
    for a in axes:
        size *= int(mesh.shape.get(a, 0) or 0)
    return size


def _build_spec(
    shape: Sequence[int],
    roles: Tuple[_Role, ...],
    plan: ShardingPlan,
    mesh: Mesh,
) -> P:
    """Align ``roles`` to the trailing dims; divisibility-gate each axis."""
    ndim = len(shape)
    lead = ndim - len(roles)
    if lead < 0:  # rule written for more dims than the leaf has: replicate
        return P()
    parts: list = [None] * lead
    used: set = set()
    for dim, role in zip(shape[lead:], roles):
        axes = _role_to_axes(role, plan)
        size = _axes_size(mesh, axes) if axes else 0
        if axes and size > 0 and dim % size == 0 and not (set(axes) & used):
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else tuple(axes))
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def param_spec(
    path: Sequence[Any],
    leaf: Any,
    cfg: ModelConfig,
    plan: ShardingPlan,
    mesh: Mesh,
) -> NamedSharding:
    """Sharding for one parameter leaf, identified by its tree path."""
    shape = tuple(getattr(leaf, "shape", ()))
    names = _path_names(path)
    roles = _trailing_roles(names)
    if roles is None:
        if len(shape) >= 2:  # unknown matrix: generic (fsdp, model) split
            roles = _IN_PROJ
        else:  # scalars / vectors replicate
            return NamedSharding(mesh, P())
    return NamedSharding(mesh, _build_spec(shape, roles, plan, mesh))


def make_param_shardings(
    mesh: Mesh, pshape: Any, cfg: ModelConfig, plan: ShardingPlan
) -> Any:
    """A NamedSharding for every leaf of the params (shape-)tree."""
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: param_spec(path, leaf, cfg, plan, mesh), pshape
    )


def make_opt_shardings(
    mesh: Mesh, oshape: Any, cfg: ModelConfig, plan: ShardingPlan
) -> Any:
    """Optimizer-state shardings: the m/v moment trees mirror the param
    shardings (moments co-locate with their param shards); counters and any
    other scalars replicate."""

    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("m", "v", "mu", "nu"):
            return param_spec(path[1:], leaf, cfg, plan, mesh)
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, oshape)


def batch_sharding(mesh: Mesh, plan: ShardingPlan, in_specs: Any) -> Any:
    """Input batches shard their leading (global batch) dim over the data
    axes; all other dims replicate."""
    data = tuple(plan.data_axes)
    dsize = _axes_size(mesh, data)

    def one(leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        if shape and dsize > 0 and shape[0] % dsize == 0:
            spec = P(data[0] if len(data) == 1 else data)
        else:
            spec = P()
        return NamedSharding(mesh, spec)

    return jax.tree.map(one, in_specs)


# cache leaf name -> index of its heads dim (the dim sharded over the
# model axis): KV caches are (B, S, Hkv, D), SSM state is (B, H, N, P).
# Conv tails ("conv": (B, K-1, Ch)) and anything unrecognized get batch-only.
_CACHE_HEAD_DIM = {"k": 2, "v": 2, "h": 1}


def cache_sharding(
    mesh: Mesh, plan: ShardingPlan, cache_shape: Any, cfg: ModelConfig
) -> Any:
    """KV / SSM decode caches: batch over the data axes; the heads dim —
    identified by leaf NAME, the same way param_spec keys its rules — over
    the model axis when it divides."""
    data = tuple(plan.data_axes)
    dsize = _axes_size(mesh, data)
    msize = _axes_size(mesh, (plan.model_axis,))

    def one(path, leaf):
        shape = tuple(getattr(leaf, "shape", ()))
        parts: list = [None] * len(shape)
        if shape and dsize > 0 and shape[0] % dsize == 0:
            parts[0] = data[0] if len(data) == 1 else data
        names = _path_names(path)
        hdim = _CACHE_HEAD_DIM.get(names[-1]) if names else None
        if (
            hdim is not None
            and hdim < len(shape)
            and msize > 1
            and shape[hdim] % msize == 0
        ):
            parts[hdim] = plan.model_axis
        while parts and parts[-1] is None:
            parts.pop()
        return NamedSharding(mesh, P(*parts))

    return jax.tree_util.tree_map_with_path(one, cache_shape)
