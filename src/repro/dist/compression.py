"""int8 gradient wire compression + a compressed psum collective.

Per-tensor symmetric int8 quantization (scale = max|x| / 127).  With a PRNG
key, rounding is stochastic — floor(x/s + u), u ~ U[0,1) — which makes the
dequantized value an unbiased estimator of x (E[dq(q(x))] = x), the
property SGD-family optimizers need for compressed gradients to converge.
Without a key, round-to-nearest halves the worst-case error.

``compressed_psum`` is the wire story: inside shard_map, each shard
quantizes its local partial, all-gathers the int8 payload + f32 scales
(4.06 bytes/elem/shard on the wire vs 4 bytes for f32 ring all-reduce —
but the payload term is 4x smaller), then dequantizes and reduces locally.
"""
from __future__ import annotations

from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp


def _scale_of(x: jnp.ndarray) -> jnp.ndarray:
    s = jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0
    return jnp.where(s > 0.0, s, 1.0)


def quantize_int8(
    x: jnp.ndarray, key: Optional[jax.Array] = None
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x -> (int8 codes, f32 scalar scale). Stochastic rounding iff ``key``."""
    x32 = x.astype(jnp.float32)
    s = _scale_of(x32)
    y = x32 / s
    if key is not None:
        y = jnp.floor(y + jax.random.uniform(key, x32.shape))
    else:
        y = jnp.round(y)
    return jnp.clip(y, -127, 127).astype(jnp.int8), s


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compression_error_bound(x: jnp.ndarray) -> float:
    """Worst-case |dq(q(x)) - x| (covers stochastic rounding; deterministic
    rounding achieves half of this)."""
    return float(jnp.max(jnp.abs(x.astype(jnp.float32))) / 127.0)


def quantize_tree(
    tree: Any, key: Optional[jax.Array] = None
) -> Tuple[Any, Any]:
    """Quantize every leaf; returns (codes tree, scales tree)."""
    leaves, treedef = jax.tree.flatten(tree)
    keys = (
        list(jax.random.split(key, len(leaves)))
        if key is not None
        else [None] * len(leaves)
    )
    pairs = [quantize_int8(x, k) for x, k in zip(leaves, keys)]
    return (
        treedef.unflatten([p[0] for p in pairs]),
        treedef.unflatten([p[1] for p in pairs]),
    )


def dequantize_tree(qtree: Any, stree: Any) -> Any:
    return jax.tree.map(dequantize_int8, qtree, stree)


def compressed_psum(x: jnp.ndarray, axis_name: str) -> jnp.ndarray:
    """Sum ``x`` over a shard_map mesh axis with int8 wire compression.

    all_gather(int8 codes + scalar scales) then dequantize-and-reduce
    locally; every shard returns the identical (replicated) sum.
    """
    q, s = quantize_int8(x)
    gq = jax.lax.all_gather(q, axis_name)  # (n, *x.shape) int8
    gs = jax.lax.all_gather(s, axis_name)  # (n,) f32
    scales = gs.reshape((-1,) + (1,) * x.ndim)
    return jnp.sum(gq.astype(jnp.float32) * scales, axis=0)
