"""repro.dist — the model-sharding layer.

The paper's disaggregation argument only matters relative to a real
consumer: a sharded model on a mesh whose input pipeline must keep up.
This package owns everything about HOW that model is laid out:

  * ``context``        — ShardingPlan (logical axis assignment), the active
                         plan context (``use_plan``), and the
                         ``shard_activations`` constraint hook the model
                         layers call.
  * ``sharding_rules`` — parameter / optimizer-state / batch / KV-cache
                         PartitionSpec derivation (Megatron-style tensor
                         parallel + FSDP over the data axis).
  * ``compression``    — int8 gradient wire compression (stochastic
                         rounding) and a compressed psum collective.
"""
from .context import ShardingPlan, shard_activations, use_plan

__all__ = ["ShardingPlan", "shard_activations", "use_plan"]
