"""Sharding plan + activation-constraint context.

A ``ShardingPlan`` maps LOGICAL tensor roles onto PHYSICAL mesh axes.  The
model code never names mesh axes directly — layers call
``shard_activations(x, "bsd")`` with a role string (one character per dim)
and the active plan decides which mesh axis, if any, each role pins to:

  role  meaning                      default axis
  ----  ---------------------------  -------------------------------
  b     global batch                 plan.data_axes
  s     sequence                     plan.seq_axis (None unless
                                     sequence parallelism is on)
  d     d_model / hidden             None (replicated)
  g     MoE dispatch group           plan.data_axes (groups align
                                     with dp shards by construction)
  t     tokens within a group        None
  e     expert                       plan.moe_expert_axis (subject to
                                     plan.moe_pin)
  c     expert capacity slot         None
  h     heads                        plan.model_axis

Outside an active plan (unit tests, single-host runs) the hook is an exact
no-op, so model code is runnable with zero mesh setup.  Constraints are
also dropped per-dim when the dim size does not divide the axis size —
sharding falls back to replication rather than crashing (see
tests/test_dist.py::test_indivisible_dims_fall_back_to_replication for the
parameter-side contract).
"""
from __future__ import annotations

import contextlib
import threading
from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

Axis = Union[str, Tuple[str, ...]]


@dataclass(frozen=True)
class ShardingPlan:
    """Logical-axis → mesh-axis assignment for one launch.

    ``data_axes`` may span multiple mesh axes (("pod", "data") on the
    multi-pod mesh).  ``fsdp_axis`` is the axis parameters are
    fully-sharded over (ZeRO-3 style); it may equal the data axis or
    extend over ("pod", "data") for the 1T-param configs.
    """

    data_axes: Tuple[str, ...] = ("data",)
    model_axis: str = "model"
    fsdp_axis: Optional[Axis] = "data"
    seq_axis: Optional[str] = None
    # MoE dispatch-buffer pinning: "auto"/"group_ep" pins (G→data, E→expert
    # axis); "group" pins only G and lets SPMD place E.
    moe_pin: str = "auto"
    moe_expert_axis: str = "model"

    @property
    def fsdp_axes(self) -> Tuple[str, ...]:
        if self.fsdp_axis is None:
            return ()
        if isinstance(self.fsdp_axis, str):
            return (self.fsdp_axis,)
        return tuple(self.fsdp_axis)


class _PlanState(threading.local):
    def __init__(self) -> None:
        self.plan: Optional[ShardingPlan] = None
        self.mesh: Optional[Mesh] = None


_STATE = _PlanState()


@contextlib.contextmanager
def use_plan(plan: ShardingPlan, mesh: Optional[Mesh] = None):
    """Activate ``plan`` for the dynamic extent (usually alongside a mesh
    context: ``with mesh, use_plan(plan): ...``)."""
    prev_plan, prev_mesh = _STATE.plan, _STATE.mesh
    _STATE.plan, _STATE.mesh = plan, mesh
    try:
        yield plan
    finally:
        _STATE.plan, _STATE.mesh = prev_plan, prev_mesh


def current_plan() -> Optional[ShardingPlan]:
    return _STATE.plan


def _ambient_mesh() -> Optional[Mesh]:
    if _STATE.mesh is not None:
        return _STATE.mesh
    try:  # the `with mesh:` context manager
        from jax.interpreters import pxla

        mesh = pxla.thread_resources.env.physical_mesh
        if mesh is not None and not mesh.empty:
            return mesh
    except Exception:
        pass
    return None


def _role_axes(role: str, plan: ShardingPlan) -> Optional[Tuple[str, ...]]:
    if role == "b" or role == "g":
        return tuple(plan.data_axes)
    if role == "s":
        return (plan.seq_axis,) if plan.seq_axis else None
    if role == "h":
        return (plan.model_axis,)
    if role == "e":
        if plan.moe_pin in ("auto", "group_ep"):
            return (plan.moe_expert_axis,)
        return None
    return None  # d, t, c, and anything unrecognized: replicate


def plan_spec(roles: str, plan: ShardingPlan,
              shape: Optional[Sequence[int]] = None,
              mesh: Optional[Mesh] = None) -> P:
    """PartitionSpec for a role string, dropping axes that don't divide."""
    parts = []
    used: set = set()
    for i, role in enumerate(roles):
        axes = _role_axes(role, plan)
        if axes and not (set(axes) & used):
            if mesh is not None and shape is not None:
                size = 1
                for a in axes:
                    size *= mesh.shape.get(a, 0) or 0
                if size == 0 or shape[i] % size:
                    parts.append(None)
                    continue
            used.update(axes)
            parts.append(axes[0] if len(axes) == 1 else tuple(axes))
        else:
            parts.append(None)
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def shard_activations(x: jax.Array, roles: str) -> jax.Array:
    """Constrain an activation's sharding per the active plan (no-op when
    no plan is active — model code stays mesh-free in unit tests)."""
    plan = _STATE.plan
    if plan is None:
        return x
    mesh = _ambient_mesh()
    if mesh is None or mesh.size == 1:
        return x
    assert len(roles) == x.ndim, (roles, x.shape)
    spec = plan_spec(roles, plan, shape=x.shape, mesh=mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))
