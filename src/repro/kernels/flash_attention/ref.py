"""Pure-jnp oracle for blocked GQA flash attention (materializes scores)."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Sq, Hkv, G, D) * scale
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qf, k.astype(jnp.float32))
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = q_offset + jnp.arange(Sq)
    kv_pos = jnp.arange(Sk)
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= kv_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= kv_pos[None, :] > q_pos[:, None] - window
    s = jnp.where(mask[None, :, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask[None, :, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, Hq, D).astype(q.dtype)
