"""Public jit'd wrapper for the flash attention Pallas kernel.

``interpret=True`` executes the kernel body in Python on CPU (validation);
on TPU the default lowers through Mosaic.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_fwd


@partial(
    jax.jit,
    static_argnames=(
        "causal", "window", "softcap", "q_offset", "block_q", "block_k",
        "interpret",
    ),
)
def flash_attention(
    q: jnp.ndarray,
    k: jnp.ndarray,
    v: jnp.ndarray,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    return flash_attention_fwd(
        q, k, v,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        block_q=block_q,
        block_k=block_k,
        interpret=interpret,
    )
