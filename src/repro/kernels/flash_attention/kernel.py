"""Pallas TPU flash attention (blocked GQA, online softmax).

Grid: (B, Hq, num_q_blocks, num_kv_blocks) — the last dimension is
"arbitrary" (sequential), so the online-softmax running state (m, l, acc)
lives in VMEM scratch and is carried across KV blocks; the output block is
emitted on the final KV iteration.

BlockSpec tiling (per grid step, all VMEM):
  q    (1, block_q, 1, D)     — one q-head tile
  k/v  (1, block_k, 1, D)     — the GQA kv head is q_head // group_size
  out  (1, block_q, 1, D)
  scratch: acc (block_q, D) f32, m/l (block_q, MINOR) f32

block_q/block_k default to 128/256: q·kᵀ tiles are (128, 256) f32 = 128 KiB,
acc is (128, 128) f32 = 64 KiB — comfortably VMEM-resident, and both matmul
dims are multiples of the 128-wide MXU.

Causal masking is block-aware: KV blocks strictly above the diagonal are
skipped (no MXU work), diagonal blocks apply the triangular mask inline.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

MINOR = 128  # TPU vector lane width; scratch minor dim
NEG_INF = -1e30  # avoids -inf NaN propagation inside masked blocks


def _fa_kernel(
    q_ref, k_ref, v_ref,  # VMEM block refs
    o_ref,
    acc_ref, m_ref, l_ref,  # scratch
    *,
    block_q: int,
    block_k: int,
    sq: int,
    sk: int,
    causal: bool,
    window: int,
    softcap: float,
    q_offset: int,
    scale: float,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # absolute positions of this tile
    q_lo = q_offset + qi * block_q
    k_lo = ki * block_k

    # block-level skip: strictly-above-diagonal (causal) or out-of-window
    run = jnp.asarray(True)
    if causal:
        run &= k_lo <= q_lo + block_q - 1
    if window > 0:
        run &= k_lo + block_k - 1 > q_lo - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, :, 0, :]  # (block_q, D)
        k = k_ref[0, :, 0, :]  # (block_k, D)
        v = v_ref[0, :, 0, :]
        s = jax.lax.dot_general(
            q, k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        ) * scale  # (block_q, block_k)
        if softcap > 0.0:
            s = softcap * jnp.tanh(s / softcap)

        q_pos = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kv_pos = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kv_pos < sk  # tail padding of the last KV block
        if causal:
            mask &= kv_pos <= q_pos
        if window > 0:
            mask &= kv_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]  # (block_q,)
        m_cur = s.max(axis=-1)
        m_new = jnp.maximum(m_prev, m_cur)
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_ref[:, 0] = l_ref[:, 0] * alpha + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == nk - 1)
    def _emit():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, :, 0, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,  # (B, Sq, Hq, D)
    k: jnp.ndarray,  # (B, Sk, Hkv, D)
    v: jnp.ndarray,  # (B, Sk, Hkv, D)
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Sq, Hq, D = q.shape
    _, Sk, Hkv, _ = k.shape
    assert Hq % Hkv == 0, "GQA requires Hq % Hkv == 0"
    G = Hq // Hkv
    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    nq = pl.cdiv(Sq, block_q)
    nk = pl.cdiv(Sk, block_k)
    if Sq % block_q:
        q = jnp.pad(q, ((0, 0), (0, nq * block_q - Sq), (0, 0), (0, 0)))
    if Sk % block_k:
        pad = nk * block_k - Sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    grid = (B, Hq, nq, nk)
    kern = functools.partial(
        _fa_kernel,
        block_q=block_q,
        block_k=block_k,
        sq=Sq,
        sk=Sk,
        causal=causal,
        window=window,
        softcap=softcap,
        q_offset=q_offset,
        scale=1.0 / math.sqrt(D),
    )
    out = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
            pl.BlockSpec((1, block_k, 1, D), lambda b, h, i, j: (b, j, h // G, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, 1, D), lambda b, h, i, j: (b, i, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, nq * block_q, Hq, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, D), jnp.float32),
            pltpu.VMEM((block_q, MINOR), jnp.float32),
            pltpu.VMEM((block_q, MINOR), jnp.float32),
        ],
        compiler_params=pltpu.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(q, k, v)
    return out[:, :Sq]
