from .ops import moe_router

__all__ = ["moe_router"]
