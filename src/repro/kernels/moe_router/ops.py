"""Public jit'd wrapper for the fused MoE router Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import moe_router_fwd


@partial(jax.jit, static_argnames=("k", "capacity", "block_t", "interpret"))
def moe_router(
    logits: jnp.ndarray,  # (T, E)
    k: int,
    capacity: int,
    block_t: int = 256,
    interpret: bool = False,
):
    """Returns (expert_ids (T,k) i32, gates (T,k) f32, slots (T,k) i32).

    A (token, choice) is dropped iff ``slots >= capacity``.
    """
    return moe_router_fwd(
        logits, k, capacity, block_t=block_t, interpret=interpret
    )
