"""Pallas TPU fused MoE router: softmax + top-k + capacity slot assignment.

One kernel replaces four XLA ops (softmax, top_k, one_hot+cumsum dispatch
bookkeeping) and keeps the (T, E) probability tile VMEM-resident throughout.

Grid: (num_token_blocks,) — sequential ("arbitrary"), because slot
assignment is a running per-expert counter carried in VMEM scratch across
blocks.  Block tiling:
  logits (block_t, E) in VMEM;  outputs ids/gates/slots (block_t, k)
  scratch counts (1, E) int32 — the per-expert fill level

Top-k is k rounds of (max, argmax, mask) over the VMEM tile — k ≤ 8 for
every assigned MoE config, so the loop is fully unrolled vector work.
Slot assignment is token-major over the flattened (T·k) choice list —
bit-identical to the gshard exclusive cumsum in ``models.layers.moe_ffn``:
slot(t, j) = counts_before[e] + #{(t', j'): t' < t, id = e}
                              + #{(t, j'): j' < j, id = e}.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _router_kernel(
    logits_ref,
    ids_ref, gates_ref, slots_ref,
    counts_ref,  # scratch (1, E) int32
    *,
    k: int,
    block_t: int,
    total_t: int,
):
    bi = pl.program_id(0)

    @pl.when(bi == 0)
    def _init():
        counts_ref[...] = jnp.zeros_like(counts_ref)

    logits = logits_ref[...].astype(jnp.float32)  # (Tb, E)
    Tb, E = logits.shape
    # mask padded tail tokens so they never win capacity slots
    tok = bi * block_t + jax.lax.broadcasted_iota(jnp.int32, (Tb, 1), 0)
    valid = tok < total_t  # (Tb, 1)

    m = logits.max(axis=-1, keepdims=True)
    ex = jnp.exp(logits - m)
    probs = ex / ex.sum(axis=-1, keepdims=True)

    eids = jax.lax.broadcasted_iota(jnp.int32, (Tb, E), 1)
    counts = counts_ref[0, :]  # (E,)

    # phase 1: top-k winners (unrolled: k ≤ 8)
    gate_cols = []
    onehots = []
    for j in range(k):
        g = probs.max(axis=-1)  # (Tb,)
        win = probs == g[:, None]  # ties -> lowest expert id wins
        idx = jnp.where(win, eids, E).min(axis=-1)  # (Tb,)
        onehots.append(((eids == idx[:, None]) & valid).astype(jnp.int32))
        ids_ref[:, j] = idx
        gate_cols.append(g)
        probs = jnp.where(eids == idx[:, None], -1.0, probs)

    # phase 2: token-major slot assignment.  For (t, j):
    #   counts[e] + Σ_{t'<t} any-choice[t', e] + Σ_{j'<j} onehot_j'[t, e]
    all_choices = onehots[0]
    for j in range(1, k):
        all_choices = all_choices + onehots[j]  # (Tb, E) ∈ {0,1}
    before_tok = jnp.cumsum(all_choices, axis=0) - all_choices
    prior_round = jnp.zeros_like(all_choices)
    for j in range(k):
        pos = counts[None, :] + before_tok + prior_round
        slots_ref[:, j] = (pos * onehots[j]).sum(axis=-1)
        prior_round = prior_round + onehots[j]
    counts_ref[0, :] = counts + all_choices.sum(axis=0)

    gates = jnp.stack(gate_cols, axis=1)  # (Tb, k)
    gates_ref[...] = gates / jnp.maximum(gates.sum(axis=1, keepdims=True), 1e-9)


def moe_router_fwd(
    logits: jnp.ndarray,  # (T, E)
    k: int,
    capacity: int,  # kept in the signature for parity with ref; dropping
    *,                # is `slots >= capacity` downstream
    block_t: int = 256,
    interpret: bool = False,
):
    T, E = logits.shape
    block_t = min(block_t, T)
    nb = pl.cdiv(T, block_t)
    Tp = nb * block_t
    if Tp != T:
        logits = jnp.pad(logits, ((0, Tp - T), (0, 0)))

    kern = functools.partial(
        _router_kernel, k=k, block_t=block_t, total_t=T
    )
    ids, gates, slots = pl.pallas_call(
        kern,
        grid=(nb,),
        in_specs=[pl.BlockSpec((block_t, E), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
            pl.BlockSpec((block_t, k), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
            jax.ShapeDtypeStruct((Tp, k), jnp.float32),
            jax.ShapeDtypeStruct((Tp, k), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((1, E), jnp.int32)],
        compiler_params=pltpu.compiler_params(
            dimension_semantics=("arbitrary",),
        ),
        interpret=interpret,
    )(logits)
    return ids[:T], gates[:T], slots[:T]
