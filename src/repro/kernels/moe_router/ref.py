"""Pure-jnp oracle for the fused MoE router.

Semantics: softmax over experts, top-k by iterated argmax (ties broken
toward the lower expert id), gates renormalized over the k winners.
Capacity slots are assigned token-major over the flattened (T·k) choice
list — identical to the gshard exclusive-cumsum in ``models.layers.moe_ffn``
— so ``slot >= capacity`` means the (token, choice) is dropped.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def moe_router_ref(
    logits: jnp.ndarray,  # (T, E) f32
    k: int,
    capacity: int,
):
    T, E = logits.shape
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    ids = []
    gates = []
    p = probs
    for _ in range(k):
        idx = jnp.argmax(p, axis=-1)
        ids.append(idx)
        gates.append(jnp.take_along_axis(p, idx[:, None], axis=-1)[:, 0])
        p = p.at[jnp.arange(T), idx].set(-1.0)
    ids = jnp.stack(ids, axis=1)  # (T, k)
    gates = jnp.stack(gates, axis=1)
    gates = gates / jnp.maximum(gates.sum(axis=1, keepdims=True), 1e-9)

    # token-major slot assignment (gshard exclusive cumsum over (T·k, E))
    onehot = jax.nn.one_hot(ids, E, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(T * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat
    slots = (pos * flat).sum(-1).reshape(T, k)
    return ids.astype(jnp.int32), gates, slots.astype(jnp.int32)
