"""Pallas TPU kernel for the mamba2 SSD scan (chunked matmul formulation).

The SSD duality turns the token recurrence into per-chunk matmuls (MXU
work) plus a tiny cross-chunk state recurrence:

  intra-chunk:  Y_d = (C Bᵀ ∘ L ∘ dt) X            (Q×Q)·(Q×P) dots
  state in:     Y_o = (C ∘ exp(cum)) H_prev         (Q×N)·(N×P) dot
  state update: H   = exp(cum_Q) H_prev + (B ∘ w)ᵀ X  (N×Q)·(Q×P) dot

Grid: (B, H, num_chunks); the chunk dimension is sequential ("arbitrary")
and the running state H (N, P) f32 lives in VMEM scratch — the cross-chunk
recurrence never leaves the core.  Block tiling (VMEM):

  x  (1, Q, 1, P)   dt (1, Q, 1)   B/C (1, Q, 1, N)
  y  (1, Q, 1, P)   final state (1, 1, N, P) emitted on the last chunk

Q=chunk (default 128), N=state, P=head dim — all matmul dims are 128-ish,
MXU-aligned for the assigned mamba2 config (N=128, P=64).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

NEG_INF = -1e30


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref,
    y_ref, h_out_ref,
    h_ref,  # scratch: running state (N, P) f32
    *,
    chunk: int,
    length: int,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    Q = chunk
    x = x_ref[0, :, 0, :].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, :, 0].astype(jnp.float32)  # (Q,)
    Bm = b_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0, :, 0, :].astype(jnp.float32)  # (Q, N)
    a = a_ref[0]  # scalar decay rate for this head
    D = d_ref[0]

    # zero padded tail tokens (last chunk when L % Q != 0)
    tok = ci * Q + jax.lax.broadcasted_iota(jnp.int32, (Q, 1), 0)
    valid = (tok < length)[:, 0]  # (Q,)
    dt = jnp.where(valid, dt, 0.0)

    da = dt * a  # (Q,) log-decay per token
    cum = jnp.cumsum(da)  # inclusive
    # L[i, j] = exp(cum_i - cum_j) for j <= i else 0  (decay from j+1..i)
    seg = cum[:, None] - cum[None, :]
    tri = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    Lmat = jnp.where(tri, jnp.exp(seg), 0.0)

    CB = jax.lax.dot_general(
        Cm, Bm, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, Q) = C_i . B_j
    W = CB * Lmat * dt[None, :]  # weight token j's input into token i's output
    y_diag = jax.lax.dot_general(
        W, x, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (Q, P)

    h_prev = h_ref[...]  # (N, P)
    state_in = Cm * jnp.exp(cum)[:, None]  # (Q, N)
    y_off = jax.lax.dot_general(
        state_in, h_prev, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    y = y_diag + y_off + x * D
    y_ref[0, :, 0, :] = jnp.where(valid[:, None], y, 0.0).astype(y_ref.dtype)

    # state update: H = exp(cum_Q) H_prev + Σ_j exp(cum_Q - cum_j) dt_j B_j x_jᵀ
    cq = cum[Q - 1]
    w = jnp.exp(cq - cum) * dt  # (Q,)
    bw = Bm * w[:, None]  # (Q, N)
    h_new = jnp.exp(cq) * h_prev + jax.lax.dot_general(
        bw, x, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )  # (N, P)
    h_ref[...] = h_new

    @pl.when(ci == nc - 1)
    def _emit_state():
        h_out_ref[0, 0, :, :] = h_new


def ssd_scan_fwd(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H) — post-softplus step sizes
    a: jnp.ndarray,  # (H,) negative decay rates
    Bm: jnp.ndarray,  # (B, L, H, N)
    Cm: jnp.ndarray,  # (B, L, H, N)
    D: jnp.ndarray,  # (H,) skip gain
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, L)
    nc = pl.cdiv(L, chunk)
    Lp = nc * chunk
    if Lp != L:
        pad = Lp - L
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))

    grid = (Bsz, H, nc)
    kern = functools.partial(_ssd_kernel, chunk=chunk, length=L)
    y, h_final = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, h, c: (b, c, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, chunk, 1, N), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, 1, P), lambda b, h, c: (b, c, h, 0)),
            pl.BlockSpec((1, 1, N, P), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((Bsz, Lp, H, P), x.dtype),
            jax.ShapeDtypeStruct((Bsz, H, N, P), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((N, P), jnp.float32)],
        compiler_params=pltpu.compiler_params(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(
        x,
        dt.astype(jnp.float32),
        a.astype(jnp.float32),
        Bm,
        Cm,
        D.astype(jnp.float32),
    )
    return y[:, :L], h_final
