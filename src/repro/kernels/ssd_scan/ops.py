"""Public jit'd wrapper for the SSD scan Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import ssd_scan_fwd


@partial(jax.jit, static_argnames=("chunk", "interpret", "return_state"))
def ssd_scan(
    x: jnp.ndarray,  # (B, L, H, P)
    dt: jnp.ndarray,  # (B, L, H)
    a: jnp.ndarray,  # (H,)
    Bm: jnp.ndarray,  # (B, L, H, N)
    Cm: jnp.ndarray,  # (B, L, H, N)
    D: jnp.ndarray,  # (H,)
    chunk: int = 128,
    interpret: bool = False,
    return_state: bool = False,
):
    y, h = ssd_scan_fwd(x, dt, a, Bm, Cm, D, chunk=chunk, interpret=interpret)
    return (y, h) if return_state else y
