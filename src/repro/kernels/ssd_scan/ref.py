"""Pure-jnp oracle for the mamba2 SSD scan: sequential token recurrence.

    h_t = exp(dt_t * a) * h_{t-1} + dt_t * B_t x_t^T
    y_t = C_t . h_t + D * x_t

Shapes follow the SSD paper (heads already expanded — no GQA-style groups):
  x  (B, L, H, P)  dt (B, L, H)  a (H,)  Bm/Cm (B, L, H, N)  D (H,)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jnp.ndarray,
    dt: jnp.ndarray,
    a: jnp.ndarray,
    Bm: jnp.ndarray,
    Cm: jnp.ndarray,
    D: jnp.ndarray,
) -> jnp.ndarray:
    Bsz, L, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        x_t, dt_t, B_t, C_t = inp  # (B,H,P) (B,H) (B,H,N) (B,H,N)
        decay = jnp.exp(dt_t * af[None, :])  # (B,H)
        h = h * decay[..., None, None] + jnp.einsum(
            "bhn,bh,bhp->bhnp", B_t, dt_t, x_t
        )
        y = jnp.einsum("bhn,bhnp->bhp", C_t, h)
        return h, y

    h0 = jnp.zeros((Bsz, H, N, P), jnp.float32)
    _, ys = jax.lax.scan(
        step,
        h0,
        (
            xf.transpose(1, 0, 2, 3),
            dtf.transpose(1, 0, 2),
            Bf.transpose(1, 0, 2, 3),
            Cf.transpose(1, 0, 2, 3),
        ),
    )
    y = ys.transpose(1, 0, 2, 3)  # (B, L, H, P)
    y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    return y.astype(x.dtype)
