"""Pallas TPU flash-decoding: split-K attention over a deep KV cache.

Decode attention is memory-bound — the whole KV cache streams through once
per token.  The kernel splits the cache length S into ``num_splits``
independent segments (grid dim, parallel) so HBM reads of different
segments overlap; each segment computes a partial online-softmax
(m_i, l_i, acc_i).  A cheap jnp combine (O(num_splits) per head) merges
partials into the final output — the classic flash-decoding two-phase plan,
adapted so phase 1 is one Pallas kernel and phase 2 is fused XLA.

Grid: (B, Hkv, num_splits); block tiling (VMEM):
  q     (1, 1, G, D)      — all G grouped q-heads of this kv head
  k/v   (1, block_s, 1, D)
  out   acc (1, 1, num_splits, G, D) f32; m/l (1, 1, num_splits, G)

The segment loop over block_s-sized tiles runs INSIDE the kernel
(fori_loop over VMEM loads) so each grid step reads its whole segment while
the MXU works on (G × block_s) tiles.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu

NEG_INF = -1e30


def _decode_kernel(
    len_ref,  # SMEM (B,) — valid cache lengths
    q_ref, k_ref, v_ref,
    acc_ref, m_ref, l_ref,
    *,
    block_s: int,
    seg: int,
    window: int,
    scale: float,
):
    b = pl.program_id(0)
    si = pl.program_id(2)
    G, D = q_ref.shape[2], q_ref.shape[3]
    length = len_ref[b]
    seg_lo = si * seg

    q = q_ref[0, 0, :, :].astype(jnp.float32) * scale  # (G, D)

    nblocks = seg // block_s

    def body(i, carry):
        m, l, acc = carry  # (G,), (G,), (G, D)
        lo = i * block_s  # offset within this segment
        k = k_ref[0, pl.dslice(lo, block_s), 0, :]  # (block_s, D)
        v = v_ref[0, pl.dslice(lo, block_s), 0, :]
        s = jax.lax.dot_general(
            q.astype(k.dtype), k,
            (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32,
        )  # (G, block_s)
        kv_pos = seg_lo + lo + jax.lax.broadcasted_iota(
            jnp.int32, (1, block_s), 1
        )
        mask = kv_pos < length
        if window > 0:
            mask &= kv_pos > length - 1 - window
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(mask, p, 0.0)
        l_new = l * alpha + p.sum(axis=-1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v,
            (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32,
        )
        return m_new, l_new, acc * alpha[:, None] + pv

    m0 = jnp.full((G,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((G,), jnp.float32)
    a0 = jnp.zeros((G, D), jnp.float32)
    m, l, acc = jax.lax.fori_loop(0, nblocks, body, (m0, l0, a0))
    acc_ref[0, 0, 0, :, :] = acc
    m_ref[0, 0, 0, :] = m
    l_ref[0, 0, 0, :] = l


def decode_attention_fwd(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    lengths: jnp.ndarray,  # (B,) int32
    *,
    window: int = 0,
    num_splits: int = 8,
    block_s: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    assert Hq % Hkv == 0
    G = Hq // Hkv

    # segment size: multiple of block_s covering S
    num_splits = max(1, min(num_splits, pl.cdiv(S, block_s)))
    seg = pl.cdiv(S, num_splits)
    block_s = min(block_s, seg)
    seg = pl.cdiv(seg, block_s) * block_s  # round seg to block multiple
    S_pad = seg * num_splits
    if S_pad != S:
        pad = S_pad - S
        k_cache = jnp.pad(k_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, pad), (0, 0), (0, 0)))

    qg = q.reshape(B, Hkv, G, D)
    grid = (B, Hkv, num_splits)
    kern = functools.partial(
        _decode_kernel,
        block_s=block_s,
        seg=seg,
        window=window,
        scale=1.0 / math.sqrt(D),
    )
    acc, m, l = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, D), lambda b, h, s, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, seg, 1, D), lambda b, h, s, *_: (b, s, h, 0)),
                pl.BlockSpec((1, seg, 1, D), lambda b, h, s, *_: (b, s, h, 0)),
            ],
            out_specs=[
                pl.BlockSpec(
                    (1, 1, 1, G, D), lambda b, h, s, *_: (b, h, s, 0, 0)
                ),
                pl.BlockSpec((1, 1, 1, G), lambda b, h, s, *_: (b, h, s, 0)),
                pl.BlockSpec((1, 1, 1, G), lambda b, h, s, *_: (b, h, s, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, Hkv, num_splits, G, D), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, num_splits, G), jnp.float32),
            jax.ShapeDtypeStruct((B, Hkv, num_splits, G), jnp.float32),
        ],
        compiler_params=pltpu.compiler_params(
            dimension_semantics=("parallel", "parallel", "parallel"),
        ),
        interpret=interpret,
    )(lengths.astype(jnp.int32), qg, k_cache, v_cache)

    # phase 2: merge split partials (tiny, fused by XLA)
    m_g = m.max(axis=2, keepdims=True)  # (B, Hkv, 1, G)
    w = jnp.exp(m - m_g)  # (B, Hkv, ns, G)
    l_tot = (l * w).sum(axis=2)  # (B, Hkv, G)
    out = (acc * w[..., None]).sum(axis=2) / jnp.maximum(l_tot, 1e-30)[..., None]
    return out.reshape(B, Hq, D).astype(q.dtype)
