"""Public jit'd wrapper for the flash-decoding Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import decode_attention_fwd


@partial(
    jax.jit,
    static_argnames=("window", "num_splits", "block_s", "interpret"),
)
def decode_attention(
    q: jnp.ndarray,  # (B, Hq, D)
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    lengths: jnp.ndarray,  # (B,) valid lengths
    window: int = 0,
    num_splits: int = 8,
    block_s: int = 256,
    interpret: bool = False,
) -> jnp.ndarray:
    return decode_attention_fwd(
        q, k_cache, v_cache, lengths,
        window=window,
        num_splits=num_splits,
        block_s=block_s,
        interpret=interpret,
    )
