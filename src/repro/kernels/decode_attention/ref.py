"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import math

import jax.numpy as jnp


def decode_attention_ref(
    q: jnp.ndarray,  # (B, Hq, D) — one new token per sequence
    k_cache: jnp.ndarray,  # (B, S, Hkv, D)
    v_cache: jnp.ndarray,  # (B, S, Hkv, D)
    lengths: jnp.ndarray,  # (B,) int32 — valid cache length per sequence
    window: int = 0,
) -> jnp.ndarray:
    B, Hq, D = q.shape
    _, S, Hkv, _ = k_cache.shape
    G = Hq // Hkv
    scale = 1.0 / math.sqrt(D)
    qf = q.astype(jnp.float32).reshape(B, Hkv, G, D) * scale
    s = jnp.einsum("bhgd,bkhd->bhgk", qf, k_cache.astype(jnp.float32))
    kv_pos = jnp.arange(S)
    mask = kv_pos[None, :] < lengths[:, None]  # (B, S)
    if window > 0:
        mask &= kv_pos[None, :] > lengths[:, None] - 1 - window
    s = jnp.where(mask[:, None, None, :], s, -jnp.inf)
    p = jnp.exp(s - s.max(axis=-1, keepdims=True))
    p = jnp.where(mask[:, None, None, :], p, 0.0)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, Hq, D).astype(q.dtype)
