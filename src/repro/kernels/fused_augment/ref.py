"""Pure-jnp oracle for fused crop + horizontal-flip + normalize."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def fused_augment_ref(
    images: jnp.ndarray,  # (B, H, W, C) uint8
    crops: jnp.ndarray,  # (B, 2) int32 — (y0, x0) top-left corners
    flips: jnp.ndarray,  # (B,) int32 ∈ {0, 1}
    mean: jnp.ndarray,  # (C,) f32
    std: jnp.ndarray,  # (C,) f32
    out_h: int,
    out_w: int,
) -> jnp.ndarray:
    def one(img, crop, flip):
        tile = jax.lax.dynamic_slice(
            img, (crop[0], crop[1], 0), (out_h, out_w, img.shape[-1])
        ).astype(jnp.float32)
        tile = jnp.where(flip > 0, tile[:, ::-1, :], tile)
        return (tile / 255.0 - mean[None, None, :]) / std[None, None, :]

    return jax.vmap(one)(images, crops, flips)
