"""Public jit'd wrapper for the fused augmentation Pallas kernel."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import fused_augment_fwd


@partial(jax.jit, static_argnames=("out_h", "out_w", "interpret"))
def fused_augment(
    images: jnp.ndarray,  # (B, H, W, C) uint8
    crops: jnp.ndarray,  # (B, 2) int32 top-left corners
    flips: jnp.ndarray,  # (B,) int32 flags
    mean: jnp.ndarray,  # (C,) f32
    std: jnp.ndarray,  # (C,) f32
    out_h: int = 224,
    out_w: int = 224,
    interpret: bool = False,
) -> jnp.ndarray:
    return fused_augment_fwd(
        images, crops, flips, mean, std,
        out_h=out_h, out_w=out_w, interpret=interpret,
    )
