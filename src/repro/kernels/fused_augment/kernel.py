"""Pallas TPU fused crop + horizontal-flip + normalize.

This is the DALI-style "offload preprocessing to the accelerator"
alternative the paper argues against (§2): one kernel fuses the three
per-image ops so the uint8 source is read from HBM exactly once and only
the f32 crop is written back — but it still burns VPU cycles the train
step wants (the roofline benchmark quantifies that trade).

Grid: (B,) — per-image programs, embarrassingly parallel.  The (y0, x0)
crop corner and flip flag ride in scalar prefetch (SMEM) because the
dynamic slice offsets must be known when the kernel indexes VMEM.  Block
tiling: the full (1, H, W, C) uint8 image in VMEM (a 224² RGB image is
~150 KiB — VMEM holds dozens), output (1, out_h, out_w, C) f32.

The horizontal flip is an in-VMEM reversed gather fused with the
normalize multiply-add; mean/std fold into a single FMA:
out = tile * (1/255/std) + (-mean/std).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from repro.kernels import pallas_compat as pltpu


def _augment_kernel(
    crops_ref, flips_ref,  # scalar prefetch (SMEM): (B, 2) i32, (B,) i32
    img_ref, scale_ref, bias_ref,
    out_ref,
    *,
    out_h: int,
    out_w: int,
):
    b = pl.program_id(0)
    y0 = crops_ref[b, 0]
    x0 = crops_ref[b, 1]
    flip = flips_ref[b]

    C = img_ref.shape[-1]
    tile = img_ref[0, pl.dslice(y0, out_h), pl.dslice(x0, out_w), :]
    tile = tile.astype(jnp.float32)  # (out_h, out_w, C)

    # horizontal flip: reversed gather along W, selected by the flag
    rev = jax.lax.rev(tile, (1,))
    tile = jnp.where(flip > 0, rev, tile)

    # normalize as one FMA: scale = 1/(255·std), bias = -mean/std
    out_ref[0, :, :, :] = tile * scale_ref[...] + bias_ref[...]


def fused_augment_fwd(
    images: jnp.ndarray,  # (B, H, W, C) uint8
    crops: jnp.ndarray,  # (B, 2) int32
    flips: jnp.ndarray,  # (B,) int32
    mean: jnp.ndarray,  # (C,) f32
    std: jnp.ndarray,  # (C,) f32
    *,
    out_h: int,
    out_w: int,
    interpret: bool = False,
) -> jnp.ndarray:
    B, H, W, C = images.shape
    scale = (1.0 / (255.0 * std)).astype(jnp.float32)[None, None, :]
    bias = (-mean / std).astype(jnp.float32)[None, None, :]

    kern = functools.partial(_augment_kernel, out_h=out_h, out_w=out_w)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=(B,),
            in_specs=[
                pl.BlockSpec((1, H, W, C), lambda b, *_: (b, 0, 0, 0)),
                pl.BlockSpec((1, 1, C), lambda b, *_: (0, 0, 0)),
                pl.BlockSpec((1, 1, C), lambda b, *_: (0, 0, 0)),
            ],
            out_specs=pl.BlockSpec(
                (1, out_h, out_w, C), lambda b, *_: (b, 0, 0, 0)
            ),
        ),
        out_shape=jax.ShapeDtypeStruct((B, out_h, out_w, C), jnp.float32),
        compiler_params=pltpu.compiler_params(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(crops.astype(jnp.int32), flips.astype(jnp.int32), images, scale, bias)
