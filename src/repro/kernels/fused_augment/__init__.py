from .ops import fused_augment

__all__ = ["fused_augment"]
