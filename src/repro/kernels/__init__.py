"""Pallas TPU kernels for compute hot-spots, each with a pure-jnp oracle.

Layout per kernel: ``<name>/kernel.py`` (pl.pallas_call + BlockSpec VMEM
tiling), ``<name>/ops.py`` (jit'd public wrapper with an ``interpret`` switch
for CPU validation), ``<name>/ref.py`` (pure-jnp oracle the tests sweep
against).

Kernels:
  flash_attention  — blocked causal/windowed GQA attention, online softmax
  decode_attention — flash-decoding split-K attention over a deep KV cache
  ssd_scan         — mamba2 SSD chunked scan (matmul formulation, MXU)
  moe_router       — fused softmax + top-k + capacity-slot assignment
  fused_augment    — crop+flip+normalize image augmentation (the DALI-style
                     "preprocess on the accelerator" alternative of paper §2)
"""
