"""Version-compat shim for the Pallas TPU API surface this repo uses.

jax has renamed pieces of the Pallas API across releases — most notably
``pltpu.TPUCompilerParams`` (jax <= 0.4.x / 0.5.x) vs
``pltpu.CompilerParams`` (newer) — and kernels that pin one spelling break
loudly 38 tests at a time when the toolchain moves.  Every kernel in
``repro.kernels`` imports the symbols it needs from here instead of from
``jax.experimental.pallas.tpu`` directly, so a jax bump is absorbed (or
rejected) in exactly one module.

``tests/test_pallas_compat.py`` is the drift canary: it asserts each of
these names resolves against the installed jax, so the next incompatible
bump fails at one readable assert instead of scattered tracebacks.
"""
from __future__ import annotations

import jax
from jax.experimental.pallas import tpu as pltpu

__all__ = [
    "JAX_VERSION",
    "VMEM",
    "SMEM",
    "ANY",
    "PrefetchScalarGridSpec",
    "compiler_params",
]

JAX_VERSION: str = jax.__version__

# --- compiler params -------------------------------------------------------
# jax <= 0.5: pltpu.TPUCompilerParams; newer jax renamed it CompilerParams.
_TPUCompilerParams = getattr(pltpu, "TPUCompilerParams", None) or getattr(
    pltpu, "CompilerParams", None
)
if _TPUCompilerParams is None:  # pragma: no cover - future drift canary
    raise ImportError(
        "jax.experimental.pallas.tpu exposes neither TPUCompilerParams nor "
        f"CompilerParams (jax {JAX_VERSION}); update repro.kernels.pallas_compat"
    )


def compiler_params(*, dimension_semantics: tuple[str, ...], **kw):
    """Build the TPU compiler-params object under either jax spelling."""
    return _TPUCompilerParams(dimension_semantics=dimension_semantics, **kw)


# --- memory spaces & scratch shapes ---------------------------------------
# pltpu.VMEM((shape), dtype) is the scratch-shape convention for every jax
# this repo supports; alias it here so kernels have a single import site.
VMEM = pltpu.VMEM
SMEM = pltpu.SMEM
ANY = pltpu.ANY

# --- grid specs ------------------------------------------------------------
# PrefetchScalarGridSpec exists in every jax this shim supports.  If a
# future jax drops it, fail at construction with a message naming the
# symbol (the shim's contract: one readable error, not a TypeError deep in
# pallas internals from an unverified substitute).
if hasattr(pltpu, "PrefetchScalarGridSpec"):
    PrefetchScalarGridSpec = pltpu.PrefetchScalarGridSpec
else:  # pragma: no cover - future drift canary

    def PrefetchScalarGridSpec(*args, **kw):
        raise ImportError(
            "jax.experimental.pallas.tpu no longer exposes "
            f"PrefetchScalarGridSpec (jax {JAX_VERSION}); port the scalar-"
            "prefetch kernels (decode_attention, fused_augment) to this "
            "jax's convention and update repro.kernels.pallas_compat"
        )
