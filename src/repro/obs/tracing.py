"""Cross-process tracing for the disaggregated data path.

A trace follows one job's data across the four processes the paper
disaggregates (client, dispatcher, worker, device feeder):

* the CLIENT mints the trace: one root context per iteration session
  (carried on ``get_or_create_job`` / ``client_heartbeat``), plus one
  child context per element-batch RPC (``get_elements``/``get_element``);
* contexts travel INSIDE the RPC payload dicts (see ``core/protocol.py``)
  — no side channel, so they survive every transport (inproc/tcp/grpc)
  and, because the job's root context is journaled with ``job_created``,
  dispatcher failover: a promoted standby keeps stamping spans with the
  same ``trace_id`` (asserted by the chaos suite);
* each process records its spans into its own :class:`Tracer` ring buffer;
  ``trace_dump`` drains them over RPC and ``repro.obs.export`` merges the
  buffers into one Chrome trace-event JSON viewable in Perfetto.

Sampling gates ALL of it: with ``sample_rate == 0`` (the default) the hot
path pays one attribute check per RPC; with ``0 < rate < 1`` each
element-batch is traced with that probability, bounding the data-plane
overhead (< 5% at the default rates, measured by ``benchmarks/obs.py``).

Span timestamps are wall-clock (``time.time``) ON PURPOSE: they must be
comparable across processes in one exported trace, which is exactly the
cross-process exception to this repo's perf_counter-for-intervals rule.
Durations are still measured with ``perf_counter`` by the callers.
"""
from __future__ import annotations

import random
import threading
import time
import uuid
from collections import deque
from contextlib import contextmanager
from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional

__all__ = ["TraceContext", "Span", "Tracer"]


def _new_id(nbytes: int = 8) -> str:
    return uuid.uuid4().hex[: nbytes * 2]


@dataclass(frozen=True)
class TraceContext:
    """What travels inside RPC payloads: ``{"trace_id", "span_id", "sample"}``.

    ``span_id`` identifies the SENDER's span; the receiver records its own
    spans with ``parent_id = span_id``.  ``sample`` carries the minting
    client's sample rate so downstream processes (worker pipeline spans)
    gate per-element instrumentation at the same rate.
    """

    trace_id: str
    span_id: str
    sample: float = 1.0

    def child(self) -> "TraceContext":
        return TraceContext(self.trace_id, _new_id(), self.sample)

    def to_wire(self) -> Dict[str, Any]:
        return {"trace_id": self.trace_id, "span_id": self.span_id, "sample": self.sample}

    @staticmethod
    def from_wire(d: Optional[Dict[str, Any]]) -> Optional["TraceContext"]:
        if not isinstance(d, dict) or "trace_id" not in d:
            return None
        return TraceContext(
            str(d["trace_id"]),
            str(d.get("span_id", "")),
            float(d.get("sample", 1.0)),
        )


@dataclass
class Span:
    """One finished span.  ``start_unix`` is wall-clock (cross-process
    alignment — see module docstring); ``duration_s`` is interval-measured
    by the caller with perf_counter."""

    name: str
    trace_id: str
    span_id: str
    parent_id: Optional[str]
    process: str
    start_unix: float
    duration_s: float
    attrs: Dict[str, Any]

    def to_dict(self) -> Dict[str, Any]:
        return {
            "name": self.name,
            "trace_id": self.trace_id,
            "span_id": self.span_id,
            "parent_id": self.parent_id,
            "process": self.process,
            "start_unix": self.start_unix,
            "duration_s": self.duration_s,
            "attrs": self.attrs,
        }


class Tracer:
    """Per-process span recorder with a bounded ring buffer.

    Recording is O(1) under a short lock; the buffer drops the OLDEST spans
    at capacity (a long-running traced job keeps its recent history, which
    is what a dashboard scrape wants).  All methods are thread-safe.
    """

    def __init__(self, process: str = "", sample_rate: float = 0.0, capacity: int = 8192):
        self.process = process or f"proc-{_new_id(3)}"
        self.sample_rate = max(0.0, min(1.0, float(sample_rate)))
        self._spans: deque = deque(maxlen=max(16, int(capacity)))
        self._lock = threading.Lock()
        self._rng = random.Random()
        self.dropped = 0

    # -- sampling ---------------------------------------------------------
    def should_sample(self, rate: Optional[float] = None) -> bool:
        r = self.sample_rate if rate is None else rate
        if r <= 0.0:
            return False
        if r >= 1.0:
            return True
        return self._rng.random() < r

    def start_trace(self, sample: Optional[float] = None) -> Optional[TraceContext]:
        """Mint a new root context, or None when tracing is off.  The root
        is minted whenever ``sample_rate > 0`` (session-level identity);
        per-batch spans are then gated at ``should_sample()`` rate."""
        rate = self.sample_rate if sample is None else sample
        if rate <= 0.0:
            return None
        return TraceContext(_new_id(), _new_id(), rate)

    # -- recording --------------------------------------------------------
    def record(
        self,
        name: str,
        ctx: TraceContext,
        start_unix: float,
        duration_s: float,
        parent_id: Optional[str] = None,
        span_id: Optional[str] = None,
        **attrs: Any,
    ) -> None:
        span = Span(
            name=name,
            trace_id=ctx.trace_id,
            span_id=span_id or ctx.span_id,
            parent_id=parent_id,
            process=self.process,
            start_unix=start_unix,
            duration_s=max(0.0, duration_s),
            attrs=attrs,
        )
        with self._lock:
            if len(self._spans) == self._spans.maxlen:
                self.dropped += 1
            self._spans.append(span)

    @contextmanager
    def span(
        self, name: str, ctx: Optional[TraceContext], **attrs: Any
    ) -> Iterator[Optional[TraceContext]]:
        """Record a child span of ``ctx`` around the with-block.  With
        ``ctx is None`` (tracing off / unsampled) the block runs untimed —
        the no-op arm costs one None check."""
        if ctx is None:
            yield None
            return
        child = ctx.child()
        wall = time.time()  # cross-process timestamp (see module docstring)
        t0 = time.perf_counter()
        try:
            yield child
        finally:
            self.record(
                name,
                child,
                wall,
                time.perf_counter() - t0,
                parent_id=ctx.span_id,
                **attrs,
            )

    # -- draining ---------------------------------------------------------
    def drain(self, max_spans: int = 0) -> List[Dict[str, Any]]:
        """Pop up to ``max_spans`` recorded spans (0 = all), oldest first."""
        out: List[Dict[str, Any]] = []
        with self._lock:
            n = len(self._spans) if max_spans <= 0 else min(max_spans, len(self._spans))
            for _ in range(n):
                out.append(self._spans.popleft().to_dict())
        return out

    def peek(self) -> List[Dict[str, Any]]:
        """Non-destructive copy of the buffer (tests, dashboards)."""
        with self._lock:
            return [s.to_dict() for s in self._spans]

    def __len__(self) -> int:
        return len(self._spans)
