"""Unified observability layer: metrics registry, cross-process tracing,
per-op pipeline profiling, Chrome-trace export, and the fleet dashboard.

* :mod:`repro.obs.registry` — typed Counter/Gauge/Histogram families with
  exact concurrent writes and lock-free-read snapshots; every metrics
  island in the service (worker, client, feeder, autoscaler, autotuner)
  sits on one of these.
* :mod:`repro.obs.tracing` — ``TraceContext`` propagation through RPC
  payloads plus per-process ``Tracer`` ring buffers.
* :mod:`repro.obs.profiling` — per-op wall/CPU rollups and the
  stall-attribution report naming the bottleneck op.
* :mod:`repro.obs.export` — ``trace_dump`` scraper + Perfetto-loadable
  Chrome trace-event JSON writer (``python -m repro.obs.export``).
* :mod:`repro.obs.top` — fleet dashboard over ``metrics_dump``
  (``python -m repro.obs.top``).
"""
from .registry import Counter, Gauge, Histogram, MetricsRegistry, get_registry
from .tracing import Span, TraceContext, Tracer
from .profiling import attribute_stalls, merge_profiles, profile_ops

# export imports repro.core.transport, which (via repro.core.__init__) pulls
# in modules that import repro.obs submodules — keep it LAST so the registry/
# tracing names above are already bound when that cycle re-enters this package.
from .export import collect, export_chrome_trace, to_chrome

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
    "Span",
    "TraceContext",
    "Tracer",
    "attribute_stalls",
    "merge_profiles",
    "profile_ops",
    "collect",
    "export_chrome_trace",
    "to_chrome",
]
