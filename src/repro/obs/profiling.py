"""Per-op pipeline profiling: rollup + stall attribution.

``data/iterators.py`` already times every op into ``OpStats`` (wall busy
time and, since this module landed, CPU thread time and element counts).
This module turns those raw counters into the two artifacts the rest of
the system consumes:

* :func:`profile_ops` — a JSON-able per-op table (wall/CPU seconds,
  elements, mean cost, parallelism, buffer occupancy) exposed through the
  worker's ``metrics_dump`` RPC per task;
* :func:`attribute_stalls` — the per-job "why is this slow" report.  The
  bottleneck is the op with the LOWEST steady-state capacity
  (``parallelism / mean_cost`` elements/s): in a linear pipeline the
  slowest stage bounds throughput regardless of how fast the others are,
  which is the same model tf.data's autotuner optimizes against.  The
  ``Autotuner`` consumes this directly (tune the bottleneck, not every
  knob), replacing its coarse whole-pipeline rate probe for op selection.

Sources (``range``/``files``/...) and zero-cost pass-through ops report no
busy time and are excluded from attribution rather than read as
infinitely fast bottlenecks.
"""
from __future__ import annotations

from typing import Any, Dict, Iterable, List, Mapping, Optional

__all__ = ["profile_ops", "attribute_stalls", "merge_profiles"]


def profile_ops(stats: Mapping[int, Any]) -> List[Dict[str, Any]]:
    """Flatten an ``ExecContext.stats`` mapping into a per-op table.

    Accepts any mapping of node index -> OpStats-shaped object (duck-typed
    so dispatcher-side aggregation can feed dicts back through).
    """
    out: List[Dict[str, Any]] = []
    for idx in sorted(stats):
        s = stats[idx]
        elements = int(getattr(s, "elements", 0))
        wall = float(getattr(s, "busy_time", 0.0))
        cpu = float(getattr(s, "cpu_time", 0.0))
        par = getattr(s, "parallelism", None)
        out.append(
            {
                "index": idx,
                "name": str(getattr(s, "name", f"op{idx}")),
                "elements": elements,
                "wall_s": wall,
                "cpu_s": cpu,
                "mean_cost_s": wall / elements if elements else 0.0,
                "parallelism": int(par.get()) if par is not None else 1,
                "buffer_occupancy": float(getattr(s, "buffer_occupancy", 0.0)),
            }
        )
    return out


def merge_profiles(profiles: Iterable[List[Dict[str, Any]]]) -> List[Dict[str, Any]]:
    """Sum per-op rows across contexts/tasks/workers, keyed by (index, name).

    A runner that restarts its pipeline per shard owns several contexts
    with identical node indices; a job owns one runner per worker — either
    way the per-op totals add.
    """
    acc: Dict[Any, Dict[str, Any]] = {}
    for rows in profiles:
        for row in rows:
            key = (row.get("index", -1), row.get("name", ""))
            cur = acc.get(key)
            if cur is None:
                acc[key] = dict(row)
                continue
            cur["elements"] += row.get("elements", 0)
            cur["wall_s"] += row.get("wall_s", 0.0)
            cur["cpu_s"] += row.get("cpu_s", 0.0)
            # widest observed width / fullest buffer win (capacity model)
            cur["parallelism"] = max(cur["parallelism"], row.get("parallelism", 1))
            cur["buffer_occupancy"] = max(
                cur["buffer_occupancy"], row.get("buffer_occupancy", 0.0)
            )
    for row in acc.values():
        row["mean_cost_s"] = (
            row["wall_s"] / row["elements"] if row["elements"] else 0.0
        )
    return sorted(acc.values(), key=lambda r: r.get("index", -1))


def attribute_stalls(
    stats_or_profile: Any, min_elements: int = 1
) -> Dict[str, Any]:
    """Name the pipeline's bottleneck op and each op's share of busy time.

    Returns ``{"bottleneck": name|None, "bottleneck_index": idx|None,
    "capacity_eps": float, "ops": [...]}`` where each op row carries
    ``busy_share`` (fraction of total timed wall) and ``capacity_eps``
    (``parallelism / mean_cost`` — the op's standalone throughput ceiling
    in elements/s).  The bottleneck is the MINIMUM-capacity op among those
    with measured cost and at least ``min_elements`` processed.
    """
    if isinstance(stats_or_profile, Mapping):
        rows = profile_ops(stats_or_profile)
    else:
        rows = [dict(r) for r in stats_or_profile]
    total_wall = sum(r["wall_s"] for r in rows) or 0.0
    bottleneck: Optional[Dict[str, Any]] = None
    for r in rows:
        r["busy_share"] = r["wall_s"] / total_wall if total_wall > 0 else 0.0
        if r["mean_cost_s"] > 0 and r["elements"] >= min_elements:
            r["capacity_eps"] = max(1, r["parallelism"]) / r["mean_cost_s"]
            if bottleneck is None or r["capacity_eps"] < bottleneck["capacity_eps"]:
                bottleneck = r
        else:
            r["capacity_eps"] = float("inf")
    return {
        "bottleneck": bottleneck["name"] if bottleneck else None,
        "bottleneck_index": bottleneck["index"] if bottleneck else None,
        "capacity_eps": bottleneck["capacity_eps"] if bottleneck else float("inf"),
        "ops": rows,
    }
