"""Chrome trace-event export (``python -m repro.obs.export``).

Collects span buffers from every process of a deployment — dispatcher and
workers over the ``trace_dump`` RPC, plus any locally-held spans (client /
feeder tracers live in the consuming process) — and writes them as Chrome
trace-event JSON: open the file in Perfetto (https://ui.perfetto.dev) or
``chrome://tracing`` to see fetch / pipeline / encode / transfer /
device-put spans aligned per process on one wall-clock timeline.

Library use::

    from repro.obs import export
    spans = export.collect(dispatcher_address) + client.tracer.drain()
    export.export_chrome_trace("trace.json", spans)

CLI use (tcp/grpc deployments)::

    python -m repro.obs.export --dispatcher tcp://HOST:PORT --out trace.json
"""
from __future__ import annotations

import argparse
import json
from typing import Any, Dict, List, Optional

from ..core.transport import Stub, TransportError

__all__ = ["collect", "to_chrome", "export_chrome_trace", "main"]


def collect(
    dispatcher_address: str, include_workers: bool = True, max_spans: int = 0
) -> List[Dict[str, Any]]:
    """Drain span buffers from the dispatcher and (optionally) every
    registered worker.  Unreachable processes are skipped, not fatal — a
    trace export must work on a half-degraded deployment."""
    spans: List[Dict[str, Any]] = []
    try:
        resp = Stub(dispatcher_address).call("trace_dump", max_spans=max_spans)
        spans.extend(resp.get("spans", []))
    except (TransportError, ValueError):
        resp = {}
    addresses: List[str] = []
    if include_workers:
        try:
            listing = Stub(dispatcher_address).call("list_workers")
            addresses = [w["address"] for w in listing.get("workers", [])]
        except (TransportError, ValueError):
            addresses = []
    for addr in addresses:
        try:
            wresp = Stub(addr).call("trace_dump", max_spans=max_spans)
            spans.extend(wresp.get("spans", []))
        except (TransportError, ValueError):
            continue
    return spans


def to_chrome(spans: List[Dict[str, Any]]) -> List[Dict[str, Any]]:
    """Convert span dicts (``Tracer.drain`` output) to trace-event JSON.

    Each distinct span ``process`` becomes a pid with a metadata naming
    event; spans are complete ("X") events in wall-clock microseconds so
    multiple processes align on one timeline.
    """
    pids: Dict[str, int] = {}
    events: List[Dict[str, Any]] = []
    for s in spans:
        proc = str(s.get("process", "?"))
        pid = pids.get(proc)
        if pid is None:
            pid = pids[proc] = len(pids) + 1
            events.append(
                {
                    "ph": "M",
                    "name": "process_name",
                    "pid": pid,
                    "tid": 0,
                    "args": {"name": proc},
                }
            )
        args = dict(s.get("attrs") or {})
        args["trace_id"] = s.get("trace_id")
        args["span_id"] = s.get("span_id")
        if s.get("parent_id"):
            args["parent_id"] = s["parent_id"]
        events.append(
            {
                "ph": "X",
                "name": str(s.get("name", "span")),
                "cat": str(s.get("trace_id", "trace")),
                "pid": pid,
                "tid": 1,
                "ts": float(s.get("start_unix", 0.0)) * 1e6,
                "dur": max(1.0, float(s.get("duration_s", 0.0)) * 1e6),
                "args": args,
            }
        )
    return events


def export_chrome_trace(
    path: str, spans: List[Dict[str, Any]], metadata: Optional[Dict[str, Any]] = None
) -> int:
    """Write Perfetto-loadable JSON; returns the number of span events."""
    events = to_chrome(spans)
    doc = {"traceEvents": events, "otherData": metadata or {}}
    with open(path, "w") as f:
        json.dump(doc, f)
    return sum(1 for e in events if e.get("ph") == "X")


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.export",
        description="Export a deployment's trace spans as Chrome trace JSON",
    )
    ap.add_argument("--dispatcher", required=True, help="dispatcher address")
    ap.add_argument("--out", default="trace.json", help="output path")
    ap.add_argument("--max-spans", type=int, default=0, help="per-process cap (0 = all)")
    args = ap.parse_args(argv)
    spans = collect(args.dispatcher, max_spans=args.max_spans)
    n = export_chrome_trace(args.out, spans)
    print(f"wrote {n} spans from {args.dispatcher} to {args.out}")
    return 0


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
