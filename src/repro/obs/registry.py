"""Typed metrics registry: the single telemetry substrate for the service.

Before this module the repo's telemetry was three disconnected islands —
``WorkerMetrics`` (locked dataclass), ``ClientMetrics`` (unlocked ``+=``
from fetcher threads, losing updates), ``FeedMetrics`` (locked helpers) —
plus ad-hoc autoscaler/autotuner dicts.  All of them now sit on this
registry, which gives every process one uniform surface the new
``metrics_dump`` RPC (and ``python -m repro.obs.top``) can scrape.

Design constraints, in order:

1. **Writer exactness.**  Counters are hammered concurrently by runner
   producer threads and RPC handler threads; a bare ``+=`` is a
   read-modify-write that loses updates under thread switches (the
   pre-existing ``WorkerMetrics`` bug class, covered by
   ``test_worker_metrics_concurrent_add_is_exact``).  Every mutation holds
   the series' own lock.
2. **Lock-free reads.**  ``snapshot()`` never takes a lock: series values
   are single floats/ints whose loads are atomic under the GIL, so a
   snapshot is at worst one increment stale per series — it can never
   block a hot writer, and a stuck writer can never block the dashboard.
   (Histogram snapshots copy the bucket list; a torn read there is one
   observation short in one bucket, which the dashboard tolerates.)
3. **Labels are cheap after the first use.**  ``labels(...)`` interns the
   child series; hot paths hold the returned handle instead of re-keying
   per event.
"""
from __future__ import annotations

import bisect
import threading
from typing import Any, Dict, Iterable, List, Optional, Tuple

__all__ = [
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "get_registry",
]

# Default histogram bucket upper bounds (seconds-ish scale: the service's
# latencies live between 10µs RPCs and multi-second stalls).
DEFAULT_BUCKETS: Tuple[float, ...] = (
    0.0001, 0.00025, 0.0005, 0.001, 0.0025, 0.005, 0.01,
    0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0,
)


class _Series:
    """One labeled time series of a Counter/Gauge: a locked float cell.

    ``value`` is read WITHOUT the lock by snapshots (GIL-atomic float
    load); the lock only serializes read-modify-writes.
    """

    __slots__ = ("value", "_lock")

    def __init__(self) -> None:
        self.value: float = 0.0
        self._lock = threading.Lock()

    def add(self, delta: float = 1.0) -> None:
        with self._lock:
            self.value += delta

    def inc(self, delta: float = 1.0) -> None:
        self.add(delta)

    def set(self, value: float) -> None:
        # plain store is atomic; the lock keeps set/add linearized
        with self._lock:
            self.value = value


class _HistogramSeries:
    """One labeled histogram series: bucket counts + sum + count."""

    __slots__ = ("bounds", "bucket_counts", "sum", "count", "_lock")

    def __init__(self, bounds: Tuple[float, ...]):
        self.bounds = bounds
        self.bucket_counts = [0] * (len(bounds) + 1)  # +inf overflow bucket
        self.sum = 0.0
        self.count = 0
        self._lock = threading.Lock()

    def observe(self, value: float) -> None:
        i = bisect.bisect_left(self.bounds, value)
        with self._lock:
            self.bucket_counts[i] += 1
            self.sum += value
            self.count += 1

    def snapshot(self) -> Dict[str, Any]:
        # lock-free: list() copies under the GIL; a concurrent observe can
        # make the copy one observation short in one cell, never corrupt it
        return {
            "buckets": list(zip(self.bounds, self.bucket_counts)),
            "overflow": self.bucket_counts[-1],
            "sum": self.sum,
            "count": self.count,
            "mean": self.sum / self.count if self.count else 0.0,
        }


_LabelKey = Tuple[Tuple[str, str], ...]


def _label_key(labels: Dict[str, Any]) -> _LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Family:
    """A named metric family: unlabeled series + labeled children."""

    kind = "counter"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._children: Dict[_LabelKey, Any] = {}
        self._lock = threading.Lock()  # guards child creation only
        self._default = self._new_series()

    def _new_series(self) -> Any:
        return _Series()

    # -- unlabeled convenience (the common case) -------------------------
    @property
    def value(self) -> float:
        return self._default.value

    def labels(self, **labels: Any) -> Any:
        key = _label_key(labels)
        child = self._children.get(key)
        if child is None:
            with self._lock:
                child = self._children.setdefault(key, self._new_series())
        return child

    def snapshot(self) -> Dict[str, Any]:
        out: Dict[str, Any] = {
            "kind": self.kind,
            "value": self._series_value(self._default),
        }
        if self._children:
            out["series"] = {
                ",".join(f"{k}={v}" for k, v in key): self._series_value(s)
                for key, s in list(self._children.items())
            }
        return out

    @staticmethod
    def _series_value(s: Any) -> Any:
        return s.value


class Counter(_Family):
    """Monotonically increasing family.  ``add``/``inc`` on the default
    series; ``labels(...)`` for children."""

    kind = "counter"

    def add(self, delta: float = 1.0) -> None:
        self._default.add(delta)

    def inc(self, delta: float = 1.0) -> None:
        self._default.add(delta)


class Gauge(_Family):
    """Set-to-current-value family (pool sizes, occupancies, EMAs)."""

    kind = "gauge"

    def set(self, value: float) -> None:
        self._default.set(value)

    def add(self, delta: float = 1.0) -> None:
        self._default.add(delta)


class Histogram(_Family):
    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets: Optional[Iterable[float]] = None):
        self._bounds = tuple(buckets) if buckets is not None else DEFAULT_BUCKETS
        super().__init__(name, help)

    def _new_series(self) -> Any:
        return _HistogramSeries(self._bounds)

    def observe(self, value: float) -> None:
        self._default.observe(value)

    @staticmethod
    def _series_value(s: Any) -> Any:
        return s.snapshot()


class MetricsRegistry:
    """Process- or component-scoped collection of metric families.

    ``counter``/``gauge``/``histogram`` are get-or-create by name (so two
    components can share a family without coordination), with a kind check:
    re-registering a name as a different type is a bug, not a merge.
    """

    def __init__(self) -> None:
        self._families: Dict[str, _Family] = {}
        self._lock = threading.Lock()

    def _get_or_create(self, cls: type, name: str, help: str, **kw: Any) -> Any:
        fam = self._families.get(name)
        if fam is None:
            with self._lock:
                fam = self._families.get(name)
                if fam is None:
                    fam = self._families[name] = cls(name, help, **kw)
        if not isinstance(fam, cls):
            raise TypeError(
                f"metric {name!r} already registered as {fam.kind}, "
                f"not {cls.__name__.lower()}"
            )
        return fam

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get_or_create(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get_or_create(Gauge, name, help)

    def histogram(
        self, name: str, help: str = "", buckets: Optional[Iterable[float]] = None
    ) -> Histogram:
        return self._get_or_create(Histogram, name, help, buckets=buckets)

    def snapshot(self) -> Dict[str, Dict[str, Any]]:
        """Point-in-time view of every family — read lock-free (see module
        docstring); safe to call from any thread at any rate."""
        return {name: fam.snapshot() for name, fam in list(self._families.items())}

    def values(self) -> Dict[str, float]:
        """Flat {name: default-series value} view (counters/gauges only) —
        what most tests and the dashboard's top-line numbers want."""
        return {
            name: fam.value
            for name, fam in list(self._families.items())
            if fam.kind != "histogram"
        }


# Per-process default registry: background singletons (autoscaler, autotuner,
# orchestrator) report here so one metrics_dump surfaces them all.
_DEFAULT = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _DEFAULT
