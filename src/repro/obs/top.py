"""Fleet dashboard (``python -m repro.obs.top``).

Scrapes the ``metrics_dump`` RPC on the dispatcher and on every registered
worker and renders the fleet the way the paper diagnoses it: per-job
consumer stall % (the input-bound fraction), per-worker throughput and
busy time, fleet-scheduler shares, and feed idle-per-step.  Between two
scrapes the worker counters are differenced into rates.

One-shot (CI / scripts)::

    python -m repro.obs.top --dispatcher tcp://HOST:PORT --once

Live (refreshes in place every ``--interval`` seconds) omit ``--once``.
``--json`` dumps the raw merged scrape for tooling.
"""
from __future__ import annotations

import argparse
import json
import time
from typing import Any, Dict, List, Optional

from ..core.transport import Stub, TransportError

__all__ = ["scrape", "render", "main"]


def scrape(dispatcher_address: str) -> Dict[str, Any]:
    """One fleet observation: dispatcher dump + per-worker dumps.

    Dead workers are reported, not fatal — the dashboard's job includes
    showing a degraded fleet.  ``t`` is a perf_counter timestamp used only
    for rate differencing between two scrapes in THIS process.
    """
    out: Dict[str, Any] = {"t": time.perf_counter(), "workers": {}, "errors": []}
    try:
        out["dispatcher"] = Stub(dispatcher_address).call("metrics_dump")
    except Exception as e:  # noqa: BLE001 — see below
        out["dispatcher"] = None
        out["errors"].append(f"dispatcher: {e!r}")
        return out
    for wid, addr in (out["dispatcher"].get("workers") or {}).items():
        try:
            out["workers"][wid] = Stub(addr).call("metrics_dump")
        except Exception as e:  # noqa: BLE001
            # broad on purpose: over tcp:// a dead worker is a clean
            # TransportError, but over inproc:// handler exceptions
            # propagate natively — a worker torn down between the fleet
            # listing above and this scrape raises whatever its handler
            # died with (KeyError, RuntimeError, ...).  The dashboard
            # must mark the row DOWN, never crash mid-refresh.
            out["workers"][wid] = None
            out["errors"].append(f"{wid}: {e!r}")
    return out


def _fmt(v: Optional[float], unit: str = "", digits: int = 1) -> str:
    if v is None:
        return "-"
    return f"{v:.{digits}f}{unit}"


def _counter(registry: Optional[Dict[str, Any]], name: str) -> float:
    fam = (registry or {}).get(name) or {}
    v = fam.get("value", 0.0)
    return float(v) if isinstance(v, (int, float)) else 0.0


def render(snap: Dict[str, Any], prev: Optional[Dict[str, Any]] = None) -> str:
    """Render one scrape (optionally differenced against the previous one
    for rates) as a fixed-width text dashboard."""
    lines: List[str] = []
    d = snap.get("dispatcher")
    if not d:
        return "dispatcher unreachable:\n  " + "\n  ".join(snap.get("errors", []))
    stats = d.get("stats") or {}
    dt = None
    if prev is not None and prev.get("dispatcher"):
        dt = max(1e-6, snap["t"] - prev["t"])

    jobs = stats.get("jobs") or {}
    lines.append(
        f"jobs: {len(jobs)}   workers: {stats.get('num_workers', 0)}   "
        f"errors: {len(snap.get('errors') or [])}"
    )
    lines.append("")
    lines.append(
        f"{'JOB':<22}{'POLICY':<9}{'TASKS':>6}{'SHARE':>7}{'WEIGHT':>8}"
        f"{'STALL%':>8}{'IDLE/STEP':>11}{'CLIENTS':>9}"
    )
    for jid, j in sorted(jobs.items()):
        cs = j.get("client_stall") or {}
        stall = cs.get("stall_frac")
        idle = cs.get("idle_s_per_step")
        name = j.get("name") or jid
        share = j.get("target_share")
        lines.append(
            f"{name[:21]:<22}{j.get('policy', '?'):<9}{j.get('tasks', 0):>6}"
            f"{share if share is not None else '-':>7}{j.get('weight', 1.0):>8.2f}"
            f"{_fmt(stall * 100 if stall is not None else None, '%'):>8}"
            f"{_fmt(idle * 1000 if idle is not None else None, 'ms'):>11}"
            f"{j.get('clients', 0):>9}"
        )
    lines.append("")
    lines.append(
        f"{'WORKER':<22}{'BATCH/S':>9}{'MB/S':>8}{'RPC/S':>8}{'BUSY%':>8}"
        f"{'OCC%':>7}{'BOTTLENECK':>20}"
    )
    prev_workers = (prev or {}).get("workers") or {}
    dworkers = stats.get("workers") or {}
    for wid, w in sorted(snap.get("workers", {}).items()):
        if w is None:
            lines.append(f"{wid[:21]:<22}{'DOWN':>9}")
            continue
        reg = w.get("registry") or {}
        served = _counter(reg, "worker_batches_served")
        nbytes = _counter(reg, "worker_bytes_served")
        rpcs = _counter(reg, "worker_rpc_count")
        busy = _counter(reg, "worker_busy_time")
        rate = mbs = rps = busy_pct = None
        pw = prev_workers.get(wid)
        if dt is not None and pw:
            preg = pw.get("registry") or {}
            rate = (served - _counter(preg, "worker_batches_served")) / dt
            mbs = (nbytes - _counter(preg, "worker_bytes_served")) / dt / 1e6
            rps = (rpcs - _counter(preg, "worker_rpc_count")) / dt
            busy_pct = (busy - _counter(preg, "worker_busy_time")) / dt * 100
        occ = (dworkers.get(wid) or {}).get("buffer_occupancy")
        stall_report = w.get("stall_report") or {}
        lines.append(
            f"{wid[:21]:<22}{_fmt(rate):>9}{_fmt(mbs, '', 2):>8}{_fmt(rps):>8}"
            f"{_fmt(busy_pct, '%'):>8}"
            f"{_fmt(occ * 100 if occ is not None else None, '%'):>7}"
            f"{str(stall_report.get('bottleneck') or '-')[:19]:>20}"
        )
    bg = d.get("registry") or {}
    bg_errors = {
        name: fam
        for name, fam in bg.items()
        if name.endswith("errors_total") and (fam.get("value") or fam.get("series"))
    }
    if bg_errors:
        lines.append("")
        lines.append("background errors:")
        for name, fam in sorted(bg_errors.items()):
            total = fam.get("value", 0)
            series = fam.get("series") or {}
            detail = " ".join(f"{k}={int(v)}" for k, v in sorted(series.items()))
            lines.append(f"  {name}: {int(total)} {detail}".rstrip())
    return "\n".join(lines)


def main(argv: Optional[List[str]] = None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.top",
        description="Live fleet dashboard over the metrics_dump RPC",
    )
    ap.add_argument("--dispatcher", required=True, help="dispatcher address")
    ap.add_argument("--interval", type=float, default=2.0)
    ap.add_argument("--once", action="store_true", help="print one scrape and exit")
    ap.add_argument("--json", action="store_true", help="dump the raw scrape as JSON")
    args = ap.parse_args(argv)
    prev: Optional[Dict[str, Any]] = None
    while True:
        snap = scrape(args.dispatcher)
        if args.json:
            print(json.dumps(snap, default=str))
        else:
            if not args.once:
                print("\x1b[2J\x1b[H", end="")  # clear screen, home cursor
            print(render(snap, prev))
        if args.once:
            return 0 if snap.get("dispatcher") else 1
        prev = snap
        time.sleep(max(0.1, args.interval))


if __name__ == "__main__":  # pragma: no cover
    raise SystemExit(main())
