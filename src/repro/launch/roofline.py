"""Roofline analysis from compiled dry-run artifacts (deliverable g).

Three terms per (arch × shape × mesh), in seconds:

    compute    = HLO_FLOPs       / (chips × 197e12 bf16 FLOP/s)
    memory     = HLO_bytes       / (chips × 819e9  B/s HBM)
    collective = collective_B    / (chips × 50e9   B/s per ICI link)

``cost_analysis()`` on the SPMD-partitioned module reports PER-DEVICE flops
and bytes — but counts while-loop (scan) bodies ONCE, so we use the
trip-count-aware analyzer in ``hlo_cost`` for flops/bytes.  Collective bytes
come from the same pass: operand sizes of all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute, invocation-weighted.

MODEL_FLOPS = 6·N·D for training (N params — active params for MoE; D
tokens), 2·N_active·tokens for forward-only (prefill/decode) cells; the
ratio MODEL/HLO flags remat and padding waste.
"""
from __future__ import annotations

import json
import re
from dataclasses import asdict, dataclass
from typing import Any, Dict, Optional

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12  # bf16
HBM_BW = 819e9  # bytes/s
ICI_BW = 50e9  # bytes/s per link (conservative single-link figure)

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# matches dtype[shape] tokens, e.g. bf16[16,1024]{1,0}
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s+[^=]*?\b("
    + "|".join(_COLLECTIVES).replace("-", r"\-")
    + r")(?:-start|-done)?\(([^)]*)\)"
)


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def parse_collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum operand bytes per collective kind from optimized HLO text."""
    out: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    counts: Dict[str, int] = {c: 0 for c in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        kind, operands = m.group(1), m.group(2)
        total = sum(
            _shape_bytes(sm.group(1), sm.group(2))
            for sm in _SHAPE_RE.finditer(operands)
        )
        out[kind] += total
        counts[kind] += 1
    out_any: Dict[str, Any] = dict(out)
    out_any["total"] = sum(out.values())
    out_any["counts"] = counts
    return out_any


@dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities from the compiled module
    flops_per_device: float
    bytes_per_device: float
    collective_bytes_per_device: float
    # derived terms (seconds)
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    # accounting
    model_flops_total: float
    hlo_flops_total: float
    useful_ratio: float  # MODEL_FLOPS / HLO_FLOPs(total)
    roofline_fraction: float  # compute_s / max(all terms) — compute-bound=1
    memory_per_device_bytes: Dict[str, float]
    collective_breakdown: Dict[str, Any]
    note: str = ""

    def to_json(self) -> Dict[str, Any]:
        return asdict(self)


def model_flops(
    cfg, shape, kind: str, chips: int
) -> float:
    """6·N·D train, 2·N·D forward-only (N = active params)."""
    counts = cfg.param_counts()
    n_active = counts["active"]
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: ONE new token per sequence
    return 2.0 * n_active * shape.global_batch


def build_report(
    arch: str,
    shape_name: str,
    mesh_name: str,
    chips: int,
    cost: Dict[str, float],
    hlo_text: str,
    mem: Dict[str, float],
    cfg,
    shape,
    kind: str,
    note: str = "",
) -> RooflineReport:
    from . import hlo_cost

    hc = hlo_cost.analyze(hlo_text)
    flops_dev = float(hc.flops)  # trip-count-aware, per device (post-SPMD)
    bytes_dev = float(hc.bytes)
    coll: Dict[str, Any] = dict(hc.collective_detail)
    coll["total"] = hc.collective_bytes
    coll["counts"] = hc.collective_counts
    coll["xla_cost_analysis_flops_scan_once"] = float(cost.get("flops", 0.0))
    coll_dev = float(hc.collective_bytes)

    compute_s = flops_dev / PEAK_FLOPS
    memory_s = bytes_dev / HBM_BW
    collective_s = coll_dev / ICI_BW
    terms = {"compute": compute_s, "memory": memory_s, "collective": collective_s}
    dominant = max(terms, key=terms.get)
    mf = model_flops(cfg, shape, kind, chips)
    hlo_total = flops_dev * chips
    bound = max(terms.values())
    return RooflineReport(
        arch=arch,
        shape=shape_name,
        mesh=mesh_name,
        chips=chips,
        flops_per_device=flops_dev,
        bytes_per_device=bytes_dev,
        collective_bytes_per_device=coll_dev,
        compute_s=compute_s,
        memory_s=memory_s,
        collective_s=collective_s,
        dominant=dominant,
        model_flops_total=mf,
        hlo_flops_total=hlo_total,
        useful_ratio=mf / hlo_total if hlo_total else 0.0,
        roofline_fraction=compute_s / bound if bound > 0 else 0.0,
        memory_per_device_bytes=mem,
        collective_breakdown=coll,
        note=note,
    )
