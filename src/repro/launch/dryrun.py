import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
"""Multi-pod dry-run (deliverable e).

For every (architecture × input shape × mesh) cell:
  lower + compile the step function (train_step for train shapes, prefill /
  decode steps for serving shapes) against ShapeDtypeStruct inputs on the
  production mesh, print memory_analysis() and cost_analysis(), derive the
  three roofline terms (launch/roofline.py + launch/hlo_cost.py), and write
  a JSON record under experiments/dryrun/.

Meshes: single-pod (16, 16) = 256 chips; multi-pod (2, 16, 16) = 512 chips.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-14b --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both
  PYTHONPATH=src python -m repro.launch.dryrun --summarize
The --all driver runs each cell in a fresh subprocess (compile arenas are
per-process; a wedged cell cannot poison the sweep) and skips cells whose
JSON already exists (resumable).
"""
import argparse
import json
import subprocess
import sys
import time
import traceback

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")

# Sharding-plan hints (bf16 moments, FSDP over the pod axis) are declared
# per-config: ModelConfig.opt_state_dtype / ModelConfig.fsdp_over_pod.


def run_cell(arch: str, shape_name: str, mesh_name: str, out_dir: str,
             *, seq_shard: bool = False, microbatches: int = 1,
             param_dtype: str = "", moe_groups: int = 0,
             moe_pin: str = "auto", moe_expert_axis: str = "model",
             remat: str = "", tag: str = "") -> dict:
    import jax
    import jax.numpy as jnp

    from repro.configs import cell_supported, get_config
    from repro.dist import sharding_rules as SR
    from repro.dist.context import use_plan
    from repro.launch import specs as S
    from repro.launch.mesh import make_plan, make_production_mesh
    from repro.launch.roofline import build_report
    from repro.models import build_model
    from repro.models.config import SHAPES
    from repro.serve.engine import make_serve_step
    from repro.train import AdamWConfig, make_train_step
    from repro.train import optimizer as opt

    cfg = get_config(arch)
    if param_dtype:
        cfg = cfg.replace(param_dtype=param_dtype)
    if moe_groups and cfg.num_experts:
        cfg = cfg.replace(moe_groups=moe_groups)
    if remat:
        cfg = cfg.replace(remat=remat)
    shape = SHAPES[shape_name]
    record = {
        "arch": arch,
        "shape": shape_name,
        "mesh": mesh_name,
        "status": "unknown",
        "kind": shape.kind,
        "variant": {
            "seq_shard": seq_shard,
            "microbatches": microbatches,
            "param_dtype": param_dtype or cfg.param_dtype,
            "tag": tag,
        },
    }
    supported, reason = cell_supported(cfg, shape)
    if not supported:
        record.update(status="SKIP", reason=reason)
        return record

    multi = mesh_name == "multi"
    mesh = make_production_mesh(multi_pod=multi)
    chips = int(mesh.size)
    plan = make_plan(mesh, fsdp_over_pod=cfg.fsdp_over_pod,
                     seq_shard=seq_shard)
    if moe_pin != "auto" or moe_expert_axis != "model":
        import dataclasses
        plan = dataclasses.replace(
            plan, moe_pin=moe_pin, moe_expert_axis=moe_expert_axis
        )
    model = build_model(cfg)
    pshape = S.params_shape(model)

    t0 = time.perf_counter()  # monotonic: wall clock may jump mid-compile
    if shape.kind == "train":
        oc = AdamWConfig(state_dtype=cfg.opt_state_dtype)
        oshape = jax.eval_shape(lambda: opt.init_state(pshape, oc))
        state_shape = {"params": pshape, "opt": oshape}
        in_specs = S.train_input_specs(cfg, shape)
        state_shard = {
            "params": SR.make_param_shardings(mesh, pshape, cfg, plan),
            "opt": SR.make_opt_shardings(mesh, oshape, cfg, plan),
        }
        b_shard = SR.batch_sharding(mesh, plan, in_specs)
        fn = make_train_step(model, oc, microbatches=microbatches)
        jfn = jax.jit(fn, in_shardings=(state_shard, b_shard), donate_argnums=(0,))
        args = (state_shape, in_specs)
    elif shape.kind == "prefill":
        in_specs = S.prefill_input_specs(cfg, shape)
        p_shard = SR.make_param_shardings(mesh, pshape, cfg, plan)
        b_shard = SR.batch_sharding(mesh, plan, in_specs)

        def prefill(params, batch):
            return model.forward(params, batch, last_token_only=True)

        jfn = jax.jit(prefill, in_shardings=(p_shard, b_shard))
        args = (pshape, in_specs)
    else:  # decode
        tok_specs, cache_shape = S.decode_input_specs(model, cfg, shape)
        p_shard = SR.make_param_shardings(mesh, pshape, cfg, plan)
        c_shard = SR.cache_sharding(mesh, plan, cache_shape, cfg)
        t_shard = SR.batch_sharding(mesh, plan, tok_specs)
        if cfg.family == "encdec":
            def decode(params, cache, tokens):
                return model.decode_step(params, cache, tokens)
        else:
            decode = make_serve_step(model)
        jfn = jax.jit(
            decode,
            in_shardings=(p_shard, c_shard, t_shard["tokens"]),
            donate_argnums=(1,),
        )
        args = (pshape, cache_shape, tok_specs["tokens"])

    with mesh, use_plan(plan):
        lowered = jfn.lower(*args)
        t_lower = time.perf_counter() - t0
        t0 = time.perf_counter()
        compiled = lowered.compile()
        t_compile = time.perf_counter() - t0

    ma = compiled.memory_analysis()
    mem = {
        "argument_bytes": getattr(ma, "argument_size_in_bytes", 0),
        "output_bytes": getattr(ma, "output_size_in_bytes", 0),
        "temp_bytes": getattr(ma, "temp_size_in_bytes", 0),
        "alias_bytes": getattr(ma, "alias_size_in_bytes", 0),
    }
    # per-device footprint ≈ args + temp (aliased outputs overlap args)
    mem["per_device_total"] = (
        mem["argument_bytes"] + mem["temp_bytes"]
    )
    print(f"memory_analysis: {ma}")
    cost = compiled.cost_analysis()
    cost = cost if isinstance(cost, dict) else (cost[0] if cost else {})
    print(f"cost_analysis: flops={cost.get('flops')} bytes={cost.get('bytes accessed')}")
    hlo = compiled.as_text()
    rep = build_report(
        arch, shape_name, mesh_name, chips, cost, hlo, mem, cfg, shape, shape.kind
    )
    record.update(
        status="OK",
        lower_s=round(t_lower, 2),
        compile_s=round(t_compile, 2),
        hlo_bytes=len(hlo),
        roofline=rep.to_json(),
        fits_hbm_16g=bool(mem["per_device_total"] < 16e9),
    )
    return record


def cell_path(out_dir: str, arch: str, shape: str, mesh: str) -> str:
    return os.path.join(out_dir, f"{mesh}__{arch}__{shape}.json")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--summarize", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    # §Perf variant knobs (experiments/perf/<tag>__<cell>.json)
    ap.add_argument("--seq-shard", action="store_true",
                    help="sequence-parallel activations over the model axis")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--param-dtype", default="",
                    help="override cfg.param_dtype (e.g. bfloat16)")
    ap.add_argument("--moe-groups", type=int, default=0,
                    help="GShard 2D dispatch groups (align with dp shards)")
    ap.add_argument("--moe-pin", default="auto",
                    choices=["auto", "group", "group_ep"],
                    help="MoE dispatch-buffer sharding pin")
    ap.add_argument("--moe-expert-axis", default="model",
                    choices=["model", "data"],
                    help="mesh axis sharding the expert dim of MoE weights")
    ap.add_argument("--remat", default="",
                    choices=["", "none", "block"],
                    help="override cfg.remat (quantify recompute waste)")
    ap.add_argument("--tag", default="", help="variant tag; files go to --out")
    args = ap.parse_args()
    out_dir = os.path.abspath(args.out)
    os.makedirs(out_dir, exist_ok=True)

    if args.summarize:
        summarize(out_dir)
        return

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        from repro.configs import ARCH_IDS
        from repro.models.config import SHAPES

        cells = [
            (a, s, m) for m in meshes for a in ARCH_IDS for s in SHAPES
        ]
        done = ok = failed = 0
        for a, s, m in cells:
            prefix = f"{args.tag}__" if args.tag else ""
            path = cell_path(out_dir, f"{prefix}{a}", s, m)
            if os.path.exists(path) and not args.force:
                done += 1
                continue
            print(f"=== {m} / {a} / {s} ===", flush=True)
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", a, "--shape", s, "--mesh", m, "--out", out_dir,
            ]
            # forward variant knobs to per-cell subprocesses
            if args.seq_shard:
                cmd.append("--seq-shard")
            if args.microbatches != 1:
                cmd += ["--microbatches", str(args.microbatches)]
            if args.param_dtype:
                cmd += ["--param-dtype", args.param_dtype]
            if args.moe_groups:
                cmd += ["--moe-groups", str(args.moe_groups)]
            if args.moe_pin != "auto":
                cmd += ["--moe-pin", args.moe_pin]
            if args.moe_expert_axis != "model":
                cmd += ["--moe-expert-axis", args.moe_expert_axis]
            if args.tag:
                cmd += ["--tag", args.tag]
            rc = subprocess.run(
                cmd,
                env={**os.environ, "PYTHONPATH": _pythonpath()},
                timeout=3600,
            )
            if rc.returncode == 0:
                ok += 1
            else:
                failed += 1
        print(f"done(existing)={done} ok={ok} failed={failed}")
        summarize(out_dir)
        return

    record = {"arch": args.arch, "shape": args.shape, "mesh": meshes[0]}
    try:
        record = run_cell(
            args.arch, args.shape, meshes[0], out_dir,
            seq_shard=args.seq_shard, microbatches=args.microbatches,
            param_dtype=args.param_dtype, moe_groups=args.moe_groups,
            moe_pin=args.moe_pin, moe_expert_axis=args.moe_expert_axis,
            remat=args.remat, tag=args.tag,
        )
    except Exception as e:
        record.update(status="FAIL", error=repr(e), traceback=traceback.format_exc())
        print(record["traceback"], file=sys.stderr)
    prefix = f"{args.tag}__" if args.tag else ""
    path = cell_path(out_dir, f"{prefix}{args.arch}", args.shape, meshes[0])
    with open(path, "w") as f:
        json.dump(record, f, indent=1)
    print(json.dumps({k: v for k, v in record.items() if k != "traceback"}, indent=1))
    sys.exit(0 if record.get("status") in ("OK", "SKIP") else 1)


def _pythonpath() -> str:
    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    cur = os.environ.get("PYTHONPATH", "")
    return f"{src}:{cur}" if cur else src


def summarize(out_dir: str) -> None:
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if not fn.endswith(".json"):
            continue
        with open(os.path.join(out_dir, fn)) as f:
            rows.append(json.load(f))
    print(f"{'mesh':6s} {'arch':22s} {'shape':12s} {'status':6s} "
          f"{'compute_s':>10s} {'memory_s':>10s} {'coll_s':>10s} {'dom':>10s} "
          f"{'useful':>7s} {'mem/dev':>9s} {'compile':>8s}")
    for r in rows:
        rl = r.get("roofline") or {}
        mem_gb = (rl.get("memory_per_device_bytes", {}) or {}).get("per_device_total", 0) / 1e9
        print(
            f"{r.get('mesh',''):6s} {r.get('arch',''):22s} {r.get('shape',''):12s} "
            f"{r.get('status',''):6s} "
            f"{rl.get('compute_s', 0):10.4f} {rl.get('memory_s', 0):10.4f} "
            f"{rl.get('collective_s', 0):10.4f} {rl.get('dominant',''):>10s} "
            f"{rl.get('useful_ratio', 0):7.2f} {mem_gb:8.1f}G "
            f"{r.get('compile_s', 0):7.1f}s"
        )


if __name__ == "__main__":
    main()
