"""Trip-count-aware HLO cost analysis.

XLA's built-in ``compiled.cost_analysis()`` counts a while-loop body ONCE —
for scan-over-layers models that under-reports FLOPs by ~num_layers×.  This
module parses the optimized (post-SPMD, per-device) HLO text and computes:

  * flops  — dot/convolution flops, weighted by computation invocation count
             (while bodies × trip count, fusion/called bodies × caller count)
  * bytes  — memory traffic at fusion granularity: operand + result bytes of
             top-level instructions (fusions counted as single instructions,
             mirroring XLA's fusion-boundary bytes-accessed model)
  * collective operand bytes, invocation-weighted (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute)

Trip counts come from the while op's ``backend_config known_trip_count``
(present for scan-lowered loops), falling back to the largest integer
literal in the loop condition.

Validated in tests against unrolled-vs-scanned small models and against the
analytic 6·N·D estimate for dense LMs.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2,
    "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1, "f8e4m3b11fnuz": 1, "f8e5m2fnuz": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "c64": 8, "c128": 16,
}

_DT_ALT = "|".join(sorted(_DTYPE_BYTES, key=len, reverse=True))
_SHAPE_TOKEN = re.compile(rf"\b({_DT_ALT})\[([0-9,]*)\]")

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

_COMP_HDR = re.compile(r"^(ENTRY\s+)?%([\w\.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w\.\-]+)\s*=\s*(.+?)\s+([\w\-]+)\((.*)$"
)
_ATTR_CALLS = re.compile(r"calls=%?([\w\.\-]+)")
_ATTR_BODY = re.compile(r"body=%?([\w\.\-]+)")
_ATTR_COND = re.compile(r"condition=%?([\w\.\-]+)")
_ATTR_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")
_ATTR_TO_APPLY = re.compile(r"to_apply=%?([\w\.\-]+)")
_CONTRACT = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_CONST_INT = re.compile(r"constant\((\d+)\)")
_TRIP = re.compile(r"known_trip_count[^0-9]*(\d+)")


def _nelems(dims: str) -> int:
    n = 1
    if dims.strip():
        for d in dims.split(","):
            n *= int(d)
    return n


def _shape_bytes(dtype: str, dims: str) -> int:
    return _nelems(dims) * _DTYPE_BYTES[dtype]


_OPERAND_REF = re.compile(r"%([\w\.\-]+)")


@dataclass
class Instruction:
    name: str
    opcode: str
    result: List[Tuple[str, str]]  # shape tokens of the result type
    operand_names: List[str]  # %refs inside the call parens
    attrs: str  # text after the closing paren of the args

    def result_bytes(self) -> int:
        return sum(_shape_bytes(d, s) for d, s in self.result)


@dataclass
class Computation:
    name: str
    instructions: List[Instruction] = field(default_factory=list)
    symtab: Dict[str, List[Tuple[str, str]]] = field(default_factory=dict)

    def operand_shapes(self, ins: Instruction) -> List[List[Tuple[str, str]]]:
        return [self.symtab.get(n, []) for n in ins.operand_names]

    def operand_bytes(self, ins: Instruction) -> int:
        return sum(
            _shape_bytes(d, s)
            for shapes in self.operand_shapes(ins)
            for d, s in shapes
        )

    def param_slice_bytes(self) -> Dict[int, int]:
        """For fused computations: parameters consumed ONLY by (dynamic-)slice
        ops effectively read just the slice, not the whole operand — map
        param index -> bytes actually read.  (Scan bodies slice one layer's
        weights out of the stacked array; charging the full stack per trip
        would overcount HBM traffic by num_layers×.)"""
        # parameter index: use declaration order (HLO prints parameter(N)
        # instructions in index order within a computation).
        idx = 0
        out: Dict[int, int] = {}
        uses: Dict[str, List[str]] = {}
        for ins in self.instructions:
            for n in ins.operand_names:
                uses.setdefault(n, []).append(ins.opcode)
        for ins in self.instructions:
            if ins.opcode != "parameter":
                continue
            consumers = uses.get(ins.name, [])
            if consumers and all(c in ("dynamic-slice", "slice") for c in consumers):
                # bytes read = sum of slice result bytes (count each use once)
                total = 0
                for other in self.instructions:
                    if other.opcode in ("dynamic-slice", "slice") and ins.name in other.operand_names:
                        total += other.result_bytes()
                out[idx] = total
            elif consumers and all(c == "dynamic-update-slice" for c in consumers):
                # destination of an in-place update: written bytes = update size
                total = 0
                for other in self.instructions:
                    if other.opcode == "dynamic-update-slice" and other.operand_names and other.operand_names[0] == ins.name:
                        # update operand is the second arg
                        if len(other.operand_names) > 1:
                            upd = self.symtab.get(other.operand_names[1], [])
                            total += sum(_shape_bytes(d, s) for d, s in upd)
                out[idx] = total
            idx += 1
        return out


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry = m.group(2)
            continue
        if line.strip().startswith("}"):
            cur = None
            continue
        m = _INSTR.match(line)
        if not m:
            continue
        name, result_type, opcode, rest = m.groups()
        # split args (balanced parens) from trailing attributes
        depth = 1
        end = len(rest)
        for i, ch in enumerate(rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    end = i
                    break
        args, attrs = rest[:end], rest[end + 1 :]
        ins = Instruction(
            name=name,
            opcode=opcode,
            result=[(t.group(1), t.group(2)) for t in _SHAPE_TOKEN.finditer(result_type)],
            operand_names=_OPERAND_REF.findall(args),
            attrs=attrs,
        )
        cur.instructions.append(ins)
        cur.symtab[name] = ins.result
    return comps, entry


def _trip_count(ins: Instruction, comps: Dict[str, Computation]) -> int:
    m = _TRIP.search(ins.attrs)
    if m:
        return max(1, int(m.group(1)))
    cm = _ATTR_COND.search(ins.attrs)
    if cm and cm.group(1) in comps:
        best = 0
        for ci in comps[cm.group(1)].instructions:
            for mm in _CONST_INT.finditer(ci.attrs):
                best = max(best, int(mm.group(1)))
            if ci.opcode == "constant":
                # constants appear as `%c = s32[] constant(8)` — args empty,
                # value inside parens was consumed into args text; re-check
                pass
        # also scan raw constants in the condition: value is in args of the
        # constant instruction line which we stored as operands-free; use a
        # permissive text search over instruction names/attrs
        if best:
            return best
    return 1


@dataclass
class HloCost:
    flops: float = 0.0
    bytes: float = 0.0
    collective_bytes: float = 0.0
    collective_detail: Dict[str, float] = field(default_factory=dict)
    collective_counts: Dict[str, float] = field(default_factory=dict)
    dot_flops: float = 0.0
    while_trips: List[int] = field(default_factory=list)


_SKIP_BYTES_OPS = {
    "parameter", "constant", "tuple", "get-tuple-element", "while", "call",
    "conditional", "bitcast", "bitcast-convert", "after-all", "partition-id",
    "replica-id", "iota",
}

# ops whose nested computation is tiny (reducers/comparators): do not recurse
_TRIVIAL_CALLEES = {
    "reduce", "reduce-window", "select-and-scatter", "sort", "map", "scatter",
    "all-reduce", "reduce-scatter",
}


def _dot_flops(ins: Instruction, comp: Computation) -> float:
    out_elems = sum(_nelems(s) for _, s in ins.result)
    contract = 1
    m = _CONTRACT.search(ins.attrs)
    lhs_shapes = comp.operand_shapes(ins)
    if m and lhs_shapes and lhs_shapes[0]:
        lhs_dims = lhs_shapes[0][0][1].split(",") if lhs_shapes[0][0][1] else []
        for idx in m.group(1).split(","):
            if idx.strip() and int(idx) < len(lhs_dims):
                contract *= int(lhs_dims[int(idx)])
    return 2.0 * out_elems * contract


def analyze(text: str) -> HloCost:
    comps, entry = parse_hlo(text)
    cost = HloCost(
        collective_detail={c: 0.0 for c in _COLLECTIVES},
        collective_counts={c: 0.0 for c in _COLLECTIVES},
    )
    if entry is None:
        return cost

    def visit(comp: Computation, mult: float, count_bytes: bool, depth: int = 0) -> None:
        if depth > 32:
            return
        for ins in comp.instructions:
            op = ins.opcode
            if op == "dot":
                f = _dot_flops(ins, comp) * mult
                cost.flops += f
                cost.dot_flops += f
            elif op == "fusion":
                m = _ATTR_CALLS.search(ins.attrs)
                callee = comps.get(m.group(1)) if m else None
                if callee is not None:
                    visit(callee, mult, False, depth + 1)
                if count_bytes:
                    b = ins.result_bytes()
                    slice_map = callee.param_slice_bytes() if callee else {}
                    for i, shapes in enumerate(comp.operand_shapes(ins)):
                        full = sum(_shape_bytes(d, s) for d, s in shapes)
                        b += min(slice_map.get(i, full), full)
                    cost.bytes += b * mult
            elif op == "while":
                trips = _trip_count(ins, comps)
                cost.while_trips.append(trips)
                bm = _ATTR_BODY.search(ins.attrs)
                if bm and bm.group(1) in comps:
                    visit(comps[bm.group(1)], mult * trips, count_bytes, depth + 1)
            elif op == "conditional":
                bm = _ATTR_BRANCHES.search(ins.attrs)
                if bm:
                    for name in re.findall(r"%?([\w\.\-]+)", bm.group(1)):
                        if name in comps:
                            visit(comps[name], mult, count_bytes, depth + 1)
            elif op == "call":
                m = _ATTR_TO_APPLY.search(ins.attrs)
                if m and m.group(1) in comps:
                    visit(comps[m.group(1)], mult, count_bytes, depth + 1)
            elif op in ("dynamic-slice", "slice"):
                if count_bytes:
                    cost.bytes += 2 * ins.result_bytes() * mult  # read + write slice
            elif op == "dynamic-update-slice":
                if count_bytes:
                    upd = 0
                    if len(ins.operand_names) > 1:
                        upd = sum(
                            _shape_bytes(d, s)
                            for d, s in comp.symtab.get(ins.operand_names[1], [])
                        )
                    cost.bytes += 2 * upd * mult  # read update + write slice
            else:
                if op in _TRIVIAL_CALLEES:
                    pass  # reducer bodies are scalar lambdas — skip
                if count_bytes and op not in _SKIP_BYTES_OPS:
                    cost.bytes += (ins.result_bytes() + comp.operand_bytes(ins)) * mult
            base = op.replace("-start", "").replace("-done", "")
            if base in _COLLECTIVES and not op.endswith("-done"):
                ob = comp.operand_bytes(ins) * mult
                cost.collective_bytes += ob
                cost.collective_detail[base] += ob
                cost.collective_counts[base] += mult
        return

    visit(comps[entry], 1.0, True)
    return cost
