"""Production mesh construction.

Single-pod: (data=16, model=16) = 256 chips (one v5e pod).
Multi-pod:  (pod=2, data=16, model=16) = 512 chips; the ``pod`` axis carries
data parallelism over DCN (params replicated per pod by default; FSDP can
extend over ("pod","data") for the 1T-param configs — see ShardingPlan).

Defined as FUNCTIONS so importing this module never touches jax device
state; only launch/dryrun.py (which sets XLA_FLAGS first) builds the big
meshes.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np
from jax.sharding import Mesh

from ..dist.context import ShardingPlan


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    n = int(np.prod(shape))
    devices = jax.devices()
    if len(devices) < n:
        raise RuntimeError(
            f"mesh {shape} needs {n} devices, have {len(devices)} — run via "
            "launch/dryrun.py which sets xla_force_host_platform_device_count"
        )
    return jax.make_mesh(shape, axes, devices=devices[:n])


def make_plan(mesh: Mesh, *, fsdp_over_pod: bool = False,
              seq_shard: bool = False) -> ShardingPlan:
    multi = "pod" in mesh.axis_names
    data_axes = ("pod", "data") if multi else ("data",)
    fsdp = ("pod", "data") if (multi and fsdp_over_pod) else "data"
    return ShardingPlan(
        data_axes=data_axes,
        model_axis="model",
        fsdp_axis=fsdp,
        seq_axis="model" if seq_shard else None,
    )


def make_test_mesh(data: int = 1, model: int = 1) -> Optional[Mesh]:
    """Tiny mesh over however many devices exist (CPU tests)."""
    n = data * model
    if len(jax.devices()) < n:
        return None
    return jax.make_mesh((data, model), ("data", "model"), devices=jax.devices()[:n])
