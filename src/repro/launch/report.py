"""Generate the EXPERIMENTS.md §Dry-run and §Roofline tables from the
experiments/dryrun/*.json artifacts.

Usage: PYTHONPATH=src python -m repro.launch.report [--out EXPERIMENTS.md]
prints markdown to stdout (the EXPERIMENTS.md sections are assembled from
this output plus the hand-written §Perf log).
"""
from __future__ import annotations

import argparse
import json
import os
from typing import Any, Dict, List

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "experiments", "dryrun")


def load(out_dir: str) -> List[Dict[str, Any]]:
    rows = []
    for fn in sorted(os.listdir(out_dir)):
        if fn.endswith(".json"):
            with open(os.path.join(out_dir, fn)) as f:
                rows.append(json.load(f))
    return rows


def fmt_bytes(b: float) -> str:
    return f"{b/1e9:.1f}G" if b >= 1e8 else f"{b/1e6:.0f}M"


def dryrun_table(rows: List[Dict[str, Any]], mesh: str) -> str:
    out = [
        f"### Mesh `{mesh}`",
        "",
        "| arch | shape | status | lower+compile (s) | bytes/device | fits 16G HBM | collectives (counts) |",
        "|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if r["status"] == "SKIP":
            out.append(
                f"| {r['arch']} | {r['shape']} | SKIP | — | — | — | {r.get('reason','')} |"
            )
            continue
        rl = r.get("roofline") or {}
        mem = (rl.get("memory_per_device_bytes") or {}).get("per_device_total", 0)
        cb = rl.get("collective_breakdown") or {}
        counts = cb.get("counts") or {}
        cstr = ", ".join(
            f"{k}:{int(v)}" for k, v in counts.items() if v
        ) or "none"
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} | "
            f"{r.get('lower_s',0):.1f}+{r.get('compile_s',0):.1f} | "
            f"{fmt_bytes(mem)} | {'yes' if r.get('fits_hbm_16g') else 'NO'} | {cstr} |"
        )
    return "\n".join(out)


def roofline_table(rows: List[Dict[str, Any]]) -> str:
    out = [
        "| arch | shape | compute (s) | memory (s) | collective (s) | dominant | MODEL/HLO flops | roofline frac | next lever |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        if r.get("mesh") != "single" or r["status"] != "OK":
            continue
        rl = r["roofline"]
        lever = _lever(rl)
        out.append(
            f"| {r['arch']} | {r['shape']} | {rl['compute_s']:.4g} | "
            f"{rl['memory_s']:.4g} | {rl['collective_s']:.4g} | "
            f"**{rl['dominant']}** | {rl['useful_ratio']:.2f} | "
            f"{rl['roofline_fraction']:.3f} | {lever} |"
        )
    return "\n".join(out)


def _lever(rl: Dict[str, Any]) -> str:
    dom = rl["dominant"]
    if dom == "memory":
        if rl["useful_ratio"] < 0.6:
            return "cut remat recompute / padding waste (useful ratio low)"
        return "shard activations wider / microbatch to shrink live set"
    if dom == "collective":
        cb = rl.get("collective_breakdown") or {}
        top = max(
            ((k, v) for k, v in cb.items() if k not in ("total", "counts") and isinstance(v, (int, float))),
            key=lambda kv: kv[1], default=("?", 0),
        )[0]
        return f"reduce {top} volume (reshard or overlap)"
    return "compute-bound — at roofline, tune MXU utilization"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=os.path.abspath(OUT_DIR))
    args = ap.parse_args()
    rows = load(args.dir)
    ok = sum(1 for r in rows if r["status"] == "OK")
    skip = sum(1 for r in rows if r["status"] == "SKIP")
    print("## §Dry-run\n")
    print(f"{ok} OK / {skip} SKIP of {len(rows)} cells "
          "(SKIPs: `long_500k` on pure full-attention archs, per DESIGN.md §4).\n")
    print(dryrun_table(rows, "single"))
    print()
    print(dryrun_table(rows, "multi"))
    print("\n## §Roofline (single-pod 16×16 = 256 chips, TPU v5e)\n")
    print("Terms per §Roofline spec: compute = HLO_FLOPs/(chips·197e12); "
          "memory = HLO_bytes/(chips·819e9); collective = coll_bytes/(chips·50e9). "
          "FLOPs/bytes are trip-count-aware per-device values from the "
          "SPMD-partitioned module (launch/hlo_cost.py).\n")
    print(roofline_table(rows))


if __name__ == "__main__":
    main()
