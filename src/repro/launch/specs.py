"""ShapeDtypeStruct input stand-ins for every (arch × shape) cell.

``input_specs`` returns weak-type-correct, shardable specs with NO device
allocation — the dry-run lowers against these.  Modality frontends are
STUBS per the assignment: whisper receives precomputed 1500-frame mel
embeddings, qwen2-vl receives pre-embedded mixed text/vision tokens plus
(t,h,w) M-RoPE position ids.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from ..models import Model, build_model
from ..models.config import ModelConfig, ShapeConfig
from ..models.layers import DTYPES


def train_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    dt = DTYPES[cfg.dtype]
    if cfg.family == "encdec":
        return {
            "enc_embeds": jax.ShapeDtypeStruct((B, cfg.encoder_seq, cfg.d_model), dt),
            "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    if cfg.family == "vlm":
        return {
            "embeds": jax.ShapeDtypeStruct((B, S, cfg.d_model), dt),
            "positions": jax.ShapeDtypeStruct((B, S, 3), jnp.int32),
            "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
        }
    return {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }


def prefill_input_specs(cfg: ModelConfig, shape: ShapeConfig) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(
    model: Model, cfg: ModelConfig, shape: ShapeConfig
) -> Tuple[Dict[str, Any], Any]:
    """(token specs, cache specs) for one-new-token decode over a seq_len-deep
    cache (the ``decode_*`` / ``long_*`` cells lower serve_step, NOT train)."""
    B, S = shape.global_batch, shape.seq_len
    if cfg.family == "encdec":
        cache = jax.eval_shape(
            lambda p: model.init_cache(p, B, S), params_shape(model)
        )
    else:
        cache = jax.eval_shape(lambda: model.init_cache(B, S))
    tokens = jax.ShapeDtypeStruct((B,), jnp.int32)
    return {"tokens": tokens}, cache


def params_shape(model: Model) -> Any:
    return jax.eval_shape(lambda: model.init(jax.random.PRNGKey(0)))


def opt_shape(model: Model, opt_cfg) -> Any:
    from ..train import optimizer as opt

    p = params_shape(model)
    return jax.eval_shape(lambda: opt.init_state(p_concrete(p), opt_cfg))


def p_concrete(shape_tree: Any) -> Any:
    """ShapeDtypeStructs pass through eval_shape as abstract values."""
    return shape_tree
