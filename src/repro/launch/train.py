"""Production training launcher: any assigned arch × shape on the
production mesh (dry-run lowering) or a reduced config end-to-end on CPU,
always fed through the disaggregated data service.

Two modes:

  --execute      REDUCED config, real training on this host's devices, data
                 via a local service deployment (workers + dispatcher).
                 The smoke-scale twin of the production job.
  (default)      FULL config, production mesh: lower + compile the sharded
                 train_step exactly as the multi-pod dry-run does, print the
                 memory/cost analysis, and exit — the pre-flight a real
                 cluster launch would run first.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-14b --shape train_4k --seq-shard
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-2.7b --execute --steps 30
"""
import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--seq-shard", action="store_true")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--execute", action="store_true",
                    help="run a reduced config for real on this host")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--ckpt-dir", default="")
    args = ap.parse_args()

    if args.execute:
        _execute_reduced(args)
        return

    # pre-flight: compile the production job (needs 512 host devices BEFORE
    # jax initializes, so re-exec through the dryrun module)
    from repro.launch import dryrun

    cmd = [
        sys.executable, "-m", "repro.launch.dryrun",
        "--arch", args.arch.replace("-", "_").replace(".", "p"),
        "--shape", args.shape, "--mesh", args.mesh,
        "--tag", "preflight",
    ]
    if args.seq_shard:
        cmd.append("--seq-shard")
    if args.microbatches != 1:
        cmd += ["--microbatches", str(args.microbatches)]
    import subprocess

    src = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", ".."))
    env = {**os.environ}
    env["PYTHONPATH"] = f"{src}:{env.get('PYTHONPATH', '')}"
    sys.exit(subprocess.run(cmd, env=env).returncode)


def _execute_reduced(args) -> None:
    import time

    import jax
    import jax.numpy as jnp
    import numpy as np

    from repro.configs import get_config
    from repro.core import start_service
    from repro.data import Dataset
    from repro.feed import DeviceFeeder
    from repro.launch import specs as S
    from repro.models import build_model
    from repro.models.config import ShapeConfig
    from repro.train import (
        AdamWConfig, init_train_state, make_train_step, save_checkpoint,
    )

    cfg = get_config(args.arch).scaled_down()
    model = build_model(cfg)
    opt = AdamWConfig(lr=1e-3, warmup_steps=5, decay_steps=args.steps)
    state = init_train_state(model, jax.random.PRNGKey(0), opt)
    step_fn = jax.jit(make_train_step(model, opt, microbatches=args.microbatches))

    B, SEQ = 4, 64
    shape = ShapeConfig("exec", SEQ, B, "train")
    spec = S.train_input_specs(cfg, shape)

    def make_batch(i):
        rng = np.random.default_rng(int(i))
        out = {}
        for k, v in spec.items():
            shp = v.shape[1:]  # per-example
            if jnp.issubdtype(v.dtype, jnp.integer):
                out[k] = rng.integers(1, cfg.vocab_size, shp).astype(np.int32)
            else:
                out[k] = rng.standard_normal(shp).astype(np.float32)
        return out

    svc = start_service(num_workers=args.workers)
    try:
        ds = (
            Dataset.range(10_000)
            .map(make_batch)
            .batch(B, drop_remainder=True)
            .distribute(service=svc, processing_mode="dynamic")
        )
        # device feed: background fetch + host->device transfer with a
        # double buffer — the step function never waits on the host loop
        # unless the service itself falls behind (feeder.metrics says which)
        with DeviceFeeder(ds, depth=2) as feeder:
            t0 = time.perf_counter()
            for step in range(1, args.steps + 1):
                batch = feeder.next()
                state, metrics = step_fn(state, batch)
                if step % 5 == 0 or step == args.steps:
                    jax.block_until_ready(metrics["loss"])
                    print(f"[{args.arch}] step {step:3d} "
                          f"loss {float(metrics['loss']):.4f} "
                          f"({(time.perf_counter()-t0)/step:.2f}s/step)",
                          flush=True)
            fm = feeder.metrics
            bd = fm.breakdown()
            print(f"[{args.arch}] feed: idle {fm.idle_s_per_step*1e3:.1f}ms/step "
                  f"(stall {fm.stall_fraction:.1%}) — "
                  f"fetch {bd['fetch']:.0%} / transfer {bd['transfer']:.0%} / "
                  f"compute {bd['compute']:.0%}", flush=True)
        if args.ckpt_dir:
            save_checkpoint(args.ckpt_dir, args.steps, state)
            print(f"checkpoint -> {args.ckpt_dir}")
    finally:
        svc.orchestrator.stop()


if __name__ == "__main__":
    main()
