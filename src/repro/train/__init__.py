"""repro.train — optimizer, train step, loss, checkpointing.

Training loops consume batches through ``DeviceFeeder`` (re-exported from
``repro.feed``): service fetch + host→device transfer run on a background
thread behind a double buffer, so the jitted step never blocks on input.
"""
from .optimizer import AdamWConfig, apply_updates, init_state, lr_schedule
from .step import (
    cross_entropy,
    init_train_state,
    make_eval_step,
    make_loss_fn,
    make_train_step,
)
from .checkpoint import latest_step, restore_checkpoint, save_checkpoint
from ..feed import DeviceFeeder, FeedMetrics

__all__ = [
    "AdamWConfig",
    "DeviceFeeder",
    "FeedMetrics",
    "apply_updates",
    "cross_entropy",
    "init_state",
    "init_train_state",
    "latest_step",
    "lr_schedule",
    "make_eval_step",
    "make_loss_fn",
    "make_train_step",
    "restore_checkpoint",
    "save_checkpoint",
]
