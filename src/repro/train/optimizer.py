"""AdamW with dtype-configurable state (no optax dependency).

Moments can be stored in bf16 for very large models (llama3-405b / kimi-k2
training state would not fit 256 chips with f32 moments); the update math is
always f32.  Global-norm gradient clipping included.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

DTYPES = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    decay_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    """Linear warmup + cosine decay to min_lr_ratio."""
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(1.0, cfg.decay_steps - cfg.warmup_steps),
        0.0,
        1.0,
    )
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_state(params: Any, cfg: AdamWConfig) -> Dict[str, Any]:
    dt = DTYPES[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros, params),
        "v": jax.tree.map(zeros, params),
    }


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply_updates(
    params: Any, grads: Any, state: Dict[str, Any], cfg: AdamWConfig
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    lr = lr_schedule(cfg, step)
    c1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    c2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)
    dt = DTYPES[cfg.state_dtype]

    def upd(p, g, m, v):
        g32 = g.astype(jnp.float32) * scale
        m32 = cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g32
        v32 = cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g32 * g32
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps)
        if p.ndim >= 2:  # decoupled weight decay on matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), m32.astype(dt), v32.astype(dt)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state["m"])
    flat_v = treedef.flatten_up_to(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, {"step": step, "m": new_m, "v": new_v}, metrics
