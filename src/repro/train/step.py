"""Train-step factory: loss + grad + AdamW update, microbatch accumulation,
and (pod, data, model) mesh sharding hooks.

``make_train_step(model, opt_cfg)`` returns a pure ``step(state, batch)``
suitable for ``jax.jit`` with explicit in/out shardings (see launch/dryrun).
"""
from __future__ import annotations

from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..models import Model
from . import optimizer as opt

PAD_ID = 0  # label id treated as padding (masked out of the loss)


def cross_entropy(
    logits: jnp.ndarray,  # (B, S, V) f32
    labels: jnp.ndarray,  # (B, S) i32
    z_loss: float = 1e-4,
) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    mask = (labels != PAD_ID).astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    zl = z_loss * jnp.square(lse) * mask
    denom = jnp.maximum(mask.sum(), 1.0)
    loss = (nll + zl).sum() / denom
    acc = ((jnp.argmax(logits, -1) == labels) * mask).sum() / denom
    return loss, {"loss": nll.sum() / denom, "z_loss": zl.sum() / denom, "accuracy": acc}


def make_loss_fn(model: Model) -> Callable:
    def loss_fn(params: Any, batch: Dict[str, Any]) -> Tuple[jnp.ndarray, Dict]:
        logits = model.forward(params, batch)
        return cross_entropy(logits, batch["labels"])

    return loss_fn


def make_train_step(
    model: Model,
    opt_cfg: Optional[opt.AdamWConfig] = None,
    microbatches: int = 1,
) -> Callable:
    """Returns step(train_state, batch) -> (train_state, metrics).

    train_state = {"params", "opt"}.  ``microbatches > 1`` accumulates
    gradients over batch slices (pipeline-friendly; also shrinks activation
    memory for the biggest configs).
    """
    opt_cfg = opt_cfg or opt.AdamWConfig()
    loss_fn = make_loss_fn(model)
    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def step(state: Dict[str, Any], batch: Dict[str, Any]):
        params = state["params"]
        if microbatches <= 1:
            (loss, aux), grads = grad_fn(params, batch)
        else:
            def slice_batch(i):
                return jax.tree.map(
                    lambda x: x.reshape((microbatches, -1) + x.shape[1:])[i], batch
                )

            def acc_body(carry, i):
                g_acc, l_acc = carry
                (l, _aux), g = grad_fn(params, slice_batch(i))
                g_acc = jax.tree.map(lambda a, b: a + b, g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = lax.scan(
                acc_body, (g0, jnp.zeros((), jnp.float32)), jnp.arange(microbatches)
            )
            grads = jax.tree.map(lambda g: g / microbatches, grads)
            loss = loss_sum / microbatches
            aux = {"loss": loss, "z_loss": jnp.zeros(()), "accuracy": jnp.zeros(())}
        new_params, new_opt, om = opt.apply_updates(params, grads, state["opt"], opt_cfg)
        metrics = {**aux, **om, "total_loss": loss}
        return {"params": new_params, "opt": new_opt}, metrics

    return step


def make_eval_step(model: Model) -> Callable:
    loss_fn = make_loss_fn(model)

    def step(params: Any, batch: Dict[str, Any]) -> Dict[str, jnp.ndarray]:
        _, aux = loss_fn(params, batch)
        return aux

    return step


def init_train_state(
    model: Model, rng: jax.Array, opt_cfg: Optional[opt.AdamWConfig] = None
) -> Dict[str, Any]:
    params = model.init(rng)
    return {"params": params, "opt": opt.init_state(params, opt_cfg or opt.AdamWConfig())}
