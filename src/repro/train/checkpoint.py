"""Distributed checkpointing: shard-wise npz + manifest (tensorstore-free).

Design for 1000+ nodes: each host writes only ITS param shards (here: the
single-process path writes everything, but the layout is per-leaf files so a
multi-host deployment maps leaf→owning host).  Restores are elastic: a
checkpoint taken on one data-parallel size restores onto another (arrays are
stored unsharded per leaf; resharding happens at device_put with the target
NamedSharding).  Atomicity via write-to-tmp + rename of the manifest —
a crashed save never corrupts the previous checkpoint (restart safety).
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np

MANIFEST = "manifest.json"


def _flatten_with_paths(tree: Any) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save_checkpoint(directory: str, step: int, state: Any, keep: int = 3) -> str:
    """Write ``state`` pytree under ``directory/step_<N>/``; prune old."""
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    tmp_dir = ckpt_dir + ".tmp"
    os.makedirs(tmp_dir, exist_ok=True)
    entries = []
    for key, leaf in _flatten_with_paths(state):
        arr = np.asarray(leaf)
        fname = key.replace("/", "__") + ".npy"
        np.save(os.path.join(tmp_dir, fname), arr)
        entries.append(
            {"key": key, "file": fname, "shape": list(arr.shape), "dtype": str(arr.dtype)}
        )
    manifest = {"step": step, "entries": entries}
    with open(os.path.join(tmp_dir, MANIFEST), "w") as f:
        json.dump(manifest, f)
    if os.path.exists(ckpt_dir):
        shutil.rmtree(ckpt_dir)
    os.replace(tmp_dir, ckpt_dir)  # atomic publish
    _prune(directory, keep)
    return ckpt_dir


def _prune(directory: str, keep: int) -> None:
    steps = sorted(
        d for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    )
    for d in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, d), ignore_errors=True)


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
        and os.path.exists(os.path.join(directory, d, MANIFEST))
    ]
    return max(steps) if steps else None


def restore_checkpoint(
    directory: str,
    target: Any,
    step: Optional[int] = None,
    shardings: Optional[Any] = None,
) -> Tuple[Any, int]:
    """Restore into the structure of ``target``.

    ``shardings``: optional matching pytree of NamedShardings — arrays are
    device_put directly to their target layout (elastic resume on a different
    mesh works because files store full arrays).
    """
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoint under {directory}")
    ckpt_dir = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(ckpt_dir, MANIFEST)) as f:
        manifest = json.load(f)
    by_key = {e["key"]: e for e in manifest["entries"]}

    flat_t = jax.tree_util.tree_flatten_with_path(target)
    shard_leaves = jax.tree.leaves(shardings) if shardings is not None else None
    leaves = []
    for i, (path, leaf) in enumerate(flat_t[0]):
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        e = by_key.get(key)
        if e is None:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = np.load(os.path.join(ckpt_dir, e["file"]))
        if shard_leaves is not None:
            leaves.append(jax.device_put(arr, shard_leaves[i]))
        else:
            leaves.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(flat_t[1], leaves), step
