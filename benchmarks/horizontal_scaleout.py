"""Paper Fig. 8 (a: speedup, b: cost): horizontal scale-out for input-bound
jobs.

Real tier: measures (on this machine) the per-batch preprocessing cost of a
vision-style augmentation pipeline and of a service hop (RPC+serialization),
plus a REAL small-scale colocated-vs-2-worker service run.  Sim tier: the
validated event model sweeps the paper's worker counts for four M-like
workloads whose CPU:accelerator cost ratios bracket the paper's M1–M3 +
ResNet50 mix, reporting speedup and Eq.-1 cost saving.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import CostRates, GCP_RATES, JobResources, cost_saving, start_service

# The paper's production jobs run TPU v4 (≈$3.22/chip-h public on-demand) —
# accelerator-heavy rates; the open-source anchor is the v2-8 GCP_RATES.
V4_RATES = CostRates(
    cpu_per_core_hour=GCP_RATES.cpu_per_core_hour,
    mem_per_gb_hour=GCP_RATES.mem_per_gb_hour,
    acc_per_chip_hour=3.22,
)
from repro.data import Dataset
from repro.data.elements import decode_element, encode_element

from .common import Row, SimParams, print_rows, simulate_throughput, time_fn


def vision_batch_pipeline(n_images=64, hw=64, batch=8):
    """Decode + crop + flip + normalize 'images' (synthetic, CPU-costed)."""
    rng = np.random.default_rng(0)
    imgs = [rng.integers(0, 256, (hw, hw, 3)).astype(np.uint8) for _ in range(n_images)]

    def augment(i):
        img = imgs[int(i) % n_images].astype(np.float32)
        y, x = int(i) % 8, (int(i) * 3) % 8
        img = img[y : y + hw - 8, x : x + hw - 8]
        if int(i) % 2:
            img = img[:, ::-1]
        return (img / 255.0 - 0.45) / 0.22

    return Dataset.range(n_images).map(augment).batch(batch)


def measure_real() -> List[Row]:
    rows: List[Row] = []
    ds = vision_batch_pipeline()

    batches = []
    t_pipe = time_fn(lambda: batches.extend(ds.as_numpy()), repeat=3)
    n_batches = len(batches) / 3
    batch_cost = t_pipe / max(1, len(ds.as_numpy()))
    rows.append(Row("preproc_cost_per_batch", batch_cost, "s", "real",
                    "vision augment pipeline, batch=8 64px"))

    # serialization + RPC hop cost (the client-side ingest bound)
    elem = ds.as_numpy()[0]
    enc = encode_element(elem)
    t_ser = time_fn(lambda: encode_element(elem), repeat=20)
    t_de = time_fn(lambda: decode_element(enc), repeat=20)
    rows.append(Row("serialize_per_batch", t_ser, "s", "real", f"{len(enc)} bytes"))
    rows.append(Row("deserialize_per_batch", t_de, "s", "real", ""))

    # real colocated vs 2-worker service throughput (1 core: contention-real)
    t0 = time.perf_counter()
    local = sum(1 for _ in ds)
    t_colo = time.perf_counter() - t0
    svc = start_service(num_workers=2)
    try:
        dds = ds.distribute(service=svc, processing_mode="dynamic")
        t0 = time.perf_counter()
        remote = sum(1 for _ in dds)
        t_svc = time.perf_counter() - t0
    finally:
        svc.orchestrator.stop()
    rows.append(Row("colocated_batches_per_s", local / t_colo, "batches/s", "real", ""))
    rows.append(Row("service2w_batches_per_s", remote / t_svc, "batches/s", "real",
                    "same machine: upper-bounds service overhead, not speedup"))
    return rows, batch_cost, t_ser + t_de


def sweep_sim(batch_cost: float, rpc: float) -> List[Row]:
    """Sim tier anchored on the paper's §4.2 workload parameters:

      colocated batches/s and ideal batches/s are the paper's measured
      values for M1/M2/M3/ResNet50; per-batch CPU cost follows from the
      colocated rate; the client ingest ceiling uses OUR measured
      serialization rate scaled to ~1 MB vision batches.  Worker counts and
      trainer hardware are the paper's (442/421/128/16 workers; 32/8/16/8
      accelerators).
    """
    rows: List[Row] = []
    per_mb = rpc / 0.3  # measured on a 0.3 MB batch -> s/MB
    # name: (colo b/s, ideal b/s, workers, trainers, accel/trainer, batch_MB)
    paper = {
        "M1": (0.55, 6.47, 442, 4, 8, 4.0),
        "M2": (4.7, 563.0, 421, 1, 8, 1.0),
        "M3": (22.2, 64.4, 128, 2, 8, 1.0),
        "ResNet50": (1.75, 4.5, 16, 1, 8, 12.0),  # 1024x224x224x3 bf16-ish
    }
    speedups, savings = [], []
    for name, (colo_bps, ideal_bps, workers, trainers, acc, mb) in paper.items():
        p = SimParams(
            step_time_s=1.0 / ideal_bps,
            batch_cost_s=1.0 / colo_bps,  # colocated host ≡ 1 "core-set"
            rpc_overhead_s=per_mb * mb,
            worker_parallelism=1,
            local_cores=1,
        )
        colo = simulate_throughput(p, num_workers=0)["batches_per_s"]
        got = simulate_throughput(p, num_workers=workers)["batches_per_s"]
        speedup = got / colo
        speedups.append(speedup)
        dur = 1.0
        colo_res = JobResources(duration_hours=dur, num_trainers=trainers,
                                accelerators_per_trainer=acc)
        dis_res = JobResources(
            duration_hours=dur / speedup,
            num_workers=workers,
            worker_cpu_util_cores=6.0,  # ~75% of an n2-standard-8
            worker_mem_util_gb=24.0,
            num_trainers=trainers,
            accelerators_per_trainer=acc,
        )
        rates = GCP_RATES if name == "ResNet50" else V4_RATES
        saving = cost_saving(colo_res, dis_res, rates)
        savings.append(saving)
        ingest_cap = 1.0 / p.rpc_overhead_s
        note = f"{workers} workers; ingest cap {ingest_cap:.0f} b/s"
        rows.append(Row(f"speedup_{name}", speedup, "x", "sim", note))
        rows.append(Row(f"cost_saving_{name}", saving, "x", "sim",
                        "Eq.1 " + ("v2-8 rates" if name == "ResNet50" else "v4 rates")))
    rows.append(Row("speedup_avg", float(np.mean(speedups)), "x", "sim",
                    "paper reports 31.7x avg"))
    rows.append(Row("cost_saving_avg", float(np.mean(savings)), "x", "sim",
                    "paper reports 26.2x avg (production rates undisclosed)"))
    return rows


def main() -> List[Row]:
    real_rows, batch_cost, rpc = measure_real()
    rows = real_rows + sweep_sim(batch_cost, rpc)
    print_rows(rows, "Fig8 horizontal scale-out: speedup + cost")
    return rows


if __name__ == "__main__":
    main()
