"""TPU adaptation of §3.6 (DESIGN.md §3.2): TF re-kernelizes per dynamic
shape; XLA recompiles per shape instead.  Coordinated reads bound the shape
set to the bucket boundaries, so we compile ONE executable per bucket and
route batches — this benchmark measures the real compile cost and cache
behavior of that scheme vs naive per-shape compilation.
"""
from __future__ import annotations

import time
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.models import build_model
from repro.train import AdamWConfig, init_train_state, make_train_step

from .common import Row, print_rows

BOUNDARIES = (32, 64, 96, 128)


def main() -> List[Row]:
    rows: List[Row] = []
    cfg = get_config("starcoder2-3b").scaled_down()
    model = build_model(cfg)
    state = init_train_state(model, jax.random.PRNGKey(0), AdamWConfig())
    step = jax.jit(make_train_step(model, AdamWConfig()))
    rng = np.random.default_rng(0)

    # per-bucket executables: one compile per boundary
    compile_times = {}
    for s_len in BOUNDARIES:
        batch = {
            "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
            "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
        }
        t0 = time.perf_counter()
        jax.block_until_ready(step(state, batch))
        compile_times[s_len] = time.perf_counter() - t0
    total_compile = sum(compile_times.values())
    rows.append(Row("bucket_executables", len(BOUNDARIES), "count", "real",
                    f"compile {total_compile:.2f}s total"))

    # steady-state: batches routed to cached executables -> no recompiles
    t0 = time.perf_counter()
    steps = 0
    for _ in range(12):
        s_len = int(rng.choice(BOUNDARIES))
        batch = {
            "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
            "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
        }
        jax.block_until_ready(step(state, batch))
        steps += 1
    steady = (time.perf_counter() - t0) / steps
    rows.append(Row("steady_step_time", steady, "s", "real",
                    "bucketed shapes hit the executable cache"))

    # the naive alternative: unbucketed dynamic lengths -> compile per shape
    novel = [33, 47, 61, 75, 89, 101]
    t0 = time.perf_counter()
    for s_len in novel:
        batch = {
            "tokens": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
            "labels": jnp.asarray(rng.integers(1, cfg.vocab_size, (2, s_len))),
        }
        jax.block_until_ready(step(state, batch))
    per_novel = (time.perf_counter() - t0) / len(novel)
    rows.append(Row("unbucketed_step_time", per_novel, "s", "real",
                    f"every novel length recompiles ({per_novel/steady:.0f}x steady)"))
    print_rows(rows, "per-bucket compiled executables (TPU adaptation of §3.6)")
    return rows


if __name__ == "__main__":
    main()
