"""Data-plane throughput: elements/sec across transports × batch × codecs.

Measures the client↔worker element fetch path end-to-end through a real
deployment (dispatcher + 2 workers), comparing three data-plane shapes:

  single    — one element per RPC, one outstanding request (the seed v1
              ``get_element`` path, forced via ``prefer_batched=False``).
  batched   — ``get_elements`` draining up to ``max_batch`` per RPC,
              one outstanding request.
  pipelined — batched + a window of outstanding requests per task, each
              on its own connection.

Production is made deliberately cheap (pre-generated payloads) so the
numbers isolate the data plane — RPC framing, serialization, compression —
rather than worker compute.  All rows are tier ``real``.

Run:  PYTHONPATH=src python benchmarks/data_plane.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Optional

import numpy as np

sys.path.insert(0, "src")

from repro.core import available_codecs, start_service  # noqa: E402
from repro.data import Dataset  # noqa: E402

try:
    from .common import Row, print_rows  # running under benchmarks.run
except ImportError:
    from common import Row, print_rows  # noqa: E402  (direct script run)

# ~32 KiB of incompressible-ish payload per element, pre-generated so the
# map fn costs ~nothing (isolates transfer from production).
_PAYLOADS = np.random.default_rng(0).standard_normal((8, 64, 64)).astype(np.float32)


def _payload(i):
    return _PAYLOADS[int(i) % len(_PAYLOADS)]


def measure(
    transport: str,
    codec: Optional[str],
    fetch_window: int,
    max_batch: int,
    prefer_batched: bool,
    n_elements: int,
) -> float:
    """Steady-state elements/sec consuming ``n_elements`` per worker.

    The clock starts at the FIRST consumed element: job/task rollout (worker
    heartbeat delivery, producer thread spin-up) is a fixed ~0.3 s ramp that
    would otherwise swamp the per-element numbers at bench sizes.
    """
    svc = start_service(
        num_workers=2, transport=transport, worker_buffer_size=128
    )
    try:
        # OFF policy: every worker serves the full range — pure data-plane
        # load with no shard hand-out chatter on the timed path.
        ds = Dataset.range(n_elements).map(_payload)
        dds = ds.distribute(
            service=svc,
            processing_mode="off",
            compression=codec,
            buffer_size=128,
            fetch_window=fetch_window,
            max_batch=max_batch,
            prefer_batched=prefer_batched,
        )
        sess = dds.session()
        it = iter(sess)
        next(it)  # ramp: job rollout + first production
        t0 = time.perf_counter()
        n = sum(1 for _ in it)
        dt = time.perf_counter() - t0
        expect = n_elements * 2 - 1  # off: full dataset per worker
        assert n == expect, f"consumed {n}, expected {expect}"
        return n / dt
    finally:
        svc.orchestrator.stop()


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer elements")
    ap.add_argument("--transports", default="inproc,tcp")
    args, _ = ap.parse_known_args()
    # --quick still needs enough elements that the ~1k-eps single-element
    # baseline runs ≥1 s per cell; shorter and scheduler noise dominates.
    n = 512 if args.quick else 1024

    shapes = [
        ("single", dict(fetch_window=1, max_batch=1, prefer_batched=False)),
        ("batched", dict(fetch_window=1, max_batch=16, prefer_batched=True)),
        ("pipelined", dict(fetch_window=2, max_batch=32, prefer_batched=True)),
    ]
    codecs = [c if c != "none" else None for c in available_codecs()]

    rows: List[Row] = []
    baseline: dict = {}
    for transport in args.transports.split(","):
        for codec in codecs:
            for shape_name, kw in shapes:
                eps = measure(transport, codec, n_elements=n, **kw)
                cname = codec or "none"
                rows.append(
                    Row(
                        name=f"data_plane/{transport}/{cname}/{shape_name}",
                        value=eps,
                        unit="elements/s",
                        tier="real",
                        detail=f"window={kw['fetch_window']} max_batch={kw['max_batch']}",
                    )
                )
                if shape_name == "single":
                    baseline[(transport, cname)] = eps
                else:
                    base = baseline[(transport, cname)]
                    rows.append(
                        Row(
                            name=f"data_plane/{transport}/{cname}/{shape_name}_speedup",
                            value=eps / base,
                            unit="x_vs_single",
                            tier="real",
                            detail="ratio to seed single-element path",
                        )
                    )
    print_rows(rows, "data plane: elements/sec by transport x codec x shape")
    return rows


if __name__ == "__main__":
    main()
