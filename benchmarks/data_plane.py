"""Data-plane throughput: transports × batch × codecs, shm vs tcp, procs.

Measures the client↔worker element fetch path end-to-end through a real
deployment (dispatcher + workers), in three sections:

1. **Shapes** (dispatcher + 2 workers): the fetch-path evolution —

  single    — one element per RPC, one outstanding request (the seed v1
              ``get_element`` path, forced via ``prefer_batched=False``).
  batched   — ``get_elements`` draining up to ``max_batch`` per RPC,
              one outstanding request.
  pipelined — batched + a window of outstanding requests per task, each
              on its own connection.

2. **shm vs tcp** (co-located worker, 8 MB batches): the same session
   consuming large uncompressed batches through the ``shm://`` ring
   (zero-copy borrow) versus the identical job forced onto the inline
   tcp-loopback payload path (``shm=False``).  Reported in MB/s.

3. **Process scaling** (DYNAMIC job, 1 worker): pipeline execution fanned
   across ``worker_processes`` = 1/2/4 pool children, over a map stage
   dominated by blocking simulated I/O (``time.sleep`` per element — the
   GIL-free wait stands in for storage/decode stalls; the box has a
   single core, so a pure-CPU workload could not scale here and the
   detail field says so).

Production in section 1 is made deliberately cheap (pre-generated
payloads) so the numbers isolate the data plane — RPC framing,
serialization, compression — rather than worker compute.  All rows are
tier ``real``.

Run:  PYTHONPATH=src python benchmarks/data_plane.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import sys
import time
from typing import List, Optional

import numpy as np

sys.path.insert(0, "src")

from repro.core import available_codecs, start_service  # noqa: E402
from repro.data import Dataset  # noqa: E402

try:
    from .common import Row, print_rows  # running under benchmarks.run
except ImportError:
    from common import Row, print_rows  # noqa: E402  (direct script run)

# ~32 KiB of incompressible-ish payload per element, pre-generated so the
# map fn costs ~nothing (isolates transfer from production).
_PAYLOADS = np.random.default_rng(0).standard_normal((8, 64, 64)).astype(np.float32)


def _payload(i):
    return _PAYLOADS[int(i) % len(_PAYLOADS)]


def measure(
    transport: str,
    codec: Optional[str],
    fetch_window: int,
    max_batch: int,
    prefer_batched: bool,
    n_elements: int,
) -> float:
    """Steady-state elements/sec consuming ``n_elements`` per worker.

    The clock starts at the FIRST consumed element: job/task rollout (worker
    heartbeat delivery, producer thread spin-up) is a fixed ~0.3 s ramp that
    would otherwise swamp the per-element numbers at bench sizes.
    """
    svc = start_service(
        num_workers=2, transport=transport, worker_buffer_size=128
    )
    try:
        # OFF policy: every worker serves the full range — pure data-plane
        # load with no shard hand-out chatter on the timed path.
        ds = Dataset.range(n_elements).map(_payload)
        dds = ds.distribute(
            service=svc,
            processing_mode="off",
            compression=codec,
            buffer_size=128,
            fetch_window=fetch_window,
            max_batch=max_batch,
            prefer_batched=prefer_batched,
        )
        sess = dds.session()
        it = iter(sess)
        next(it)  # ramp: job rollout + first production
        t0 = time.perf_counter()
        n = sum(1 for _ in it)
        dt = time.perf_counter() - t0
        expect = n_elements * 2 - 1  # off: full dataset per worker
        assert n == expect, f"consumed {n}, expected {expect}"
        return n / dt
    finally:
        svc.orchestrator.stop()


# ---------------------------------------------------------------------------
# Section 2: shm ring vs tcp loopback at 8 MB batches
# ---------------------------------------------------------------------------
_BIG = np.random.default_rng(1).standard_normal((2 * 1024 * 1024,)).astype(
    np.float32
)  # 8 MiB per element


def _big_payload(i):
    return _BIG


def measure_big_batches(use_shm: bool, n_elements: int) -> float:
    """MB/s consuming ``n_elements`` 8 MiB batches from a co-located worker.

    Timed span is first→last ELEMENT arrival: end-of-stream detection
    (the client polling every task until the dispatcher reports the job
    done) costs a few hundred ms regardless of transport, and at bench
    sizes it would swamp the per-byte numbers both rows exist to compare.
    """
    svc = start_service(num_workers=1, transport="tcp", worker_buffer_size=8)
    try:
        ds = Dataset.range(n_elements).map(_big_payload)
        dds = ds.distribute(
            service=svc,
            processing_mode="off",
            compression=None,
            buffer_size=4,
            fetch_window=1,
            max_batch=1,  # one 8 MB element per response frame
        )
        sess = dds.session(shm=use_shm, zero_copy=use_shm)
        sink, n = 0.0, 0
        t0 = t_last = 0.0
        for e in sess:
            t_last = time.perf_counter()
            if n == 0:
                t0 = t_last  # ramp: rollout + negotiation + first frame
            sink += float(e[0])  # touch the (possibly borrowed) buffer
            n += 1
        dt = t_last - t0
        assert n == n_elements and np.isfinite(sink)
        if use_shm:
            assert sess.metrics.shm_batches > 0, "shm never negotiated"
        else:
            assert sess.metrics.shm_batches == 0
        return (n - 1) * _BIG.nbytes / dt / 1e6
    finally:
        svc.orchestrator.stop()


# ---------------------------------------------------------------------------
# Section 3: executor-process scaling on a blocking pipeline
# ---------------------------------------------------------------------------
_SLEEP_S = 0.01  # simulated per-element I/O stall (GIL-free blocking wait)


def _slow_payload(i):
    time.sleep(_SLEEP_S)
    return _PAYLOADS[int(i) % len(_PAYLOADS)]


def measure_proc_scaling(processes: int, n_elements: int) -> float:
    """Elements/s through one worker running ``processes`` pool children
    over a DYNAMIC job whose map stage blocks ``_SLEEP_S`` per element."""
    svc = start_service(
        num_workers=1, transport="tcp", worker_processes=processes,
        worker_buffer_size=64,
    )
    try:
        ds = Dataset.range(n_elements).map(_slow_payload)
        dds = ds.distribute(
            service=svc, processing_mode="dynamic", buffer_size=64,
            max_batch=16,
        )
        sess = dds.session()
        it = iter(sess)
        next(it)  # ramp: rollout + child fork + first production
        t0 = time.perf_counter()
        n = 1 + sum(1 for _ in it)
        dt = time.perf_counter() - t0
        assert n == n_elements, f"consumed {n}, expected {n_elements}"
        return (n - 1) / dt
    finally:
        svc.orchestrator.stop()


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer elements")
    ap.add_argument("--transports", default="inproc,tcp")
    args, _ = ap.parse_known_args()
    # --quick still needs enough elements that the ~1k-eps single-element
    # baseline runs ≥1 s per cell; shorter and scheduler noise dominates.
    n = 512 if args.quick else 1024

    shapes = [
        ("single", dict(fetch_window=1, max_batch=1, prefer_batched=False)),
        ("batched", dict(fetch_window=1, max_batch=16, prefer_batched=True)),
        ("pipelined", dict(fetch_window=2, max_batch=32, prefer_batched=True)),
    ]
    codecs = [c if c != "none" else None for c in available_codecs()]

    rows: List[Row] = []
    baseline: dict = {}
    for transport in args.transports.split(","):
        for codec in codecs:
            for shape_name, kw in shapes:
                eps = measure(transport, codec, n_elements=n, **kw)
                cname = codec or "none"
                rows.append(
                    Row(
                        name=f"data_plane/{transport}/{cname}/{shape_name}",
                        value=eps,
                        unit="elements/s",
                        tier="real",
                        detail=f"window={kw['fetch_window']} max_batch={kw['max_batch']}",
                    )
                )
                if shape_name == "single":
                    baseline[(transport, cname)] = eps
                else:
                    base = baseline[(transport, cname)]
                    rows.append(
                        Row(
                            name=f"data_plane/{transport}/{cname}/{shape_name}_speedup",
                            value=eps / base,
                            unit="x_vs_single",
                            tier="real",
                            detail="ratio to seed single-element path",
                        )
                    )
    # -- section 2: shm vs tcp at 8 MB batches ------------------------------
    # Median of 3 runs per row: a single run occasionally catches a
    # scheduler stall on the shm side (observed ~25% dips).
    n_big = 12 if args.quick else 40
    reps = 1 if args.quick else 3
    med = lambda xs: sorted(xs)[len(xs) // 2]  # noqa: E731
    tcp_mbs = med(
        [measure_big_batches(use_shm=False, n_elements=n_big) for _ in range(reps)]
    )
    shm_mbs = med(
        [measure_big_batches(use_shm=True, n_elements=n_big) for _ in range(reps)]
    )
    rows.append(
        Row(
            name="data_plane/tcp/8MB_batches", value=tcp_mbs, unit="MB/s",
            tier="real", detail="inline tcp loopback, shm=False, max_batch=1",
        )
    )
    rows.append(
        Row(
            name="data_plane/shm/8MB_batches", value=shm_mbs, unit="MB/s",
            tier="real",
            detail="shm:// ring, zero_copy borrow, co-located worker",
        )
    )
    rows.append(
        Row(
            name="data_plane/shm_vs_tcp_speedup", value=shm_mbs / tcp_mbs,
            unit="x_vs_tcp", tier="real",
            detail="shm ring vs inline tcp loopback at 8MB batches",
        )
    )

    # -- section 3: executor-process scaling --------------------------------
    n_slow = 96 if args.quick else 240
    eps_by_procs = {}
    for procs in (1, 2, 4):
        eps = measure_proc_scaling(procs, n_slow)
        eps_by_procs[procs] = eps
        rows.append(
            Row(
                name=f"data_plane/procs/{procs}", value=eps, unit="elements/s",
                tier="real",
                detail=(
                    f"DYNAMIC, worker_processes={procs}, "
                    f"{_SLEEP_S*1e3:.0f}ms blocking I/O per element "
                    f"({os.cpu_count()}-core box: scaling shown on I/O wait, "
                    "not CPU)"
                ),
            )
        )
    rows.append(
        Row(
            name="data_plane/proc_scaling_4v1",
            value=eps_by_procs[4] / eps_by_procs[1],
            unit="x_vs_1proc", tier="real",
            detail="4 executor processes vs 1, same blocking pipeline",
        )
    )

    print_rows(rows, "data plane: elements/sec by transport x codec x shape")
    return rows


if __name__ == "__main__":
    main()
