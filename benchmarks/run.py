"""Benchmark driver: one module per paper figure/table.  Prints each
suite's ``name,value,unit,tier,detail`` CSV and a final summary of the
paper's headline claims vs our measured/simulated reproduction."""
from __future__ import annotations

import sys
import time
import traceback


SUITES = (
    ("Fig8_horizontal_scaleout", "benchmarks.horizontal_scaleout"),
    ("Fig9_worker_sweep", "benchmarks.worker_sweep"),
    ("Fig10_ephemeral_sharing", "benchmarks.ephemeral_sharing"),
    ("Fig11_coordinated_reads", "benchmarks.coordinated_reads"),
    ("S33_visitation", "benchmarks.visitation"),
    ("S42_cross_region", "benchmarks.cross_region"),
    ("TPU_bucket_compile", "benchmarks.bucket_compile"),
    ("DataPlane_throughput", "benchmarks.data_plane"),
    ("Pallas_kernels", "benchmarks.kernels"),
    ("Snapshot_materialization", "benchmarks.snapshot"),
)


def main() -> None:
    import importlib

    all_rows = {}
    failed = []
    for name, mod_name in SUITES:
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        t0 = time.time()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.main()
            all_rows[name] = {r.name: r for r in rows or ()}
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"[{name}: {time.time()-t0:.1f}s]", flush=True)

    print(f"\n{'='*72}\n== SUMMARY: paper headline claims vs this reproduction\n{'='*72}")

    def get(suite, key):
        r = all_rows.get(suite, {}).get(key)
        return f"{r.value:.2f} ({r.tier})" if r else "n/a"

    claims = (
        ("Fig8 avg speedup (input-bound jobs)", "31.7x",
         get("Fig8_horizontal_scaleout", "speedup_avg")),
        ("Fig8 avg cost saving", "26.2x",
         get("Fig8_horizontal_scaleout", "cost_saving_avg")),
        ("Fig9 M1 speedup @512 workers", "12.3x",
         get("Fig9_worker_sweep", "sim_speedup_512w")),
        ("Fig10 sharing holds cost flat (mode A, k=16)", "1x",
         get("Fig10_ephemeral_sharing", "sim_cost_modeA_k16")),
        ("Fig11 avg NLP speedup (coordinated reads)", "2.2x",
         get("Fig11_coordinated_reads", "sim_speedup_avg")),
        ("§3.4 at-most-once under worker kill", "holds",
         get("S33_visitation", "visitation_dynamic_kill")),
    )
    w = max(len(c[0]) for c in claims) + 2
    print(f"{'claim':{w}s} {'paper':>8s}  {'ours':>16s}")
    for c, p, o in claims:
        print(f"{c:{w}s} {p:>8s}  {o:>16s}")
    if failed:
        print(f"\nFAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
