"""Benchmark driver: one module per paper figure/table.  Prints each
suite's ``name,value,unit,tier,detail`` CSV, writes a machine-readable
``BENCH_<suite>.json`` per suite (suite, rows, timestamp — the perf
trajectory across PRs), and ends with a summary of the paper's headline
claims vs our measured/simulated reproduction."""
from __future__ import annotations

import argparse
import sys
import time
import traceback

try:
    from .common import write_bench_json
except ImportError:
    from common import write_bench_json


SUITES = (
    ("Fig8_horizontal_scaleout", "benchmarks.horizontal_scaleout"),
    ("Fig9_worker_sweep", "benchmarks.worker_sweep"),
    ("Fig10_ephemeral_sharing", "benchmarks.ephemeral_sharing"),
    ("Fig11_coordinated_reads", "benchmarks.coordinated_reads"),
    ("S33_visitation", "benchmarks.visitation"),
    ("S42_cross_region", "benchmarks.cross_region"),
    ("TPU_bucket_compile", "benchmarks.bucket_compile"),
    ("data_plane", "benchmarks.data_plane"),
    ("Pallas_kernels", "benchmarks.kernels"),
    ("Snapshot_materialization", "benchmarks.snapshot"),
    ("feed", "benchmarks.feed"),
    ("multi_job", "benchmarks.multi_job"),
    ("ha", "benchmarks.ha"),
    ("obs", "benchmarks.obs"),
)


def main() -> None:
    import importlib

    ap = argparse.ArgumentParser()
    ap.add_argument(
        "--timestamp",
        default="",
        help="label stamped into every BENCH_<suite>.json (default: now)",
    )
    ap.add_argument("--out", default=".", help="BENCH_*.json directory")
    ap.add_argument(
        "--only", default="", help="comma-separated suite-name filter"
    )
    args, _ = ap.parse_known_args()
    only = {s for s in args.only.split(",") if s}
    timestamp = args.timestamp or time.strftime("%Y-%m-%dT%H:%M:%S")

    all_rows = {}
    failed = []
    for name, mod_name in SUITES:
        if only and name not in only:
            continue
        print(f"\n{'='*72}\n== {name}\n{'='*72}", flush=True)
        t0 = time.perf_counter()
        try:
            mod = importlib.import_module(mod_name)
            rows = mod.main()
            all_rows[name] = {r.name: r for r in rows or ()}
            write_bench_json(name, rows or [], out_dir=args.out, timestamp=timestamp)
        except Exception:
            traceback.print_exc()
            failed.append(name)
        print(f"[{name}: {time.perf_counter()-t0:.1f}s]", flush=True)

    print(f"\n{'='*72}\n== SUMMARY: paper headline claims vs this reproduction\n{'='*72}")

    def get(suite, key):
        r = all_rows.get(suite, {}).get(key)
        return f"{r.value:.2f} ({r.tier})" if r else "n/a"

    claims = (
        ("Fig8 avg speedup (input-bound jobs)", "31.7x",
         get("Fig8_horizontal_scaleout", "speedup_avg")),
        ("Fig8 avg cost saving", "26.2x",
         get("Fig8_horizontal_scaleout", "cost_saving_avg")),
        ("Fig9 M1 speedup @512 workers", "12.3x",
         get("Fig9_worker_sweep", "sim_speedup_512w")),
        ("Fig10 sharing holds cost flat (mode A, k=16)", "1x",
         get("Fig10_ephemeral_sharing", "sim_cost_modeA_k16")),
        ("Fig11 avg NLP speedup (coordinated reads)", "2.2x",
         get("Fig11_coordinated_reads", "sim_speedup_avg")),
        ("§3.4 at-most-once under worker kill", "holds",
         get("S33_visitation", "visitation_dynamic_kill")),
        ("feed keeps accelerators fed (steps/s vs sync)", ">1x",
         get("feed", "feed/speedup")),
        ("§3 fleet scheduler right-sizes per job (agg. vs all-on-all)", ">=1x",
         get("multi_job", "multi_job/aggregate_ratio")),
        ("§3.4 dispatcher failover downtime (s, hot standby)", "~lease",
         get("ha", "ha/failover_downtime_s")),
    )
    w = max(len(c[0]) for c in claims) + 2
    print(f"{'claim':{w}s} {'paper':>8s}  {'ours':>16s}")
    for c, p, o in claims:
        print(f"{c:{w}s} {p:>8s}  {o:>16s}")
    if failed:
        print(f"\nFAILED suites: {failed}")
        sys.exit(1)


if __name__ == "__main__":
    main()
