"""Paper §4.2 "Cross-region": source data in a remote region adds fetch
latency; extra workers hide it.

Real tier: measured per-element fetch cost with injected latency (a sleep
in the source — the honest stand-in for a cross-continent read) at small
scale through the real service, 1 vs 4 workers.  Sim tier: the paper's M3
anchor — colocated-with-remote-data 13.3x slower than ideal; scale-out
recovers the ideal rate by overlapping fetch latency.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import start_service
from repro.data import Dataset

from .common import Row, SimParams, print_rows, simulate_throughput

FETCH_LAT = 0.02  # 20 ms injected "cross-region" latency per element


def slow_fetch(i):
    time.sleep(FETCH_LAT)
    return np.int64(i)


def real_latency_hiding() -> List[Row]:
    rows: List[Row] = []
    base = Dataset.range(24).map(slow_fetch).batch(4)
    for w in (1, 4):
        svc = start_service(num_workers=w, worker_buffer_size=16)
        try:
            dds = base.distribute(service=svc, processing_mode="dynamic")
            t0 = time.perf_counter()
            n = sum(1 for _ in dds)
            dt = time.perf_counter() - t0
        finally:
            svc.orchestrator.stop()
        rows.append(Row(f"real_xregion_throughput_{w}w", n / dt, "batches/s",
                        "real", f"{FETCH_LAT*1e3:.0f}ms/element injected latency"))
    return rows


def sim_m3_out_of_region() -> List[Row]:
    rows: List[Row] = []
    # M3 anchors: ideal 64.4 b/s; out-of-region colocated is 13.3x slower
    # than ideal (vs 2.9x in-region) — fetch latency dominates batch cost.
    ideal = 64.4
    colo_out = ideal / 13.3
    p = SimParams(step_time_s=1 / ideal, batch_cost_s=1 / colo_out,
                  rpc_overhead_s=0.3e-3, local_cores=1)
    got = simulate_throughput(p, num_workers=256)["batches_per_s"]
    rows.append(Row("sim_xregion_colocated_slowdown", 13.3, "x", "sim",
                    "paper-anchored: out-of-region vs ideal"))
    rows.append(Row("sim_xregion_scaleout_recovery", got / ideal, "frac", "sim",
                    "256 workers hide cross-region fetch latency (paper: reaches ideal)"))
    return rows


def main() -> List[Row]:
    rows = real_latency_hiding() + sim_m3_out_of_region()
    print_rows(rows, "§4.2 cross-region: latency hiding by scale-out")
    return rows


if __name__ == "__main__":
    main()
