"""Multi-tenant fleet scheduling: per-job steps/s with the scheduler on vs off.

Two concurrent jobs with ~4-5x asymmetric per-batch preprocessing cost
share one fixed fleet, each driven by a paced consumer (one batch per
``PACE_S`` — the stand-in training step) that reports its stall fraction
the way ``repro.feed`` does:

  unscheduled — the seed behavior: every job gets a task on EVERY worker.
  scheduled   — ``scheduling=True``: the dispatcher computes demand-driven
                weighted max-min fair worker shares per job and
                grants/retires tasks to realize them (driven here by a
                two-level Autoscaler with a pinned pool size).

On this container the workload is sleep-bound (no CPU contention between
runner threads), so the honest expectation is throughput PARITY — both
arms hold both consumers at pace — while the scheduler serves the same
load from an unequal, right-sized allocation (the heavy job ends with
2-3x the light job's workers) instead of 2x tasks on every worker.  In a
real deployment the freed workers are released capacity (scale-in /
other tenants); the per-worker CPU/RAM right-sizing is the paper's §3
claim, which this benchmark demonstrates structurally (shares, task
counts) and guards on throughput (aggregate ratio vs the unscheduled
baseline must not regress).

Run:  PYTHONPATH=src python benchmarks/multi_job.py [--quick]
Emits BENCH_multi_job.json (machine-readable trajectory).
"""
from __future__ import annotations

import argparse
import sys
import threading
import time
from typing import Dict, List

sys.path.insert(0, "src")

from repro.core import Autoscaler, AutoscalerConfig, start_service  # noqa: E402
from repro.data import Dataset  # noqa: E402

try:
    from .common import Row, print_rows, write_bench_json
except ImportError:
    from common import Row, print_rows, write_bench_json  # noqa: E402

BATCH = 2  # elements per batch
PACE_S = 0.04  # consumer step time (one batch per step)


def _slow(x, t=0.0):
    time.sleep(t)
    return x


def _pipeline(elem_cost_s: float) -> Dataset:
    return (
        Dataset.range(1_000_000)
        .map(_slow, t=elem_cost_s)
        .batch(BATCH)
        .repeat()
    )


def _consume(session, stop: threading.Event, out: Dict[str, float]) -> None:
    """Paced consumer reporting its stall window (repro.feed's signal)."""
    it = iter(session)
    win_t0 = time.perf_counter()
    win_stall = 0.0
    while not stop.is_set():
        t0 = time.perf_counter()
        try:
            next(it)
        except StopIteration:
            break
        win_stall += time.perf_counter() - t0
        out["steps"] += 1
        now = time.perf_counter()
        if now - win_t0 >= 0.25:
            session.report_feed_stall(
                {"stall_frac": min(1.0, win_stall / (now - win_t0))}
            )
            win_t0, win_stall = now, 0.0
        time.sleep(PACE_S)


def _run_arm(
    scheduled: bool,
    workers: int,
    heavy_cost: float,
    light_cost: float,
    converge_s: float,
    measure_s: float,
) -> Dict[str, float]:
    svc = start_service(
        num_workers=workers, scheduling=scheduled, worker_buffer_size=2
    )
    stop = threading.Event()
    counters = {"heavy": {"steps": 0}, "light": {"steps": 0}}
    sessions, threads = [], []
    scaler = None
    try:
        for name, cost, weight in (
            ("heavy", heavy_cost, 3.0),
            ("light", light_cost, 1.0),
        ):
            dds = _pipeline(cost).distribute(
                service=svc,
                processing_mode="dynamic",
                job_name=name,
                weight=weight,
            )
            session = dds.session(heartbeat_interval=0.1, buffer_size=4)
            sessions.append(session)
            th = threading.Thread(
                target=_consume, args=(session, stop, counters[name]), daemon=True
            )
            th.start()
            threads.append(th)
        if scheduled:
            # two-level autoscaler, pool pinned: every step rebalances
            # per-job shares; the fleet itself cannot move (A/B fairness:
            # both arms use exactly `workers` workers)
            scaler = Autoscaler(
                svc.orchestrator,
                AutoscalerConfig(
                    min_workers=workers,
                    max_workers=workers,
                    interval_s=0.15,
                    cooldown_s=0.0,
                ),
            ).start()
        time.sleep(converge_s)
        if scaler is not None:
            # freeze the converged allocation for a clean measurement
            scaler.stop()
        start = {k: dict(v) for k, v in counters.items()}
        time.sleep(measure_s)
        jobs = {
            j["name"]: j for j in svc.orchestrator.stats()["jobs"].values()
        }
        out = {
            "heavy_steps_per_s": (counters["heavy"]["steps"] - start["heavy"]["steps"]) / measure_s,
            "light_steps_per_s": (counters["light"]["steps"] - start["light"]["steps"]) / measure_s,
            "heavy_workers": jobs["heavy"]["active_tasks"],
            "light_workers": jobs["light"]["active_tasks"],
        }
        out["aggregate_steps_per_s"] = (
            out["heavy_steps_per_s"] + out["light_steps_per_s"]
        )
        return out
    finally:
        stop.set()
        if scaler is not None:
            scaler.stop()
        for s in sessions:
            s.close()
        for th in threads:
            th.join(timeout=5.0)
        svc.orchestrator.stop()


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller fleet, shorter windows")
    ap.add_argument("--out", default=".", help="BENCH_multi_job.json directory")
    args, _ = ap.parse_known_args()
    # converge windows sit INSIDE the scheduler's shrink-patience window:
    # the measured allocation is the weighted max-min trim (right-sized,
    # meeting pace), before patient demand-shrink walks it to the stall
    # boundary — the honest steady state for an A/B against a pace-bound
    # baseline
    if args.quick:
        workers, converge_s, measure_s = 4, 2.5, 3.0
        heavy_cost, light_cost = 0.045, 0.01  # needs ~2.3 vs ~0.5 workers
    else:
        workers, converge_s, measure_s = 8, 2.5, 5.0
        heavy_cost, light_cost = 0.08, 0.02  # needs ~4 vs ~1 workers

    base = _run_arm(False, workers, heavy_cost, light_cost, converge_s, measure_s)
    sched = _run_arm(True, workers, heavy_cost, light_cost, converge_s, measure_s)

    pace_bound = 1.0 / PACE_S
    ratio = sched["aggregate_steps_per_s"] / max(1e-9, base["aggregate_steps_per_s"])
    rows = [
        Row("multi_job/unscheduled/heavy_steps_per_s", base["heavy_steps_per_s"],
            "steps/s", "real", f"task on all {workers} workers; pace bound {pace_bound:.0f}/s"),
        Row("multi_job/unscheduled/light_steps_per_s", base["light_steps_per_s"],
            "steps/s", "real", f"task on all {workers} workers"),
        Row("multi_job/unscheduled/aggregate_steps_per_s", base["aggregate_steps_per_s"],
            "steps/s", "real", "both jobs on every worker (seed behavior)"),
        Row("multi_job/scheduled/heavy_steps_per_s", sched["heavy_steps_per_s"],
            "steps/s", "real", f"{sched['heavy_workers']} of {workers} workers allocated"),
        Row("multi_job/scheduled/light_steps_per_s", sched["light_steps_per_s"],
            "steps/s", "real", f"{sched['light_workers']} of {workers} workers allocated"),
        Row("multi_job/scheduled/aggregate_steps_per_s", sched["aggregate_steps_per_s"],
            "steps/s", "real", "weighted max-min fair shares"),
        Row("multi_job/scheduled/heavy_workers", sched["heavy_workers"], "workers",
            "real", "converged share (demand-driven)"),
        Row("multi_job/scheduled/light_workers", sched["light_workers"], "workers",
            "real", "converged share (demand-driven)"),
        Row("multi_job/aggregate_ratio", ratio, "x_vs_unscheduled", "real",
            "sleep-bound container: parity expected; the win is the "
            "right-sized allocation (freed capacity), not throughput"),
    ]
    print_rows(rows, "multi-tenant fleet scheduling: scheduler on vs off")
    if __name__ == "__main__":
        write_bench_json("multi_job", rows, out_dir=args.out)
    return rows


if __name__ == "__main__":
    main()
