"""Paper Fig. 9 (a: job-time speedup, b: cost saving) — M1 across worker
pool sizes 8..640.

Real tier: a small-scale REAL sweep (1..4 workers on this machine) verifies
the simulator's shape: throughput rises with workers until the consumer
bound.  Sim tier: the paper's M1 sweep with Eq.-1 costs at v4 rates.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from repro.core import JobResources, cost_saving, start_service
from repro.data import Dataset

from .common import Row, SimParams, print_rows, simulate_throughput
from .horizontal_scaleout import V4_RATES


def real_small_scale_sweep() -> List[Row]:
    """1->4 workers on one machine: validates the sim's monotonicity (the
    absolute numbers are contention-bound on 1 core and labeled as such)."""
    rows: List[Row] = []

    def heavy(i):
        x = np.random.default_rng(int(i)).standard_normal((64, 64))
        for _ in range(4):
            x = np.tanh(x @ x.T) / 8.0
        return x

    base = Dataset.range(96).map(heavy).batch(8)
    for w in (1, 2, 4):
        svc = start_service(num_workers=w)
        try:
            dds = base.distribute(service=svc, processing_mode="dynamic")
            t0 = time.perf_counter()
            n = sum(1 for _ in dds)
            dt = time.perf_counter() - t0
        finally:
            svc.orchestrator.stop()
        rows.append(Row(f"real_throughput_{w}w", n / dt, "batches/s", "real",
                        "1-core machine: threads contend, shape not scale"))
    return rows


def sim_m1_sweep() -> List[Row]:
    rows: List[Row] = []
    # M1 anchors (paper): colocated 0.55 b/s, ideal 6.47 b/s, 32 accels.
    p = SimParams(
        step_time_s=1 / 6.47,
        batch_cost_s=1 / 0.55,
        rpc_overhead_s=0.3e-3 * 4,  # measured serialize+deserialize, ~4MB
        local_cores=1,
    )
    colo_bps = simulate_throughput(p, num_workers=0)["batches_per_s"]
    # Fitting the paper's own curve (0.55x@8w, 1.14x@16w, 4.1x@64w,
    # 8.6x@128w) shows per-worker efficiency is ~constant: every 8 workers
    # contribute ≈0.55x of a colocated host's preprocessing — RPC serving,
    # serialization and heartbeats eat a fixed ~45% of worker CPU at every
    # pool size.  One constant reproduces the whole ramp + ceiling.
    EFF = 0.55
    for w in (8, 16, 32, 64, 128, 256, 512, 640):
        pw = SimParams(
            step_time_s=p.step_time_s,
            batch_cost_s=p.batch_cost_s,
            rpc_overhead_s=p.rpc_overhead_s,
            worker_parallelism=EFF / 8,  # 8 paper-workers ≈ 0.55 colocated host
            local_cores=1,
        )
        got = simulate_throughput(pw, num_workers=w)["batches_per_s"]
        speedup = got / colo_bps
        colo_res = JobResources(duration_hours=1.0, num_trainers=4)
        dis = JobResources(
            duration_hours=1.0 / speedup, num_workers=w,
            worker_cpu_util_cores=6.0, worker_mem_util_gb=24.0, num_trainers=4,
        )
        saving = cost_saving(colo_res, dis, V4_RATES)
        rows.append(Row(f"sim_speedup_{w}w", speedup, "x", "sim",
                        "paper Fig9a: 0.55x@8w, 1.14x@16w, 4.1x@64w, 8.6x@128w, 12.3x@512w"))
        rows.append(Row(f"sim_cost_saving_{w}w", saving, "x", "sim",
                        "paper Fig9b: 11.4x@512w; dips at 640w"))
    return rows


def main() -> List[Row]:
    rows = real_small_scale_sweep() + sim_m1_sweep()
    print_rows(rows, "Fig9 worker-count sweep (M1)")
    return rows


if __name__ == "__main__":
    main()
