"""Observability overhead: data-plane throughput with tracing off vs on.

Tracing is sampling-gated (``trace_sample``): an unsampled fetch carries NO
extra payload field and takes no tracer locks on the hot path, so the
default-off and sampled configurations must stay within noise of each
other.  The headline row — ``obs/tracing_sampled_ratio`` — is the
acceptance gate: sampled tracing (5% of fetches) must hold ≥ 0.95x the
tracing-off elements/sec.  ``tracing_full`` (every fetch sampled) is
reported for scale but not gated; it is the worst case no production
deployment runs.

Also measured: one ``metrics_dump`` scrape round (dispatcher + workers,
what ``repro.obs.top`` pays per refresh) and one ``trace_dump`` drain.

Run:  PYTHONPATH=src python benchmarks/obs.py [--quick]
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List

import numpy as np

sys.path.insert(0, "src")

from repro.core import start_service  # noqa: E402
from repro.core.transport import Stub  # noqa: E402
from repro.data import Dataset  # noqa: E402

try:
    from .common import Row, print_rows  # running under benchmarks.run
except ImportError:
    from common import Row, print_rows  # noqa: E402  (direct script run)

_PAYLOADS = np.random.default_rng(0).standard_normal((8, 64, 64)).astype(np.float32)


def _payload(i):
    return _PAYLOADS[int(i) % len(_PAYLOADS)]


def measure(trace_sample: float, n_elements: int, reps: int) -> float:
    """Best-of-``reps`` steady-state elements/sec at one sample rate.

    Best-of (not mean) because the 1-core container's scheduler noise only
    ever subtracts throughput; the max is the least-noisy estimate of the
    code path's actual cost, which is what the on/off ratio gates.
    """
    best = 0.0
    for _ in range(reps):
        svc = start_service(num_workers=2, worker_buffer_size=128)
        try:
            dds = (
                Dataset.range(n_elements)
                .map(_payload)
                .distribute(
                    service=svc,
                    processing_mode="off",
                    buffer_size=128,
                    trace_sample=trace_sample,
                )
            )
            it = iter(dds.session())
            next(it)  # ramp: job rollout + first production
            t0 = time.perf_counter()
            n = sum(1 for _ in it)
            dt = time.perf_counter() - t0
            expect = n_elements * 2 - 1  # off policy: full dataset per worker
            assert n == expect, f"consumed {n}, expected {expect}"
            best = max(best, n / dt)
        finally:
            svc.orchestrator.stop()
    return best


def measure_scrape() -> tuple:
    """(metrics_dump round ms, trace_dump ms, spans drained) on a live job."""
    svc = start_service(num_workers=2, worker_buffer_size=64)
    try:
        dds = (
            Dataset.range(256)
            .map(_payload)
            .distribute(service=svc, processing_mode="off", trace_sample=1.0)
        )
        for _ in dds.session():
            pass
        stub = Stub(svc.dispatcher_address)
        t0 = time.perf_counter()
        dump = stub.call("metrics_dump")
        for addr in dump["workers"].values():
            Stub(addr).call("metrics_dump")
        dump_ms = (time.perf_counter() - t0) * 1e3
        t0 = time.perf_counter()
        spans = list(stub.call("trace_dump", max_spans=0)["spans"])
        for addr in dump["workers"].values():
            spans += Stub(addr).call("trace_dump", max_spans=0)["spans"]
        trace_ms = (time.perf_counter() - t0) * 1e3
        return dump_ms, trace_ms, len(spans)
    finally:
        svc.orchestrator.stop()


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer elements")
    args, _ = ap.parse_known_args()
    n = 512 if args.quick else 1024
    reps = 2 if args.quick else 3

    off = measure(0.0, n, reps)
    sampled = measure(0.05, n, reps)
    full = measure(1.0, n, reps)
    dump_ms, trace_ms, n_spans = measure_scrape()

    rows = [
        Row("obs/tracing_off", off, "elements/s", "real", "trace_sample=0"),
        Row("obs/tracing_sampled", sampled, "elements/s", "real", "trace_sample=0.05"),
        Row(
            "obs/tracing_sampled_ratio", sampled / off, "x_vs_off", "real",
            "acceptance gate: must be >= 0.95",
        ),
        Row("obs/tracing_full", full, "elements/s", "real", "trace_sample=1.0"),
        Row("obs/tracing_full_ratio", full / off, "x_vs_off", "real", "not gated"),
        Row(
            "obs/metrics_dump_round_ms", dump_ms, "ms", "real",
            "dispatcher + 2 workers, one dashboard refresh",
        ),
        Row(
            "obs/trace_dump_round_ms", trace_ms, "ms", "real",
            f"drained {n_spans} spans",
        ),
    ]
    print_rows(rows, "observability: tracing overhead + scrape cost")
    return rows


if __name__ == "__main__":
    main()
