"""Accelerator feed: steps/sec + idle time, synchronous loop vs DeviceFeeder.

A/B of the two ways a training loop can consume the data service:

  sync    — the seed pattern: ``next(it)`` then ``device_put`` on the
            step's critical path.  Every step pays fetch + host→device
            transfer + compute, serially.
  feeder  — ``repro.feed.DeviceFeeder``: fetch + transfer run on a
            background thread behind a depth-2 device queue, so the step
            pays max(compute, feed) instead of the sum.

Both arms share the same deployment, pipeline, transfer call, and jitted
compute, so the ratio isolates the pipelining.  The pipeline carries a
slow ``map`` stage (per-element sleep — a stand-in for real decode /
augmentation CPU cost) and ~8 MB batches so fetch latency and transfer
bandwidth are both visible on CPU, where a real accelerator's PCIe copy
would be.  Reported per arm: steps/s and accelerator-idle seconds per
step (time the consumer was blocked waiting for a device batch).

Run:  PYTHONPATH=src python benchmarks/feed.py [--quick]
Emits BENCH_feed.json next to the CSV output (machine-readable trajectory).
"""
from __future__ import annotations

import argparse
import sys
import time
from typing import List, Tuple

import numpy as np

sys.path.insert(0, "src")

from repro.core import start_service  # noqa: E402
from repro.data import Dataset  # noqa: E402

try:
    from .common import Row, print_rows, write_bench_json
except ImportError:
    from common import Row, print_rows, write_bench_json  # noqa: E402

BATCH = 4  # elements per batch
ELEM_SHAPE = (512, 1024)  # float32: 2 MB/element, 8 MB/batch
MAP_SLEEP_S = 0.0005  # the "slow" producer stage, per element

# pre-generated payload pool: the map stage's cost is the SLEEP, not RNG
_POOL = np.random.default_rng(0).standard_normal((8, *ELEM_SHAPE)).astype(np.float32)


def _slow_elem(i):
    time.sleep(MAP_SLEEP_S)
    return {"x": _POOL[int(i) % len(_POOL)]}


def _pipeline(n_batches: int) -> Dataset:
    # 2x headroom: DYNAMIC shard boundaries rarely align with the batch
    # size, so drop_remainder trims a tail batch per shard — the consumers
    # stop at n_batches and never notice
    return (
        Dataset.range(2 * n_batches * BATCH)
        .map(_slow_elem)
        .batch(BATCH, drop_remainder=True)
    )


def _make_step():
    """Jitted stand-in for a train step over the transferred batch."""
    import jax
    import jax.numpy as jnp

    w = jax.device_put(
        np.random.default_rng(1)
        .standard_normal((ELEM_SHAPE[1], 192))
        .astype(np.float32)
    )

    @jax.jit
    def step(batch):
        y = jnp.einsum("bsd,dk->bsk", batch["x"], w)
        return jnp.tanh(y).sum()

    return step


def measure_sync(steps: int, warmup: int) -> Tuple[float, float]:
    """(steps/s, idle_s_per_step) for the synchronous consume loop."""
    import jax

    step_fn = _make_step()
    svc = start_service(num_workers=4)
    try:
        dds = _pipeline(steps + warmup).distribute(
            service=svc, processing_mode="dynamic"
        )
        it = iter(dds)
        for _ in range(warmup):  # compile + service ramp outside the clock
            jax.block_until_ready(step_fn(jax.device_put(next(it))))
        t0 = time.perf_counter()
        idle = 0.0
        out = None
        for _ in range(steps):
            ti = time.perf_counter()
            batch = jax.device_put(next(it))  # fetch + transfer, serial
            idle += time.perf_counter() - ti
            out = step_fn(batch)
        jax.block_until_ready(out)
        wall = time.perf_counter() - t0
        return steps / wall, idle / steps
    finally:
        svc.orchestrator.stop()


def measure_feeder(steps: int, warmup: int) -> Tuple[float, float, dict]:
    """(steps/s, idle_s_per_step, breakdown) through the DeviceFeeder."""
    import jax

    from repro.feed import DeviceFeeder, StallWindow

    step_fn = _make_step()
    svc = start_service(num_workers=4)
    try:
        dds = _pipeline(steps + warmup).distribute(
            service=svc, processing_mode="dynamic"
        )
        with DeviceFeeder(dds, depth=2) as feeder:
            for _ in range(warmup):
                jax.block_until_ready(step_fn(feeder.next()))
            window = StallWindow(feeder.metrics)  # deltas over the timed region
            t0 = time.perf_counter()
            out = None
            for _ in range(steps):
                out = step_fn(feeder.next())
            jax.block_until_ready(out)
            wall = time.perf_counter() - t0
            w = window.report() or {"idle_s_per_step": 0.0}
            breakdown = feeder.metrics.breakdown()
        return steps / wall, float(w["idle_s_per_step"]), breakdown
    finally:
        svc.orchestrator.stop()


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer steps")
    ap.add_argument("--out", default=".", help="BENCH_feed.json directory")
    args, _ = ap.parse_known_args()
    steps = 40 if args.quick else 150
    warmup = 5 if args.quick else 10

    sync_sps, sync_idle = measure_sync(steps, warmup)
    feed_sps, feed_idle, breakdown = measure_feeder(steps, warmup)

    rows = [
        Row("feed/sync/steps_per_s", sync_sps, "steps/s", "real",
            f"next(it)+device_put inline, {steps} steps"),
        Row("feed/sync/idle_s_per_step", sync_idle, "s", "real",
            "fetch+transfer on the step's critical path"),
        Row("feed/feeder/steps_per_s", feed_sps, "steps/s", "real",
            "DeviceFeeder depth=2"),
        Row("feed/feeder/idle_s_per_step", feed_idle, "s", "real",
            "consumer blocked in next()"),
        Row("feed/speedup", feed_sps / sync_sps, "x_vs_sync", "real",
            f"breakdown fetch={breakdown['fetch']:.0%} "
            f"transfer={breakdown['transfer']:.0%} "
            f"compute={breakdown['compute']:.0%}"),
    ]
    print_rows(rows, "device feed: synchronous loop vs double-buffered feeder")
    if __name__ == "__main__":
        # standalone runs emit their own results file; under benchmarks.run
        # the driver writes BENCH_feed.json with the coordinated --timestamp
        write_bench_json("feed", rows, out_dir=args.out)
    return rows


if __name__ == "__main__":
    main()
