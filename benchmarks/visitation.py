"""Paper §3.3/§3.4 table: visitation guarantees per sharding policy,
measured by counting actual element visits through the real service,
with and without an injected worker failure."""
from __future__ import annotations

import collections
import time
from typing import List

import numpy as np

from repro.core import start_service
from repro.data import Dataset

from .common import Row, print_rows

N = 240


def visits(svc, mode, kill_at=None):
    ds = Dataset.range(N).batch(2).distribute(service=svc, processing_mode=mode)
    counts = collections.Counter()
    for i, b in enumerate(ds):
        for v in np.asarray(b).ravel().tolist():
            counts[int(v)] += 1
        if kill_at is not None and i == kill_at:
            svc.orchestrator.kill_worker(0)
    return counts


def main() -> List[Row]:
    rows: List[Row] = []
    for mode, kill, expect in (
        ("dynamic", None, "exactly-once"),
        ("dynamic", 5, "at-most-once"),
        ("static", None, "exactly-once"),
        ("off", None, "zero-once-or-more (per-worker full pass)"),
    ):
        svc = start_service(num_workers=3, heartbeat_timeout=0.6, gc_interval=0.1)
        try:
            c = visits(svc, mode, kill)
        finally:
            svc.orchestrator.stop()
        max_v = max(c.values()) if c else 0
        missing = N - len(c)
        dupes = sum(1 for v in c.values() if v > 1)
        if mode == "off":
            ok = max_v <= 3 and missing == 0  # ≤ one pass per worker
        elif kill is None:
            ok = dupes == 0 and missing == 0
        else:
            ok = dupes == 0  # at-most-once: no duplicates; loss allowed
        rows.append(Row(
            f"visitation_{mode}{'_kill' if kill else ''}",
            1.0 if ok else 0.0, "pass", "real",
            f"expect {expect}: missing={missing} dupes={dupes} max_visits={max_v}",
        ))
    print_rows(rows, "§3.3/3.4 visitation guarantees (measured)")
    return rows


if __name__ == "__main__":
    main()
