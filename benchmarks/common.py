"""Shared benchmark machinery.

This container has ONE CPU core and no real accelerators or network, so the
benchmarks are split into two honestly-labeled tiers:

  real — measured on this machine: per-batch preprocessing cost, RPC +
         serialization overhead, cache hit behavior, padding FLOPs.
  sim  — a discrete-event model of the paper's experiments (Fig. 8/9/10)
         parameterized BY the real measurements: a training step consumes
         one batch every ``step_time``; W workers each produce a batch
         every ``batch_cost / W-parallelism``; the client stalls when the
         buffer is empty.  The simulator is validated against the real
         service at small scale in test/bench cross-checks.

Every reported row carries its tier.
"""
from __future__ import annotations

import json
import os
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional

import numpy as np


@dataclass
class Row:
    name: str
    value: float
    unit: str
    tier: str  # real | sim
    detail: str = ""

    def csv(self) -> str:
        return f"{self.name},{self.value:.6g},{self.unit},{self.tier},{self.detail}"


def print_rows(rows: List[Row], header: str) -> None:
    print(f"\n# {header}")
    print("name,value,unit,tier,detail")
    for r in rows:
        print(r.csv())


def write_bench_json(
    suite: str,
    rows: List[Row],
    out_dir: str = ".",
    timestamp: Optional[str] = None,
) -> str:
    """Persist one suite's rows as ``BENCH_<suite>.json``.

    The machine-readable twin of the printed CSV: committed/archived per
    run so the perf trajectory is diffable across PRs.  ``timestamp`` is
    caller-supplied (the driver's ``--timestamp`` arg) so re-runs of the
    same code can be labeled identically.
    """
    payload = {
        "suite": suite,
        "timestamp": timestamp or time.strftime("%Y-%m-%dT%H:%M:%S"),
        "rows": [
            {
                "name": r.name,
                "value": r.value,
                "unit": r.unit,
                "tier": r.tier,
                "detail": r.detail,
            }
            for r in rows or ()
        ],
    }
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=1)
    print(f"[bench results -> {path}]")
    return path


def time_fn(fn: Callable, *args, repeat: int = 5, warmup: int = 1) -> float:
    """Median wall seconds of fn(*args)."""
    for _ in range(warmup):
        fn(*args)
    ts = []
    for _ in range(repeat):
        t0 = time.perf_counter()
        fn(*args)
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


# ---------------------------------------------------------------------------
# Discrete-event simulator of a disaggregated input-service deployment
# ---------------------------------------------------------------------------
@dataclass
class SimParams:
    step_time_s: float  # accelerator compute time per batch (model-bound floor)
    batch_cost_s: float  # CPU seconds to preprocess one batch (measured)
    rpc_overhead_s: float  # serialize+send+deserialize per batch (measured)
    worker_parallelism: int = 1  # useful cores per worker
    client_buffer: int = 8
    local_cores: int = 1  # colocated-mode preprocessing cores


def simulate_throughput(
    p: SimParams, num_workers: int, num_batches: int = 2000
) -> Dict[str, float]:
    """Steady-state batches/s for a job fed by ``num_workers`` remote workers.

    Event model: workers produce batches every batch_cost/(parallelism)
    seconds each into an unbounded service buffer; the client can ingest at
    most one batch per rpc_overhead (deserialization is client-side serial
    work); the accelerator consumes one batch per step_time.  Throughput is
    the min of the three service rates — queueing effects only matter at
    the crossover, which the discrete-event loop captures.
    """
    if num_workers == 0:  # colocated: local cores do the preprocessing
        produce_rate = p.local_cores / p.batch_cost_s
        ingest_rate = float("inf")  # no RPC hop
    else:
        produce_rate = num_workers * p.worker_parallelism / p.batch_cost_s
        ingest_rate = 1.0 / p.rpc_overhead_s if p.rpc_overhead_s > 0 else float("inf")
    consume_rate = 1.0 / p.step_time_s

    # discrete-event: next-production time per source vs consumption
    t = 0.0
    buf = 0.0
    produced = consumed = 0
    t_prod = 1.0 / produce_rate
    t_ing = 1.0 / ingest_rate if ingest_rate != float("inf") else 0.0
    stall = 0.0
    next_ready = 0.0
    while consumed < num_batches:
        # time when the next batch is available client-side
        next_batch = max(next_ready, (produced + 1) * t_prod) + t_ing
        produced += 1
        start = max(t, next_batch)
        stall += max(0.0, next_batch - t)
        t = start + p.step_time_s
        next_ready = next_batch
        consumed += 1
    wall = t
    return {
        "batches_per_s": num_batches / wall,
        "stall_frac": stall / wall,
        "ideal_batches_per_s": consume_rate,
    }
