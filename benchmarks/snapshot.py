"""Snapshot materialization: read-path vs compute-path throughput.

The economic claim behind materialization (Cachew; tf.data's `snapshot`;
§3.5's compute-vs-cache trade): once a CPU-bound pipeline's output is
persisted, later jobs read committed batches instead of re-running the
preprocessing.  This harness measures, through a REAL deployment
(dispatcher + 2 workers, inproc transport):

  compute   — job drains the CPU-bound vision pipeline (DYNAMIC sharding).
  write     — materializing the same pipeline to a snapshot (compute +
              chunk encode/compress/fsync: the one-time overhead).
  read      — a second job drains ``from_snapshot`` through the service
              (chunk-granularity DYNAMIC sharding).
  read_local— detached read straight off the shared FS (no service hop).

All rows are tier ``real``.  Target (ISSUE acceptance): read >= 2x compute
for a CPU-bound pipeline.

Run:  PYTHONPATH=src python benchmarks/snapshot.py [--quick]
"""
from __future__ import annotations

import argparse
import os
import shutil
import sys
import tempfile
import time
from typing import List

import numpy as np

sys.path.insert(0, "src")

try:
    from .common import Row, print_rows
except ImportError:  # direct script invocation
    from common import Row, print_rows

from repro.core import materialize, start_service  # noqa: E402
from repro.data import Dataset  # noqa: E402
from repro.data.pipelines import vision_pipeline  # noqa: E402
from repro.snapshot import iterate_snapshot, snapshot_status  # noqa: E402


def _drain(iterable) -> int:
    return sum(1 for _ in iterable)


def _timed_drain(dds):
    """(batches, seconds) with the clock starting at the FIRST element —
    job rollout (~0.3 s of heartbeat task delivery) would otherwise swamp
    small reads (same convention as benchmarks/data_plane.py)."""
    it = iter(dds)
    next(it)
    t0 = time.perf_counter()
    n = 1 + sum(1 for _ in it)
    return n, time.perf_counter() - t0


def main() -> List[Row]:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="smaller pipeline")
    args, _ = ap.parse_known_args()
    n = 128 if args.quick else 384
    work = 1 if args.quick else 2
    pipe = vision_pipeline(
        num_elements=n, batch_size=8, image_size=48, crop=40,
        work_factor=work, parallelism=0, shuffle_buffer=64,
    )
    expected_batches = n // 8

    tmp = tempfile.mkdtemp(prefix="repro-snap-bench-")
    snap = os.path.join(tmp, "snap")
    rows: List[Row] = []
    svc = start_service(num_workers=2, worker_buffer_size=64)
    try:
        # -- compute path ---------------------------------------------------
        got, compute_s = _timed_drain(
            pipe.distribute(service=svc, processing_mode="dynamic")
        )
        compute_eps = got * 8 / compute_s
        rows.append(Row("snapshot/compute_path", compute_eps, "elements/s",
                        "real", f"{got} batches, work_factor={work}"))

        # -- write (one-time materialization cost) --------------------------
        t0 = time.perf_counter()
        st = materialize(svc, pipe, snap, timeout=600)
        write_s = time.perf_counter() - t0
        assert st["finished"], st
        n_batches = st and sum(s["elements"] for s in st["streams"])
        rows.append(Row("snapshot/write_path", n_batches * 8 / write_s,
                        "elements/s", "real",
                        f"{n_batches} batches, {snapshot_status(snap)['bytes']} B"))

        # -- read paths ------------------------------------------------------
        got_r, read_s = _timed_drain(
            Dataset.from_snapshot(snap).distribute(
                service=svc, processing_mode="dynamic"
            )
        )
        read_eps = got_r * 8 / read_s
        rows.append(Row("snapshot/read_path", read_eps, "elements/s", "real",
                        f"{got_r} batches via service, chunk-sharded"))

        t0 = time.perf_counter()
        got_l = _drain(iterate_snapshot(snap))
        local_s = time.perf_counter() - t0
        rows.append(Row("snapshot/read_local", got_l * 8 / local_s,
                        "elements/s", "real", "detached read, no service hop"))

        rows.append(Row("snapshot/read_over_compute", read_eps / compute_eps,
                        "x", "real",
                        "ISSUE target >= 2x for a CPU-bound pipeline"))
        rows.append(Row("snapshot/write_overhead", write_s / compute_s, "x",
                        "real", "materialization cost vs one compute pass"))
        assert got >= expected_batches // 2, f"compute path starved: {got}"
    finally:
        svc.orchestrator.stop()
        shutil.rmtree(tmp, ignore_errors=True)

    print_rows(rows, "snapshot: materialized read path vs compute path")
    ratio = next(r for r in rows if r.name == "snapshot/read_over_compute")
    if ratio.value < 2.0:
        print(f"WARNING: read/compute ratio {ratio.value:.2f}x below 2x target",
              file=sys.stderr)
    return rows


if __name__ == "__main__":
    main()
